//! Integration: the PJRT runtime path end-to-end against the rust-native
//! sparse oracle, using the real AOT artifacts built by `make artifacts`.
//!
//! These tests are skipped (with a loud message) if `artifacts/` has not
//! been built — CI always builds artifacts first (`make test`).

use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::generate;
use veilgraph::pagerank::power::{PageRank, PageRankConfig};
use veilgraph::pagerank::summarized::run_summarized;
use veilgraph::runtime::artifact::{Manifest, Variant};
use veilgraph::runtime::client::XlaRuntime;
use veilgraph::runtime::executor::{Backend, SummarizedExecutor};
use veilgraph::summary::bigvertex::SummaryGraph;
use veilgraph::summary::hot::HotSet;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").is_file() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built — run `make artifacts`");
        None
    }
}

fn full_hot(g: &DynamicGraph) -> HotSet {
    let idxs: Vec<u32> = (0..g.num_vertices() as u32).collect();
    HotSet { k_r: idxs, k_n: vec![], k_delta: vec![], hot: vec![true; g.num_vertices()] }
}

fn cfg() -> PageRankConfig {
    PageRankConfig { beta: 0.85, max_iters: 100, epsilon: 1e-7, ..Default::default() }
}

#[test]
fn manifest_covers_step_and_run_tiers() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.capacities(Variant::Step).contains(&128));
    assert!(m.capacities(Variant::Run).contains(&128));
    assert!(m.iters_fused >= 1);
    assert_eq!(m.tile, 128);
}

#[test]
fn xla_step_matches_reference_formula() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let cap = rt.ensure_tier(Variant::Step, 100).unwrap();
    assert_eq!(cap, 128);
    // A = 0 except A[0,1] = 0.5; r = e1; b[0] = 0.25; mask first two rows.
    let mut a = vec![0.0f32; cap * cap];
    a[0 * cap + 1] = 0.5;
    let mut r = vec![0.0f32; cap];
    r[1] = 1.0;
    let mut b = vec![0.0f32; cap];
    b[0] = 0.25;
    let mut mask = vec![0.0f32; cap];
    mask[0] = 1.0;
    mask[1] = 1.0;
    let out = rt.execute(Variant::Step, cap, &a, &r, &b, &mask, 0.85, 0.01).unwrap();
    assert!(out.delta.is_none());
    // r'[0] = 0.85*(0.5*1 + 0.25) + 0.01 = 0.6475; r'[1] = 0.01; rest 0.
    assert!((out.ranks[0] - 0.6475).abs() < 1e-6, "{}", out.ranks[0]);
    assert!((out.ranks[1] - 0.01).abs() < 1e-6);
    assert!(out.ranks[2..].iter().all(|&x| x == 0.0));
}

#[test]
fn xla_run_variant_reports_delta_and_converges() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::new(&dir).unwrap();
    let cap = rt.ensure_tier(Variant::Run, 10).unwrap();
    // Two-cycle between 0 and 1.
    let mut a = vec![0.0f32; cap * cap];
    a[0 * cap + 1] = 1.0;
    a[1 * cap + 0] = 1.0;
    let mut r = vec![0.0f32; cap];
    r[0] = 0.9;
    r[1] = 0.1;
    let b = vec![0.0f32; cap];
    let mut mask = vec![0.0f32; cap];
    mask[0] = 1.0;
    mask[1] = 1.0;
    let teleport = 0.15 / 2.0;
    let mut delta_prev = f32::INFINITY;
    for _ in 0..4 {
        let out = rt.execute(Variant::Run, cap, &a, &r, &b, &mask, 0.85, teleport).unwrap();
        r = out.ranks.clone();
        let d = out.delta.expect("run variant returns delta");
        assert!(d <= delta_prev + 1e-6, "delta must shrink: {d} vs {delta_prev}");
        delta_prev = d;
    }
    // Fixed point of the 2-cycle: 0.5 each.
    assert!((r[0] - 0.5).abs() < 1e-3, "{}", r[0]);
    assert!((r[1] - 0.5).abs() < 1e-3);
}

#[test]
fn executor_xla_matches_sparse_oracle_on_random_summary() {
    let Some(dir) = artifacts_dir() else { return };
    // Synthetic BA graph; hot set = all (dense comparison is strongest).
    let edges = generate::barabasi_albert(300, 3, 0.4, 99);
    let (g, _) = DynamicGraph::from_edges(edges);
    let n = g.num_vertices();
    let prev = vec![1.0 / n as f64; n];
    let s = SummaryGraph::build(&g, &full_hot(&g), &prev, 0.0);
    assert!(s.num_vertices() <= 512);

    let sparse = run_summarized(&s, &cfg());
    let mut exec = SummarizedExecutor::with_artifacts(&dir).unwrap();
    exec.set_max_xla_k(usize::MAX); // force the dense path for the oracle check
    let (xla, backend) = exec.execute(&s, &cfg()).unwrap();
    assert!(matches!(backend, Backend::XlaDense { .. }), "{backend}");

    assert_eq!(sparse.ranks.len(), xla.ranks.len());
    for (i, (a, b)) in sparse.ranks.iter().zip(&xla.ranks).enumerate() {
        assert!((a - b).abs() < 1e-5, "rank {i}: sparse {a} vs xla {b}");
    }
}

#[test]
fn executor_matches_exact_pagerank_when_k_is_everything() {
    let Some(dir) = artifacts_dir() else { return };
    let edges = generate::erdos_renyi(200, 1200, 5);
    let (g, _) = DynamicGraph::from_edges(edges);
    let n = g.num_vertices();
    let prev = vec![1.0 / n as f64; n];
    let s = SummaryGraph::build(&g, &full_hot(&g), &prev, 0.0);

    let mut exec = SummarizedExecutor::with_artifacts(&dir).unwrap();
    exec.set_max_xla_k(usize::MAX);
    let (xla, _) = exec.execute(&s, &cfg()).unwrap();
    let exact = PageRank::new(cfg()).run(&g.snapshot());
    for (li, &v) in s.vertices.iter().enumerate() {
        assert!(
            (xla.ranks[li] - exact.ranks[v as usize]).abs() < 1e-4,
            "vertex {v}: {} vs {}",
            xla.ranks[li],
            exact.ranks[v as usize]
        );
    }
}

#[test]
fn oversized_summary_falls_back_to_sparse() {
    let Some(dir) = artifacts_dir() else { return };
    // 3000 hot vertices > max capacity 2048 ⇒ sparse backend.
    let edges = generate::erdos_renyi(3000, 9000, 11);
    let (g, _) = DynamicGraph::from_edges(edges);
    let n = g.num_vertices();
    let prev = vec![1.0 / n as f64; n];
    let s = SummaryGraph::build(&g, &full_hot(&g), &prev, 0.0);
    let mut exec = SummarizedExecutor::with_artifacts(&dir).unwrap();
    let (_, backend) = exec.execute(&s, &cfg()).unwrap();
    assert_eq!(backend, Backend::RustSparse);
}

#[test]
fn warmup_compiles_all_tiers() {
    let Some(dir) = artifacts_dir() else { return };
    let mut exec = SummarizedExecutor::with_artifacts(&dir).unwrap();
    let n = exec.warmup().unwrap();
    assert!(n >= 10, "expected >= 10 artifacts, got {n}");
}

#[test]
fn engine_with_xla_backend_tracks_exact() {
    use veilgraph::coordinator::engine::EngineBuilder;
    use veilgraph::coordinator::policies::AlwaysExact;
    use veilgraph::metrics::rbo::rbo_ext;
    use veilgraph::stream::event::EdgeOp;
    use veilgraph::summary::params::SummaryParams;

    let Some(dir) = artifacts_dir() else { return };
    let base = generate::barabasi_albert(500, 3, 0.3, 7);
    let mut approx = EngineBuilder::new()
        .params(SummaryParams::new(0.1, 1, 0.1))
        .artifacts_dir(&dir)
        .max_xla_k(2048) // exercise the dense path regardless of CPU cost
        .build_from_edges(base.iter().copied())
        .unwrap();
    assert!(approx.has_xla());
    let mut exact = EngineBuilder::new()
        .udf(Box::new(AlwaysExact))
        .build_from_edges(base.iter().copied())
        .unwrap();
    for round in 0..3u64 {
        let ops: Vec<EdgeOp> =
            (0..20).map(|i| EdgeOp::add(400 + round * 20 + i, (i * 13 + round) % 100)).collect();
        approx.ingest_many(ops.clone());
        exact.ingest_many(ops);
        let ra = approx.query().unwrap();
        let re = exact.query().unwrap();
        if ra.exec.summary_vertices > 0 && ra.exec.summary_vertices <= 2048 {
            assert!(
                matches!(ra.exec.backend, Some(Backend::XlaDense { .. })),
                "expected XLA backend, got {:?}",
                ra.exec.backend
            );
        }
        let rbo = rbo_ext(&ra.top_ids(50), &re.top_ids(50), 0.98);
        assert!(rbo > 0.85, "round {round}: rbo {rbo}");
    }
}
