//! Durable serving: crash recovery, torn-tail handling, snapshot
//! fallback, fault-injected degradation and durable subscriptions.
//!
//! * Kill/restart (drop the engine, rebuild over the same data dir)
//!   recovers a bit-identical graph and rank vector: snapshot load plus
//!   WAL-tail replay through the ordinary batch path.
//! * A clean shutdown writes a final checkpoint and recovery after it
//!   replays nothing.
//! * A torn WAL tail (partial final record, as a crash mid-write leaves
//!   behind) is detected by checksum and cleanly discarded — recovery
//!   keeps every complete record and never panics.
//! * A corrupted newest snapshot falls back to the older one; the WAL
//!   tail from there still reproduces the full pre-crash state.
//! * Property: for arbitrary op streams with interleaved queries and
//!   checkpoints, recovery equals both the pre-kill engine and the
//!   sequential oracle.
//! * Injected WAL write failures (disk-full) degrade a live server to
//!   in-memory serving with `durability_lost` visible in wire `stats`
//!   — the server keeps answering instead of crashing.
//! * An interval-synced WAL behind a simulated page cache loses at most
//!   the whole-record suffix appended after the last fsync; recovery
//!   equals the sequential oracle over exactly the synced prefix.
//! * Durable subscriptions survive a disconnect; re-subscribing under
//!   the same client token replays the missed diff.

use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use veilgraph::coordinator::checkpoint::DurabilityConfig;
use veilgraph::coordinator::engine::{Engine, EngineBuilder};
use veilgraph::coordinator::server::{handle_request, serve_shared, ServeOptions, ServerHandle};
use veilgraph::coordinator::wal::SyncPolicy;
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::stream::event::EdgeOp;
use veilgraph::testing::faults::{CrashPoint, FaultInjector, FaultyIo, VolatileIo};
use veilgraph::testing::oracle::seq_apply;
use veilgraph::testing::vprop::{forall, Gen};
use veilgraph::util::json::Json;

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

/// Unique per-test data directory, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(name: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!(
            "vg-dur-{name}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&p);
        TempDir(p)
    }

    fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

fn ring(n: u64) -> Vec<(u64, u64)> {
    (0..n).map(|i| (i, (i + 1) % n)).collect()
}

/// Batch-synced config with explicit-only checkpoints (the tests cut
/// them by hand where the scenario calls for one).
fn cfg(dir: &Path) -> DurabilityConfig {
    DurabilityConfig::new(dir).sync(SyncPolicy::Batch).checkpoint_every(1_000_000)
}

/// Graph identity: external ids in insertion order plus every edge as
/// an external-id pair in adjacency order.
fn graph_fp(g: &DynamicGraph) -> (Vec<u64>, Vec<(u64, u64)>) {
    let ids = g.ids().to_vec();
    let edges = g.edges().map(|(s, d)| (g.id(s), g.id(d))).collect();
    (ids, edges)
}

/// Rank vector as raw bits — recovery claims *bit*-identity, not
/// epsilon-closeness.
fn rank_bits(e: &Engine) -> Vec<u64> {
    e.ranks().iter().map(|r| r.to_bits()).collect()
}

/// Cut a checkpoint synchronously through the same job the server ships
/// off-thread.
fn checkpoint_now(e: &mut Engine) {
    let job = e.begin_checkpoint(None).expect("durable engine yields a checkpoint job");
    let out = job.run();
    assert!(out.ok, "checkpoint failed: {:?}", out.err);
    e.finish_checkpoint(out);
}

/// Newest file under `dir` matching `prefix` (by name order, which both
/// WAL segments and snapshots make chronological via zero-padded seqs).
fn newest_file(dir: &Path, prefix: &str) -> PathBuf {
    let mut names: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with(prefix))
                .unwrap_or(false)
        })
        .collect();
    names.sort();
    names.pop().unwrap_or_else(|| panic!("no {prefix}* under {dir:?}"))
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

fn read_json_line(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
}

// ---------------------------------------------------------------------------
// Kill / restart
// ---------------------------------------------------------------------------

/// Acceptance: dropping the engine mid-stream (the in-process stand-in
/// for `kill -9`) and rebuilding over the same directory recovers a
/// bit-identical graph and rank vector — the newest snapshot plus a
/// two-record WAL-tail replay.
#[test]
fn kill_and_restart_recovers_bit_identical_state() {
    let dir = TempDir::new("kill");
    let (mut engine, report) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(ring(10))
        .unwrap();
    assert!(report.snapshot_loaded.is_none() && report.replayed_batches == 0);
    assert!(engine.durable());

    // A few effective batches over the existing vertices, then a query
    // so the rank vector is fresh at the checkpoint.
    for b in 0..5u64 {
        engine.ingest_batch([
            EdgeOp::add(b, (b + 3) % 10),
            EdgeOp::remove(b, (b + 1) % 10),
        ]);
        engine.flush_pending();
    }
    engine.query().unwrap();
    checkpoint_now(&mut engine);

    // Two more batches land only in the WAL: the recovery tail.
    engine.ingest_batch([EdgeOp::add(7, 2)]);
    engine.flush_pending();
    engine.ingest_batch([EdgeOp::add(8, 3)]);
    engine.flush_pending();

    let (pre_ids, pre_edges) = graph_fp(engine.graph());
    let pre_ranks = rank_bits(&engine);
    let pre_version = engine.graph().version();
    drop(engine); // kill

    let (rec, report) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(Vec::<(u64, u64)>::new())
        .unwrap();
    assert!(report.snapshot_loaded.is_some(), "snapshot found");
    assert_eq!(report.replayed_batches, 2, "exactly the tail replays");
    assert_eq!(report.replayed_ops, 2);
    assert!(!report.clean_shutdown);
    assert!(!report.torn_tail_discarded);

    let (ids, edges) = graph_fp(rec.graph());
    assert_eq!(ids, pre_ids, "vertex set + order recovered exactly");
    assert_eq!(edges, pre_edges, "edge list recovered exactly");
    assert_eq!(rank_bits(&rec), pre_ranks, "ranks recovered bit-identically");
    assert_eq!(rec.graph().version(), pre_version, "topology version recovered");
    assert!(rec.durability_stats().enabled());
}

/// Acceptance: graceful shutdown persists everything — recovery loads
/// the final clean snapshot and replays nothing.
#[test]
fn clean_shutdown_replays_nothing() {
    let dir = TempDir::new("clean");
    let (mut engine, _) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(ring(8))
        .unwrap();
    engine.ingest_batch([EdgeOp::add(0, 4), EdgeOp::add(2, 6)]);
    // Deliberately NOT flushed: shutdown must drain the in-flight batch
    // through the WAL + apply path itself.
    engine.shutdown_durable(None);
    let (pre_ids, pre_edges) = graph_fp(engine.graph());
    drop(engine);

    let (rec, report) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(Vec::<(u64, u64)>::new())
        .unwrap();
    assert!(report.clean_shutdown, "final checkpoint is marked clean");
    assert_eq!(report.replayed_batches, 0, "clean recovery replays nothing");
    assert!(report.snapshot_loaded.is_some());
    assert_eq!(graph_fp(rec.graph()), (pre_ids, pre_edges));
}

// ---------------------------------------------------------------------------
// Corruption: torn WAL tail, corrupted snapshot
// ---------------------------------------------------------------------------

/// Acceptance: a crash mid-record leaves a torn tail; recovery discards
/// exactly the incomplete record, keeps every complete one, and does
/// not panic.
#[test]
fn torn_wal_tail_is_discarded_cleanly() {
    let dir = TempDir::new("torn");
    let (mut engine, _) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(ring(12))
        .unwrap();
    let chords =
        [EdgeOp::add(0, 5), EdgeOp::add(1, 6), EdgeOp::add(2, 7)];
    for op in chords {
        engine.ingest_batch([op]);
        engine.flush_pending();
    }
    drop(engine); // kill with 3 records on disk and no checkpoint

    // Clip the last record's checksum: the torn tail a short write
    // leaves behind.
    let seg = newest_file(dir.path(), "wal-");
    let bytes = fs::read(&seg).unwrap();
    fs::write(&seg, &bytes[..bytes.len() - 4]).unwrap();

    let (rec, report) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(ring(12))
        .unwrap();
    assert!(report.torn_tail_discarded, "checksum catches the partial record");
    assert_eq!(report.replayed_batches, 2, "complete records all replay");

    // End state == initial edges + the two surviving chords, per the
    // sequential oracle.
    let (mut oracle, _) = DynamicGraph::from_edges(ring(12));
    seq_apply(&mut oracle, &chords[..2]);
    assert_eq!(graph_fp(rec.graph()), graph_fp(&oracle));
}

/// Acceptance: recovery falls back to the previous snapshot when the
/// newest is corrupt, then reaches the full pre-crash state through the
/// longer WAL tail (segments are only pruned up to the *successful*
/// snapshot's position).
#[test]
fn corrupt_snapshot_falls_back_to_older() {
    let dir = TempDir::new("fallback");
    let (mut engine, _) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(ring(8))
        .unwrap();
    let batches = [EdgeOp::add(0, 3), EdgeOp::add(1, 4), EdgeOp::add(2, 5)];
    engine.ingest_batch([batches[0]]);
    engine.flush_pending();
    engine.query().unwrap();
    checkpoint_now(&mut engine); // snapshot A @ wal seq 1
    engine.ingest_batch([batches[1]]);
    engine.flush_pending();
    checkpoint_now(&mut engine); // snapshot B @ wal seq 2
    engine.ingest_batch([batches[2]]);
    engine.flush_pending();
    let (pre_ids, pre_edges) = graph_fp(engine.graph());
    drop(engine);

    // Flip a byte in the middle of the newest snapshot.
    let snap = newest_file(dir.path(), "ckpt-");
    let mut bytes = fs::read(&snap).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    fs::write(&snap, &bytes).unwrap();

    let (rec, report) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(Vec::<(u64, u64)>::new())
        .unwrap();
    assert_eq!(report.snapshots_skipped, 1, "corrupt snapshot B skipped");
    assert!(report.snapshot_loaded.is_some(), "snapshot A verified");
    assert_eq!(report.replayed_batches, 2, "tail from A covers batches 2+3");
    assert_eq!(graph_fp(rec.graph()), (pre_ids, pre_edges));
}

// ---------------------------------------------------------------------------
// Crash points
// ---------------------------------------------------------------------------

/// A crash injected immediately after the WAL append: the record is
/// durable, the in-memory apply never happened — recovery must replay
/// it, making the crash invisible in the recovered state.
#[test]
fn post_wal_append_crash_loses_nothing() {
    let dir = TempDir::new("crashpoint");
    let inj = FaultInjector::new();
    let (mut engine, _) = EngineBuilder::new()
        .durability(cfg(dir.path()).faults(Arc::clone(&inj)))
        .build_durable(ring(6))
        .unwrap();
    inj.arm_crash(CrashPoint::PostWalAppend);
    engine.ingest_batch([EdgeOp::add(0, 3)]);
    engine.flush_pending(); // append lands, apply does not, engine dies
    assert_eq!(inj.trips(), 1);
    assert!(
        !engine.graph().has_edge(0, 3),
        "the crashed batch never mutated the in-memory graph"
    );
    assert!(engine.query().is_err(), "the engine is dead, as after a real crash");
    drop(engine);

    let (rec, report) = EngineBuilder::new()
        .durability(cfg(dir.path()))
        .build_durable(ring(6))
        .unwrap();
    assert_eq!(report.replayed_batches, 1, "the durable record replays");
    assert!(rec.graph().has_edge(0, 3), "nothing acknowledged to the WAL is lost");
}

// ---------------------------------------------------------------------------
// Property: recovery == pre-kill engine == sequential oracle
// ---------------------------------------------------------------------------

/// For arbitrary op streams (growth, removals, interleaved queries and
/// checkpoints at random points), snapshot + WAL-tail replay leaves a
/// graph bit-identical to the killed engine's, which in turn equals the
/// sequential oracle over the raw stream.
#[test]
fn recovery_matches_seq_apply_oracle() {
    forall(8, 0xD1CE, |g: &mut Gen| {
        let dir = TempDir::new(&format!("prop-{:x}", g.case_seed));
        let n = g.usize(4..9);
        let mut initial = g.edges(n, 12);
        initial.push((0, 1)); // never start empty
        let (mut engine, _) = EngineBuilder::new()
            .durability(cfg(dir.path()))
            .build_durable(initial.clone())
            .unwrap();

        let mut all_ops: Vec<EdgeOp> = Vec::new();
        for _ in 0..g.usize(1..4) {
            let mut batch = Vec::new();
            for _ in 0..g.usize(1..6) {
                // Ids past `n` introduce brand-new vertices, so
                // checkpoints exercise the rank-vector extension.
                let src = g.u64(0..n as u64 + 3);
                let dst = g.u64(0..n as u64 + 3);
                if src == dst {
                    continue;
                }
                batch.push(if g.bool(0.25) {
                    EdgeOp::remove(src, dst)
                } else {
                    EdgeOp::add(src, dst)
                });
            }
            all_ops.extend(batch.iter().copied());
            engine.ingest_batch(batch);
            engine.flush_pending();
            if g.bool(0.4) {
                engine.query().unwrap();
            }
            if g.bool(0.3) {
                checkpoint_now(&mut engine);
            }
        }

        let pre = graph_fp(engine.graph());
        drop(engine); // kill

        let (rec, _) = EngineBuilder::new()
            .durability(cfg(dir.path()))
            .build_durable(initial.clone())
            .unwrap();
        assert_eq!(graph_fp(rec.graph()), pre, "recovered graph == killed engine's");

        let (mut oracle, _) = DynamicGraph::from_edges(initial);
        seq_apply(&mut oracle, &all_ops);
        assert_eq!(graph_fp(rec.graph()), graph_fp(&oracle), "recovered graph == oracle");
    });
}

// ---------------------------------------------------------------------------
// Interval sync: the page-cache loss window
// ---------------------------------------------------------------------------

/// Acceptance: under `SyncPolicy::Interval` a crash loses *at most* the
/// records appended since the last fsync — and loses them cleanly.
/// [`VolatileIo`] models the OS page cache: appends dirty an in-memory
/// buffer, and only a sync (the first append after the interval
/// elapses) lands the whole buffer on disk. Three sync cycles
/// interleave durable and dirty batches; the crash then discards
/// exactly the post-final-sync suffix, so recovery equals the
/// sequential oracle over the synced prefix — no torn record, no
/// partially applied batch.
#[test]
fn interval_sync_crash_loses_only_the_unsynced_suffix() {
    let dir = TempDir::new("interval");
    let initial = ring(6);
    let vol_cfg = || {
        DurabilityConfig::new(dir.path())
            .sync(SyncPolicy::Interval(150))
            .checkpoint_every(1_000_000)
            .io(Box::new(VolatileIo::new()))
    };
    let (mut engine, _) =
        EngineBuilder::new().durability(vol_cfg()).build_durable(initial.clone()).unwrap();

    let mut all_ops: Vec<EdgeOp> = Vec::new();
    let mut batches = 0usize;
    let mut durable_ops = 0usize; // ops covered by the last fsync
    let mut durable_batches = 0usize;
    for cycle in 0..3u64 {
        // Past the interval: the next append fsyncs, which lands every
        // batch appended so far — earlier cycles' dirty ones included.
        std::thread::sleep(Duration::from_millis(200));
        let v = 100 + cycle * 10;
        let synced = [EdgeOp::add(v, cycle % 6), EdgeOp::add(v + 1, v)];
        engine.ingest_batch(synced);
        engine.flush_pending();
        all_ops.extend(synced);
        batches += 1;
        durable_ops = all_ops.len();
        durable_batches = batches;
        // Well inside the interval: page-cache only until the next
        // sync. The final cycle's pair never gets one.
        let dirty = [EdgeOp::add(v + 2, v + 1), EdgeOp::remove(cycle % 6, (cycle + 1) % 6)];
        for op in dirty {
            engine.ingest_batch([op]);
            engine.flush_pending();
        }
        all_ops.extend(dirty);
        batches += 2;
    }
    assert!(engine.graph().ids().contains(&122), "pre-crash state holds the dirty tail");
    drop(engine); // power loss: dirty pages evaporate

    let (rec, report) =
        EngineBuilder::new().durability(vol_cfg()).build_durable(initial.clone()).unwrap();
    assert!(!report.clean_shutdown);
    assert!(!report.torn_tail_discarded, "the loss window is whole records, never a torn one");
    assert!(report.snapshot_loaded.is_none(), "no checkpoint was ever cut");
    assert_eq!(report.replayed_batches, durable_batches, "exactly the synced prefix replays");
    assert_eq!(report.replayed_ops, durable_ops);

    let (mut oracle, _) = DynamicGraph::from_edges(initial);
    seq_apply(&mut oracle, &all_ops[..durable_ops]);
    assert_eq!(graph_fp(rec.graph()), graph_fp(&oracle), "recovered == oracle(synced prefix)");
    assert!(!rec.graph().ids().contains(&122), "post-sync suffix is gone");
}

// ---------------------------------------------------------------------------
// Degradation: WAL write failure on a live server
// ---------------------------------------------------------------------------

/// Acceptance: persistent WAL write failures (injected disk-full) do
/// not crash the server — after the failure threshold it degrades to
/// in-memory serving, flags `durability_lost` in wire `stats`, and
/// keeps answering reads and writes.
#[test]
fn wal_write_failure_degrades_to_in_memory() {
    let dir = TempDir::new("degrade");
    let inj = FaultInjector::new();
    // Exactly the 16-byte segment header fits; every record write hits
    // injected ENOSPC.
    inj.set_disk_budget(16);
    let (engine, _) = EngineBuilder::new()
        .durability(
            cfg(dir.path())
                .io(Box::new(FaultyIo::new(Arc::clone(&inj))))
                .faults(Arc::clone(&inj)),
        )
        .build_durable(ring(8))
        .unwrap();
    let h = ServerHandle::spawn_with(engine, &ServeOptions::new());
    assert!(!h.durability_stats().durability_lost());

    // Each query drains the batched write path into the WAL; after
    // MAX_CONSECUTIVE_FAILURES appends the log declares itself lost.
    for i in 0..4u64 {
        h.ingest(EdgeOp::add(i, i + 20)).unwrap();
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":3}"#);
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(true),
            "the server keeps serving through WAL failures"
        );
    }
    assert!(inj.short_writes() > 0, "the injected disk actually refused writes");
    assert!(h.durability_stats().durability_lost());

    // The loss is visible over the wire, and the server still answers.
    let (stats, _) = handle_request(&h, r#"{"op":"stats"}"#);
    let dur = stats.get("stats").unwrap().get("durability").unwrap();
    assert_eq!(dur.get("durability_lost").and_then(Json::as_bool), Some(true));
    assert_eq!(dur.get("enabled").and_then(Json::as_bool), Some(true));
    assert!(dur.get("wal_errors").and_then(Json::as_u64).unwrap() >= 3);
    let (resp, _) = handle_request(&h, r#"{"op":"top","k":3}"#);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    h.shutdown();
}

// ---------------------------------------------------------------------------
// Durable subscriptions across reconnects
// ---------------------------------------------------------------------------

/// Acceptance: a tokened subscription survives its connection. While
/// the client is away the top-1 flips; re-subscribing under the same
/// token acks `replayed: true` and delivers the missed diff instead of
/// silently resetting the baseline.
#[test]
fn durable_subscription_replays_missed_diff_on_reconnect() {
    // A star into vertex 0: the unambiguous initial top-1.
    let star: Vec<(u64, u64)> = (1..=6).map(|i| (i, 0)).collect();
    let engine = EngineBuilder::new().build_from_edges(star).unwrap();
    let h = Arc::new(ServerHandle::spawn_with(engine, &ServeOptions::new()));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = {
        let h2 = Arc::clone(&h);
        std::thread::spawn(move || {
            serve_shared(h2, listener, ServeOptions::new().workers(1)).unwrap()
        })
    };

    // Control connection: drives updates while the subscriber is away.
    let mut ctl = TcpStream::connect(addr).unwrap();
    ctl.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut ctl_r = BufReader::new(ctl.try_clone().unwrap());

    // Subscriber, take one: tokened top-1 subscription.
    {
        let mut sub = TcpStream::connect(addr).unwrap();
        sub.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut sub_r = BufReader::new(sub.try_clone().unwrap());
        send_line(&mut sub, r#"{"v":2,"op":"subscribe","what":"topk","k":1,"token":"cli-1"}"#);
        let ack = read_json_line(&mut sub_r);
        assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            ack.get("replayed").and_then(Json::as_bool),
            Some(false),
            "first registration has nothing to replay"
        );
    } // connection dropped — NOT unsubscribed

    // The record outlives the connection.
    let deadline = Instant::now() + Duration::from_secs(10);
    while !h.subscriptions().is_empty() {
        assert!(Instant::now() < deadline, "closed connection never reaped");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(h.subscriptions().durable_len(), 1, "durable record survives the disconnect");

    // While the subscriber is away, vertex 7 takes the top spot: six
    // spoke in-links plus one from the old hub.
    for i in 1..=6u64 {
        send_line(&mut ctl, &format!(r#"{{"op":"add","src":{i},"dst":7}}"#));
        assert_eq!(read_json_line(&mut ctl_r).get("ok").and_then(Json::as_bool), Some(true));
    }
    send_line(&mut ctl, r#"{"op":"add","src":0,"dst":7}"#);
    assert_eq!(read_json_line(&mut ctl_r).get("ok").and_then(Json::as_bool), Some(true));
    send_line(&mut ctl, r#"{"v":2,"id":9,"op":"query","top":1}"#);
    let resp = read_json_line(&mut ctl_r);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));

    // Wait until a snapshot ranking 7 on top is actually published (the
    // recompute lands asynchronously).
    let reader = h.reader();
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let top = reader.top(1);
        if top.first().map(|&(id, _)| id) == Some(7) {
            break;
        }
        assert!(Instant::now() < deadline, "vertex 7 never reached the top");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Subscriber, take two: same token, same spec. The ack flags the
    // replay and the missed top-1 turnover arrives as a push frame.
    let mut sub = TcpStream::connect(addr).unwrap();
    sub.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut sub_r = BufReader::new(sub.try_clone().unwrap());
    send_line(&mut sub, r#"{"v":2,"op":"subscribe","what":"topk","k":1,"token":"cli-1"}"#);
    let mut replay_ack = None;
    let mut frame = None;
    for _ in 0..50 {
        let line = read_json_line(&mut sub_r);
        if line.get("notify").is_some() {
            frame = Some(line);
        } else {
            replay_ack = Some(line);
        }
        if replay_ack.is_some() && frame.is_some() {
            break;
        }
    }
    let ack = replay_ack.expect("re-subscribe ack never arrived");
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(ack.get("replayed").and_then(Json::as_bool), Some(true));
    let frame = frame.expect("missed-diff push frame never arrived");
    let body = frame.get("notify").unwrap();
    assert_eq!(body.get("kind").and_then(Json::as_str), Some("topk"));
    let entered: Vec<u64> = body
        .get("entered")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_u64)
        .collect();
    let left: Vec<u64> = body
        .get("left")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_u64)
        .collect();
    assert_eq!(entered, vec![7], "the new top-1 replays as entered");
    assert_eq!(left, vec![0], "the displaced hub replays as left");

    // An explicit unsubscribe DOES remove the durable record.
    let sub_id = ack.get("sub").and_then(Json::as_u64).unwrap();
    send_line(&mut sub, &format!(r#"{{"v":2,"op":"unsubscribe","sub":{sub_id}}}"#));
    assert_eq!(read_json_line(&mut sub_r).get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(h.subscriptions().durable_len(), 0);

    send_line(&mut ctl, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut ctl_r).get("ok").and_then(Json::as_bool), Some(true));
    server.join().unwrap();
}
