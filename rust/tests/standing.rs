//! Standing queries, wire protocol v2 and windowed graphs: acceptance.
//!
//! * Push-plane correctness: the notifications a subscription mailbox
//!   receives across a sequence of publishes must exactly match a
//!   brute-force diff of the consecutive `RankSnapshot`s, for arbitrary
//!   interleavings of rank movement, hot-set churn and top-K turnover.
//! * Protocol v2: a pipelining client gets its responses out of order
//!   (each tagged with the echoed request id) while a v1 client on the
//!   same server keeps strict in-order semantics.
//! * Subscriptions ride real TCP connections: a `subscribe` over v2
//!   yields push frames when the watched condition fires at publish.
//! * Sliding-window expiry is equivalent to a manually-maintained
//!   `RemoveEdge` stream, checked through the sequential oracle.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::server::{serve_shared, ServeOptions, ServerHandle};
use veilgraph::coordinator::serving::{RankSnapshot, SnapshotPublisher};
use veilgraph::coordinator::subscription::{Mailbox, Notification, Subscription};
use veilgraph::coordinator::udf::{Action, ExecStats, QueryContext, UdfSuite};
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::stream::event::EdgeOp;
use veilgraph::stream::window::SlidingWindow;
use veilgraph::testing::oracle::seq_apply;
use veilgraph::testing::vprop::{forall, Gen};
use veilgraph::util::json::Json;

fn ring(n: u64) -> Vec<(u64, u64)> {
    (0..n).map(|i| (i, (i + 1) % n)).collect()
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

fn read_json_line(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
}

// ---------------------------------------------------------------------------
// Push-plane correctness against a brute-force model
// ---------------------------------------------------------------------------

/// The model's view of one published snapshot: parallel id/rank arrays
/// plus the hot set, all recomputed from scratch per transition.
#[derive(Clone, Default)]
struct ModelState {
    ids: Vec<u64>,
    ranks: Vec<f64>,
    hot: Vec<u64>,
}

impl ModelState {
    fn rank_of(&self, id: u64) -> f64 {
        self.ids.iter().position(|&v| v == id).map(|i| self.ranks[i]).unwrap_or(0.0)
    }

    /// Top-k ids by rank, descending. Ranks are generated distinct, so
    /// the order is unambiguous without knowing the snapshot's
    /// tie-break.
    fn top(&self, k: usize) -> Vec<u64> {
        let mut idx: Vec<usize> = (0..self.ids.len()).collect();
        idx.sort_by(|&a, &b| self.ranks[b].partial_cmp(&self.ranks[a]).unwrap());
        idx.into_iter().take(k).map(|i| self.ids[i]).collect()
    }
}

/// Brute-force re-derivation of what one subscription should fire on a
/// `prev -> next` publish transition, independent of the library's diff.
fn brute_diff(
    spec: &Subscription,
    prev: &ModelState,
    next: &ModelState,
    version: u64,
) -> Option<Notification> {
    match *spec {
        Subscription::TopK { k } => {
            let before = prev.top(k);
            let after = next.top(k);
            let entered: Vec<u64> =
                after.iter().copied().filter(|v| !before.contains(v)).collect();
            let left: Vec<u64> =
                before.iter().copied().filter(|v| !after.contains(v)).collect();
            if entered.is_empty() && left.is_empty() {
                None
            } else {
                Some(Notification::TopK { k, version, entered, left })
            }
        }
        Subscription::RankThreshold { id, tau } => {
            let was = prev.rank_of(id) > tau;
            let rank = next.rank_of(id);
            let is = rank > tau;
            if was == is {
                None
            } else {
                Some(Notification::RankThreshold { id, tau, rank, up: is, version })
            }
        }
        Subscription::HotSet { id } => {
            let was = prev.hot.contains(&id);
            let is = next.hot.contains(&id);
            if was == is {
                None
            } else {
                Some(Notification::HotSet { id, entered: is, version })
            }
        }
        Subscription::Community { .. } => None,
    }
}

fn model_snapshot(state: &ModelState, version: u64) -> Arc<RankSnapshot> {
    let mut s = RankSnapshot::new(
        version,
        version,
        version,
        Action::ComputeExact,
        ExecStats::default(),
        state.ids.clone(),
        state.ranks.clone(),
        state.ids.len().max(1),
        Json::Null,
    );
    s.set_hot_set(state.hot.clone());
    Arc::new(s)
}

/// Acceptance (property): for arbitrary subscription mixes and arbitrary
/// snapshot sequences, the frames in the mailbox after each publish are
/// exactly the brute-force diffs, in registration order.
#[test]
fn notifications_match_bruteforce_snapshot_diffs() {
    forall(40, 0x57A4D, |g: &mut Gen| {
        let n = g.usize(3..12);
        let ids: Vec<u64> = (0..n as u64).collect();
        let publisher = SnapshotPublisher::new();
        let mb = Mailbox::new();
        let mut specs: Vec<(u64, Subscription)> = Vec::new();
        for _ in 0..g.usize(1..6) {
            let spec = match g.usize(0..3) {
                0 => Subscription::TopK { k: g.usize(1..n + 1) },
                1 => Subscription::RankThreshold {
                    id: g.u64(0..n as u64 + 2),
                    tau: g.f64(0.0..1.0),
                },
                _ => Subscription::HotSet { id: g.u64(0..n as u64 + 2) },
            };
            let sub = publisher.subscriptions().subscribe(spec, &mb);
            specs.push((sub, spec));
        }

        // The publisher starts on the empty snapshot: the first publish
        // transitions from "no vertices at all", which the model covers
        // with its Default state.
        let mut prev = ModelState::default();
        for round in 0..g.usize(2..8) {
            let version = round as u64 + 1;
            // Distinct ranks via a shuffled fixed value set: no ties, so
            // the model's top-k needs no tie-break knowledge.
            let mut ranks: Vec<f64> =
                (0..n).map(|i| (i + 1) as f64 / (n + 1) as f64).collect();
            for i in (1..n).rev() {
                ranks.swap(i, g.usize(0..i + 1));
            }
            let hot: Vec<u64> =
                ids.iter().copied().filter(|_| g.bool(0.4)).collect();
            let next = ModelState { ids: ids.clone(), ranks, hot };

            let expected: Vec<Json> = specs
                .iter()
                .filter_map(|(sub, spec)| {
                    brute_diff(spec, &prev, &next, version).map(|ev| ev.to_json(*sub))
                })
                .collect();
            publisher.publish(model_snapshot(&next, version));
            assert_eq!(
                mb.drain(),
                expected,
                "publish v{version} fired the wrong notification set"
            );
            prev = next;
        }
    });
}

// ---------------------------------------------------------------------------
// Wire protocol v2 over TCP
// ---------------------------------------------------------------------------

/// A UDF whose `on_query` parks until released: pins the engine thread
/// inside a synchronous query so wire queries provably queue behind it.
struct ParkSuite {
    entered: Arc<AtomicBool>,
    release: Arc<AtomicBool>,
}

impl UdfSuite for ParkSuite {
    fn on_query(&mut self, _ctx: &QueryContext) -> Action {
        self.entered.store(true, Ordering::SeqCst);
        while !self.release.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        Action::ComputeApproximate
    }
}

/// Acceptance: with the engine thread provably parked, a pipelining v2
/// client gets the off-queue read answered *before* its earlier wire
/// query (out-of-order, matched by id), while a v1 client on the same
/// server still gets strict request-order responses.
#[test]
fn v2_pipelines_out_of_order_while_v1_stays_in_order() {
    let entered = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let engine = EngineBuilder::new()
        .udf(Box::new(ParkSuite {
            entered: Arc::clone(&entered),
            release: Arc::clone(&release),
        }))
        .build_from_edges(ring(20))
        .unwrap();
    let h = Arc::new(ServerHandle::spawn_with(engine, &ServeOptions::new()));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = {
        let h2 = Arc::clone(&h);
        std::thread::spawn(move || {
            serve_shared(h2, listener, ServeOptions::new().workers(2)).unwrap()
        })
    };

    // Park the engine thread inside a synchronous query.
    h.ingest(EdgeOp::add(0, 7)).unwrap();
    let parked = {
        let h2 = Arc::clone(&h);
        std::thread::spawn(move || h2.query().unwrap())
    };
    while !entered.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(1));
    }

    // v2 client: wire query (stuck behind the parked engine) then an
    // off-queue read. The read's answer must arrive first.
    let mut v2 = TcpStream::connect(addr).unwrap();
    v2.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut r2 = BufReader::new(v2.try_clone().unwrap());
    send_line(&mut v2, r#"{"v":2,"id":101,"op":"query","top":3}"#);
    send_line(&mut v2, r#"{"v":2,"id":202,"op":"top","k":3}"#);
    let first = read_json_line(&mut r2);
    assert_eq!(first.get("id").unwrap().as_u64(), Some(202), "read overtakes the wire query");
    assert_eq!(first.get("v").unwrap().as_u64(), Some(2));
    assert_eq!(first.get("top").unwrap().as_arr().unwrap().len(), 3);

    // v1 client on the same server: a pending query pauses its reads, so
    // responses keep request order even though the top could answer now.
    let mut v1 = TcpStream::connect(addr).unwrap();
    v1.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut r1 = BufReader::new(v1.try_clone().unwrap());
    send_line(&mut v1, r#"{"op":"query","top":2}"#);
    send_line(&mut v1, r#"{"op":"top","k":2}"#);

    release.store(true, Ordering::SeqCst);
    parked.join().unwrap();

    // v2's second response is the completed query, tagged with its id.
    let second = read_json_line(&mut r2);
    assert_eq!(second.get("id").unwrap().as_u64(), Some(101));
    assert!(second.get("action").is_some(), "wire query response carries the decision");

    // v1's responses come back strictly in request order: query first
    // (it has action/scheduled), then the read.
    let first_v1 = read_json_line(&mut r1);
    assert_eq!(first_v1.get("v").unwrap().as_u64(), Some(1));
    assert!(first_v1.get("id").is_none(), "v1 has no id surface");
    assert!(first_v1.get("scheduled").is_some(), "v1 response order is request order");
    let second_v1 = read_json_line(&mut r1);
    assert_eq!(second_v1.get("top").unwrap().as_arr().unwrap().len(), 2);

    send_line(&mut v1, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut r1).get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap();
}

/// Acceptance: a v2 TCP client registers a standing rank-threshold query
/// and receives a push frame when a later publish crosses it. v1
/// connections are refused the subscribe op.
#[test]
fn tcp_subscription_pushes_on_rank_crossing() {
    let engine = EngineBuilder::new().build_from_edges(ring(12)).unwrap();
    let h = Arc::new(ServerHandle::spawn_with(engine, &ServeOptions::new()));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = {
        let h2 = Arc::clone(&h);
        std::thread::spawn(move || {
            serve_shared(h2, listener, ServeOptions::new().workers(1)).unwrap()
        })
    };

    let mut c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut r = BufReader::new(c.try_clone().unwrap());

    // v1 subscribe is a typed refusal.
    send_line(&mut c, r#"{"op":"subscribe","what":"rank","id":500,"tau":1e-12}"#);
    let resp = read_json_line(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));

    // v2 subscribe: vertex 500 does not exist yet, so its rank is 0 and
    // any positive rank after it joins the graph crosses tau upward.
    // (No request "id" here — the subscription target uses that key.)
    send_line(&mut c, r#"{"v":2,"op":"subscribe","what":"rank","id":500,"tau":1e-12}"#);
    let ack = read_json_line(&mut r);
    assert_eq!(ack.get("ok").unwrap().as_bool(), Some(true));
    let sub = ack.get("sub").unwrap().as_u64().unwrap();
    assert_eq!(h.subscriptions().len(), 1);

    send_line(&mut c, r#"{"op":"add","src":500,"dst":0}"#);
    send_line(&mut c, r#"{"v":2,"id":2,"op":"query","top":2}"#);

    // Reads now interleave: two request responses plus (once the
    // recompute publishes) the push frame. Scan until the frame shows.
    let mut notify = None;
    for _ in 0..50 {
        let line = read_json_line(&mut r);
        if line.get("notify").is_some() {
            notify = Some(line);
            break;
        }
    }
    let frame = notify.expect("rank-crossing push frame never arrived");
    assert_eq!(frame.get("v").unwrap().as_u64(), Some(2));
    assert_eq!(frame.get("sub").unwrap().as_u64(), Some(sub));
    let body = frame.get("notify").unwrap();
    assert_eq!(body.get("kind").and_then(Json::as_str), Some("rank"));
    assert_eq!(body.get("id").and_then(Json::as_u64), Some(500));
    assert_eq!(body.get("direction").and_then(Json::as_str), Some("up"));

    // Unsubscribe echoes the id; a second unsubscribe is unknown.
    send_line(&mut c, &format!(r#"{{"v":2,"op":"unsubscribe","sub":{sub}}}"#));
    let resp = read_json_line(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    send_line(&mut c, &format!(r#"{{"v":2,"op":"unsubscribe","sub":{sub}}}"#));
    assert_eq!(read_json_line(&mut r).get("ok").unwrap().as_bool(), Some(false));
    assert!(h.subscriptions().is_empty());

    send_line(&mut c, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut r).get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap();
}

// ---------------------------------------------------------------------------
// Sliding window vs manual-removal oracle
// ---------------------------------------------------------------------------

fn edge_set(g: &DynamicGraph) -> Vec<(u64, u64)> {
    let mut es: Vec<(u64, u64)> = g.edges().map(|(s, d)| (g.id(s), g.id(d))).collect();
    es.sort_unstable();
    es
}

fn remove_pairs(ops: &[EdgeOp]) -> Vec<(u64, u64)> {
    let mut pairs: Vec<(u64, u64)> = ops
        .iter()
        .map(|op| match *op {
            EdgeOp::RemoveEdge(s, d) => (s, d),
            ref other => panic!("window emitted a non-remove op {other:?}"),
        })
        .collect();
    pairs.sort_unstable();
    pairs
}

/// Acceptance (property): the window's generated expiries equal an
/// independent model's at every tick, and feeding "client ops + window
/// expiries" through the sequential oracle leaves a graph identical to
/// "client ops + the model's manual RemoveEdge stream".
#[test]
fn windowed_expiry_matches_manual_remove_oracle() {
    forall(50, 0xD00F, |g: &mut Gen| {
        let verts = g.u64(2..7);
        let window = g.u64(3..20);
        let horizon = g.u64(10..40);
        let mut w = SlidingWindow::new(window);
        // Independent model: per edge, the multiset of unexpired admit
        // deadlines; an explicit remove clears it. A manual RemoveEdge is
        // due the tick the last deadline passes.
        let mut model: std::collections::HashMap<(u64, u64), Vec<u64>> =
            std::collections::HashMap::new();
        let mut windowed: Vec<EdgeOp> = Vec::new();
        let mut manual: Vec<EdgeOp> = Vec::new();

        for t in 0..=horizon {
            for _ in 0..g.usize(0..3) {
                let (s, d) = (g.u64(0..verts), g.u64(0..verts));
                if s == d {
                    continue;
                }
                let op = if g.bool(0.75) { EdgeOp::add(s, d) } else { EdgeOp::remove(s, d) };
                w.admit(&op, t);
                match op {
                    EdgeOp::AddEdge(..) => {
                        model.entry((s, d)).or_default().push(t + window);
                    }
                    _ => {
                        model.remove(&(s, d));
                    }
                }
                windowed.push(op);
                manual.push(op);
            }
            let expired = w.expire_due(t);
            let mut due: Vec<(u64, u64)> = Vec::new();
            model.retain(|&key, deadlines| {
                let had = !deadlines.is_empty();
                deadlines.retain(|&dl| dl > t);
                if had && deadlines.is_empty() {
                    due.push(key);
                    false
                } else {
                    !deadlines.is_empty()
                }
            });
            due.sort_unstable();
            assert_eq!(remove_pairs(&expired), due, "tick {t}: wrong expiry set");
            windowed.extend(expired);
            manual.extend(due.into_iter().map(|(s, d)| EdgeOp::remove(s, d)));
        }

        let (mut ga, _) = DynamicGraph::from_edges(Vec::<(u64, u64)>::new());
        let (mut gb, _) = DynamicGraph::from_edges(Vec::<(u64, u64)>::new());
        seq_apply(&mut ga, &windowed);
        seq_apply(&mut gb, &manual);
        assert_eq!(edge_set(&ga), edge_set(&gb), "windowed and manual graphs diverged");
    });
}
