//! Property-based tests (via the in-repo `vprop` framework) over the
//! coordinator's core invariants: graph/CSR consistency, hot-set
//! structure, summary-graph algebra, RBO axioms, and engine state.

use std::collections::HashMap;

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::policies::StalenessPolicy;
use veilgraph::coordinator::udf::Action;
use veilgraph::graph::csr::Csr;
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::snapshot::{SnapshotBuild, SnapshotCache};
use veilgraph::metrics::ranking::top_k_ids;
use veilgraph::metrics::rbo::rbo_ext;
use veilgraph::pagerank::power::{PageRank, PageRankConfig};
use veilgraph::pagerank::summarized::{merge_ranks, run_summarized};
use veilgraph::stream::buffer::UpdateBuffer;
use veilgraph::stream::event::EdgeOp;
use veilgraph::summary::bigvertex::SummaryGraph;
use veilgraph::summary::hot::{compute_hot_set, compute_hot_set_pooled, HotSet, HotSetInputs};
use veilgraph::summary::params::SummaryParams;
use veilgraph::summary::scratch::SummaryScratch;
use veilgraph::testing::vprop::{forall, Gen};
use veilgraph::util::threadpool::ThreadPool;

fn random_graph(g: &mut Gen, max_n: usize, max_m: usize) -> DynamicGraph {
    let n = g.usize(2..max_n);
    let m = g.usize(1..max_m);
    DynamicGraph::from_edges(g.edges(n, m)).0
}

fn random_params(g: &mut Gen) -> SummaryParams {
    SummaryParams::new(g.f64(0.0..0.5), g.usize(0..3) as u32, g.f64(0.001..1.0))
}

/// CSR snapshot always mirrors the dynamic graph exactly.
#[test]
fn prop_snapshot_consistency() {
    forall(60, 0xA1, |g| {
        let dg = random_graph(g, 60, 300);
        let csr = dg.snapshot();
        assert_eq!(csr.num_vertices(), dg.num_vertices());
        assert_eq!(csr.num_edges(), dg.num_edges());
        let total_out: u32 = csr.out_degrees().iter().sum();
        assert_eq!(total_out as usize, dg.num_edges());
        for v in 0..dg.num_vertices() as u32 {
            assert_eq!(csr.row(v).len(), dg.in_degree(v));
            for &s in csr.row(v) {
                assert!(dg.out_neighbors(s).contains(&v));
            }
        }
    });
}

/// The version-cached incremental snapshot pipeline is indistinguishable
/// from a fresh full rebuild after ANY interleaving of edge/vertex
/// adds and removes, at any shard count — and an unmutated graph is a
/// pure cache hit (the identical allocation comes back).
#[test]
fn prop_incremental_snapshot_matches_full_rebuild() {
    let pool = ThreadPool::new(4);
    forall(40, 0xC1, |g| {
        let mut dg = random_graph(g, 50, 200);
        let mut cache = SnapshotCache::new();
        for _round in 0..g.usize(1..6) {
            for _ in 0..g.usize(0..25) {
                let (u, v) = (g.u64(0..60), g.u64(0..60));
                match g.usize(0..10) {
                    0..=5 => {
                        let _ = dg.add_edge(u, v);
                    }
                    6..=7 => {
                        let _ = dg.remove_edge(u, v);
                    }
                    8 => {
                        dg.add_vertex(u);
                    }
                    _ => {
                        let _ = dg.remove_vertex(u);
                    }
                }
            }
            let fresh = dg.snapshot();
            let shards = g.usize(1..8);
            let (cached, _build) = cache.get(&dg, Some(&pool), shards);
            assert_eq!(*cached, fresh);
            let (again, build) = cache.get(&dg, Some(&pool), shards);
            assert_eq!(build, SnapshotBuild::CacheHit);
            assert!(std::sync::Arc::ptr_eq(&cached, &again));
        }
    });
}

/// Parallel snapshot construction == serial for k ∈ {1, 2, 4, 7} — on
/// random graphs, the empty graph and an all-dangling (edge-free) graph;
/// same guarantee for the parallel counting-sort `Csr::from_edges_pooled`.
#[test]
fn prop_parallel_snapshot_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(40, 0xC2, |g| {
        let dg = random_graph(g, 80, 400);
        let serial = dg.snapshot();
        let dense: Vec<(u32, u32)> = dg.edges().collect();
        let serial_ce = Csr::from_edges(dg.num_vertices(), &dense);
        for k in [1usize, 2, 4, 7] {
            assert_eq!(dg.snapshot_with(Some(&pool), k), serial, "k={k}");
            let par_ce = Csr::from_edges_pooled(dg.num_vertices(), &dense, Some(&pool), k);
            assert_eq!(par_ce, serial_ce, "k={k}");
        }
    });
    for n in [0usize, 7] {
        let mut dg = DynamicGraph::new();
        for v in 0..n as u64 {
            dg.add_vertex(v);
        }
        let serial = dg.snapshot();
        for k in [1usize, 2, 4, 7] {
            assert_eq!(dg.snapshot_with(Some(&pool), k), serial, "|V|={n} k={k}");
            assert_eq!(Csr::from_edges_pooled(n, &[], Some(&pool), k), serial, "|V|={n} k={k}");
        }
    }
}

/// Every mutating `DynamicGraph` method bumps the topology version (and
/// therefore invalidates `SnapshotCache`); failed and no-op calls leave
/// both untouched.
#[test]
fn prop_every_mutation_invalidates_cache() {
    fn assert_invalidated(cache: &mut SnapshotCache, dg: &DynamicGraph, what: &str) {
        let (got, build) = cache.get(dg, None, 1);
        assert_ne!(build, SnapshotBuild::CacheHit, "{what} must invalidate");
        assert_eq!(*got, dg.snapshot(), "{what} rebuild mismatch");
    }
    forall(60, 0xC3, |g| {
        let mut dg = random_graph(g, 30, 120);
        let mut cache = SnapshotCache::new();
        let _ = cache.get(&dg, None, 1);
        // ids ≥ 100 cannot exist yet (random_graph draws from 0..30)
        let (u, v) = (g.u64(100..150), g.u64(150..200));

        let v0 = dg.version();
        dg.add_vertex(u);
        assert!(dg.version() > v0, "add_vertex (new)");
        assert_invalidated(&mut cache, &dg, "add_vertex");

        let v1 = dg.version();
        dg.add_vertex(u); // no-op: id exists
        assert_eq!(dg.version(), v1);
        dg.add_edge(u, v).unwrap();
        assert!(dg.version() > v1, "add_edge");
        assert_invalidated(&mut cache, &dg, "add_edge");

        let v2 = dg.version();
        assert!(dg.add_edge(u, v).is_err()); // duplicate
        assert!(dg.remove_edge(v, u).is_err()); // unknown edge
        assert!(dg.remove_vertex(999).is_err()); // unknown vertex
        assert_eq!(dg.version(), v2, "failed ops must not bump");
        let (_, build) = cache.get(&dg, None, 1);
        assert_eq!(build, SnapshotBuild::CacheHit, "failed ops keep the cache");

        dg.remove_edge(u, v).unwrap();
        assert!(dg.version() > v2, "remove_edge");
        assert_invalidated(&mut cache, &dg, "remove_edge");

        let v3 = dg.version();
        dg.remove_vertex(u).unwrap();
        assert!(dg.version() > v3, "remove_vertex");
        assert_invalidated(&mut cache, &dg, "remove_vertex");
    });
}

/// Applying a buffer then inspecting degrees reproduces d_{t-1} exactly.
#[test]
fn prop_buffer_prev_degrees_are_faithful() {
    forall(60, 0xA2, |g| {
        let mut dg = random_graph(g, 40, 150);
        let before: HashMap<u64, usize> = dg
            .ids()
            .iter()
            .map(|&id| (id, dg.degree(dg.index(id).unwrap())))
            .collect();
        let mut buf = UpdateBuffer::new();
        for _ in 0..g.usize(1..20) {
            let (u, v) = (g.u64(0..60), g.u64(0..60));
            if u != v {
                buf.register(EdgeOp::add(u, v));
            }
        }
        let applied = buf.apply(&mut dg).unwrap();
        for (&id, &d_prev) in &applied.prev_degree {
            assert_eq!(before[&id], d_prev, "prev degree for {id}");
        }
        for id in &applied.new_vertices {
            assert!(!before.contains_key(id), "{id} claimed new but existed");
        }
    });
}

/// Hot-set structure: tiers are disjoint, bitmap matches lists, every
/// touched-and-past-threshold vertex is captured.
#[test]
fn prop_hot_set_structure() {
    forall(50, 0xA3, |g| {
        let mut dg = random_graph(g, 50, 200);
        let mut buf = UpdateBuffer::new();
        for _ in 0..g.usize(1..15) {
            let (u, v) = (g.u64(0..70), g.u64(0..70));
            if u != v {
                buf.register(EdgeOp::add(u, v));
            }
        }
        let applied = buf.apply(&mut dg).unwrap();
        let ranks: Vec<f64> = (0..dg.num_vertices()).map(|_| g.f64(0.0..2.0)).collect();
        let params = random_params(g);
        let hs = compute_hot_set(
            &HotSetInputs {
                graph: &dg,
                prev_degree: &applied.prev_degree,
                new_vertices: &applied.new_vertices,
                prev_ranks: &ranks,
            },
            &params,
        );
        // disjoint tiers
        let all = hs.all();
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), all.len(), "tiers overlap");
        // bitmap agrees
        for &v in &all {
            assert!(hs.contains(v));
        }
        assert_eq!(hs.hot.iter().filter(|&&b| b).count(), all.len());
        // every new vertex is in K_r
        for id in &applied.new_vertices {
            let idx = dg.index(*id).unwrap();
            assert!(hs.k_r.contains(&idx), "new vertex {id} missing from K_r");
        }
        // Eq. 2 soundness: every K_r vertex either is new or crossed r
        for &v in &hs.k_r {
            let id = dg.id(v);
            if let Some(&d_prev) = applied.prev_degree.get(&id) {
                let d_now = dg.degree(v);
                let crossed = if d_prev == 0 {
                    d_now > 0
                } else {
                    (d_now as f64 / d_prev as f64 - 1.0).abs() > params.r
                };
                assert!(crossed, "vertex {id} in K_r without crossing r");
            } else {
                assert!(applied.new_vertices.contains(&id));
            }
        }
    });
}

fn assert_hot_sets_equal(a: &HotSet, b: &HotSet, what: &str) {
    assert_eq!(a.k_r, b.k_r, "{what}: k_r");
    assert_eq!(a.k_n, b.k_n, "{what}: k_n");
    assert_eq!(a.k_delta, b.k_delta, "{what}: k_delta");
    assert_eq!(a.hot, b.hot, "{what}: bitmap");
}

/// Parallel hot-set selection == serial for shards ∈ {1, 2, 4, 7}:
/// identical tiers and bitmap on random update batches — plus the empty
/// graph, an all-hot graph, and an all-dangling (edge-free) graph.
#[test]
fn prop_parallel_hot_set_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(30, 0xD1, |g| {
        let mut scratch = SummaryScratch::new();
        let mut dg = random_graph(g, 60, 250);
        let mut buf = UpdateBuffer::new();
        for _ in 0..g.usize(1..25) {
            let (u, v) = (g.u64(0..80), g.u64(0..80));
            if u != v {
                buf.register(EdgeOp::add(u, v));
            }
        }
        let applied = buf.apply(&mut dg).unwrap();
        let ranks: Vec<f64> = (0..dg.num_vertices()).map(|_| g.f64(0.0..2.0)).collect();
        let params = random_params(g);
        let inputs = HotSetInputs {
            graph: &dg,
            prev_degree: &applied.prev_degree,
            new_vertices: &applied.new_vertices,
            prev_ranks: &ranks,
        };
        let serial = compute_hot_set(&inputs, &params);
        for shards in [1usize, 2, 4, 7] {
            let par = compute_hot_set_pooled(&inputs, &params, &mut scratch, Some(&pool), shards);
            assert_hot_sets_equal(&par, &serial, &format!("shards={shards}"));
            scratch.recycle_hot(par);
        }
    });
    // Edge cases the random corpus cannot hit: the empty graph, a graph
    // where EVERY vertex is hot, and an all-dangling (edge-free) graph.
    let mut scratch = SummaryScratch::new();
    let empty = DynamicGraph::new();
    let all_hot = DynamicGraph::from_edges((0..12u64).map(|i| (i, (i + 1) % 12))).0;
    let all_prev: HashMap<u64, usize> = (0..12u64).map(|id| (id, 0)).collect();
    let mut dangling = DynamicGraph::new();
    for v in 0..9u64 {
        dangling.add_vertex(v);
    }
    let dangling_new: Vec<u64> = (0..9).collect();
    let none_prev = HashMap::new();
    let no_new: Vec<u64> = Vec::new();
    let ranks = vec![0.5; 12];
    let cases: Vec<(&DynamicGraph, &HashMap<u64, usize>, &[u64], &str)> = vec![
        (&empty, &none_prev, no_new.as_slice(), "empty"),
        (&all_hot, &all_prev, no_new.as_slice(), "all-hot"),
        (&dangling, &none_prev, dangling_new.as_slice(), "all-dangling"),
    ];
    for (dg, prev, newv, what) in cases {
        let inputs = HotSetInputs {
            graph: dg,
            prev_degree: prev,
            new_vertices: newv,
            prev_ranks: &ranks,
        };
        let params = SummaryParams::new(0.1, 2, 0.1);
        let serial = compute_hot_set(&inputs, &params);
        if what == "all-hot" {
            assert_eq!(serial.len(), dg.num_vertices(), "every vertex must be hot");
        }
        for shards in [1usize, 2, 4, 7] {
            let par = compute_hot_set_pooled(&inputs, &params, &mut scratch, Some(&pool), shards);
            assert_hot_sets_equal(&par, &serial, &format!("{what} shards={shards}"));
            scratch.recycle_hot(par);
        }
    }
}

/// Parallel `SummaryGraph::build_pooled` == serial build bit-for-bit
/// (vertices, offsets, edges, `b`, `r0`, `b_s`) for shards ∈ {1, 2, 4,
/// 7} across hot densities 0 / partial / all, plus an all-dangling
/// graph.
#[test]
fn prop_parallel_summary_build_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(30, 0xD2, |g| {
        let mut scratch = SummaryScratch::new();
        let dg = random_graph(g, 60, 300);
        let n = dg.num_vertices();
        let ranks: Vec<f64> = (0..n).map(|_| g.f64(0.01..1.5)).collect();
        for density in [0.0f64, 0.4, 1.0] {
            let mut hot = vec![false; n];
            let mut k_r = Vec::new();
            for v in 0..n as u32 {
                if density == 1.0 || (density > 0.0 && g.bool(density)) {
                    hot[v as usize] = true;
                    k_r.push(v);
                }
            }
            let hs = HotSet { k_r, k_n: vec![], k_delta: vec![], hot };
            let serial = SummaryGraph::build(&dg, &hs, &ranks, 1.0);
            for shards in [1usize, 2, 4, 7] {
                let par = SummaryGraph::build_pooled(
                    &dg,
                    &hs,
                    &ranks,
                    1.0,
                    &mut scratch,
                    Some(&pool),
                    shards,
                );
                assert_eq!(par, serial, "density={density} shards={shards}");
            }
        }
    });
    // All-dangling graph: every hot row is edge-free, b stays zero.
    let mut scratch = SummaryScratch::new();
    let mut dg = DynamicGraph::new();
    for v in 0..9u64 {
        dg.add_vertex(v);
    }
    let n = dg.num_vertices();
    let hs = HotSet {
        k_r: (0..n as u32).collect(),
        k_n: vec![],
        k_delta: vec![],
        hot: vec![true; n],
    };
    let ranks = vec![0.3; n];
    let serial = SummaryGraph::build(&dg, &hs, &ranks, 1.0);
    assert_eq!(serial.num_edges(), 0);
    for shards in [1usize, 2, 4, 7] {
        let par =
            SummaryGraph::build_pooled(&dg, &hs, &ranks, 1.0, &mut scratch, Some(&pool), shards);
        assert_eq!(par, serial, "all-dangling shards={shards}");
    }
}

/// One scratch reused across an interleaved mutate/build sequence
/// produces exactly what fresh construction does — stale epoch stamps,
/// leaked BFS state or a dirty bitmap would all surface as a mismatch —
/// and the scratch never re-grows once sized for the largest graph seen.
#[test]
fn prop_scratch_reuse_matches_fresh() {
    let pool = ThreadPool::new(4);
    forall(20, 0xD3, |g| {
        let mut dg = random_graph(g, 50, 200);
        let mut scratch = SummaryScratch::new();
        for _round in 0..g.usize(2..6) {
            let mut buf = UpdateBuffer::new();
            for _ in 0..g.usize(1..15) {
                let (u, v) = (g.u64(0..60), g.u64(0..60));
                if u == v {
                    continue;
                }
                if g.bool(0.8) {
                    buf.register(EdgeOp::add(u, v));
                } else {
                    buf.register(EdgeOp::remove(u, v));
                }
            }
            let applied = buf.apply(&mut dg).unwrap();
            let ranks: Vec<f64> = (0..dg.num_vertices()).map(|_| g.f64(0.0..2.0)).collect();
            let params = random_params(g);
            let inputs = HotSetInputs {
                graph: &dg,
                prev_degree: &applied.prev_degree,
                new_vertices: &applied.new_vertices,
                prev_ranks: &ranks,
            };
            let shards = g.usize(1..8);
            let reused =
                compute_hot_set_pooled(&inputs, &params, &mut scratch, Some(&pool), shards);
            let fresh = compute_hot_set(&inputs, &params);
            assert_hot_sets_equal(&reused, &fresh, "reused scratch");
            let s_reused = SummaryGraph::build_pooled(
                &dg,
                &reused,
                &ranks,
                1.0,
                &mut scratch,
                Some(&pool),
                shards,
            );
            let s_fresh = SummaryGraph::build(&dg, &reused, &ranks, 1.0);
            assert_eq!(s_reused, s_fresh, "reused-scratch build");
            scratch.recycle_hot(reused);
        }
        // Steady state: one more pass over the (now unchanging) graph
        // must be pure reuse — the rounds above already sized every
        // buffer for the current |V|, so any growth here means the
        // scratch re-allocates O(|V|) state per query.
        let before = scratch.stats();
        let none = HashMap::new();
        let ranks: Vec<f64> = (0..dg.num_vertices()).map(|_| g.f64(0.0..2.0)).collect();
        let inputs = HotSetInputs {
            graph: &dg,
            prev_degree: &none,
            new_vertices: &[],
            prev_ranks: &ranks,
        };
        let params = random_params(g);
        let hs = compute_hot_set_pooled(&inputs, &params, &mut scratch, Some(&pool), 4);
        let summary = SummaryGraph::build_pooled(&dg, &hs, &ranks, 1.0, &mut scratch, None, 1);
        scratch.recycle_hot(hs);
        let after = scratch.stats();
        assert_eq!(after.grown, before.grown, "steady-state pass must not grow the scratch");
        assert_eq!(after.reused, before.reused + 3, "all three acquisitions must reuse");
        assert_eq!(summary.full_n, dg.num_vertices());
    });
}

/// Summary-graph algebra: boundary sums match Eq. 1, edge weights are
/// 1/d_out, warm starts echo prev ranks.
#[test]
fn prop_summary_graph_algebra() {
    forall(50, 0xA4, |g| {
        let dg = random_graph(g, 40, 200);
        let n = dg.num_vertices();
        let ranks: Vec<f64> = (0..n).map(|_| g.f64(0.01..1.5)).collect();
        // random hot subset
        let mut hot = vec![false; n];
        let mut k_r = Vec::new();
        for v in 0..n as u32 {
            if g.bool(0.4) {
                hot[v as usize] = true;
                k_r.push(v);
            }
        }
        let hs = HotSet { k_r, k_n: vec![], k_delta: vec![], hot };
        let s = SummaryGraph::build(&dg, &hs, &ranks, 1.0);
        // Eq. 1: b_s equals the sum over b
        let b_total: f64 = s.b.iter().sum();
        assert!((b_total - s.b_s).abs() < 1e-9);
        // recompute boundary contributions independently
        let mut expect_b_s = 0.0;
        for (li, &z) in s.vertices.iter().enumerate() {
            let mut expect = 0.0;
            for &w in dg.in_neighbors(z) {
                if !hs.contains(w) {
                    expect += ranks[w as usize] / dg.out_degree(w) as f64;
                }
            }
            assert!((s.b[li] - expect).abs() < 1e-9, "b_z mismatch at local {li}");
            expect_b_s += expect;
            assert!((s.r0[li] - ranks[z as usize]).abs() < 1e-12);
        }
        assert!((expect_b_s - s.b_s).abs() < 1e-9);
        // weights are exactly 1/d_out of the full graph
        for z in 0..s.num_vertices() {
            for &(u_local, w) in s.row(z) {
                let u_dense = s.vertices[u_local as usize];
                let expect = 1.0 / dg.out_degree(u_dense) as f32;
                assert_eq!(w, expect);
            }
        }
    });
}

/// Fixed-point preservation (Langville–Meyer): summarizing at the exact
/// fixed point returns the fixed point, for ANY hot set.
#[test]
fn prop_summarized_preserves_fixed_point() {
    let cfg = PageRankConfig { epsilon: 1e-13, max_iters: 300, ..Default::default() };
    forall(40, 0xA5, |g| {
        let dg = random_graph(g, 30, 120);
        let n = dg.num_vertices();
        let exact = PageRank::new(cfg).run(&dg.snapshot());
        let mut hot = vec![false; n];
        let mut k_r = Vec::new();
        for v in 0..n as u32 {
            if g.bool(0.5) {
                hot[v as usize] = true;
                k_r.push(v);
            }
        }
        let hs = HotSet { k_r, k_n: vec![], k_delta: vec![], hot };
        let s = SummaryGraph::build(&dg, &hs, &exact.ranks, cfg.init_rank(n));
        let sr = run_summarized(&s, &cfg);
        for (li, &v) in s.vertices.iter().enumerate() {
            assert!(
                (sr.ranks[li] - exact.ranks[v as usize]).abs() < 1e-6,
                "fixed point drifted at {v}: {} vs {}",
                sr.ranks[li],
                exact.ranks[v as usize]
            );
        }
        // merge keeps non-hot untouched
        let merged = merge_ranks(&exact.ranks, &s, &sr.ranks, cfg.init_rank(n));
        for v in 0..n {
            if !hs.contains(v as u32) {
                assert_eq!(merged[v], exact.ranks[v]);
            }
        }
    });
}

/// RBO axioms on random rankings: bounds, symmetry, self-similarity.
#[test]
fn prop_rbo_axioms() {
    forall(80, 0xA6, |g| {
        let n = g.usize(1..100);
        let mut a: Vec<u64> = (0..n as u64).collect();
        let mut b = a.clone();
        g.rng().shuffle(&mut a);
        g.rng().shuffle(&mut b);
        let p = g.f64(0.5..0.999);
        let v = rbo_ext(&a, &b, p);
        assert!((0.0..=1.0).contains(&v), "rbo {v} out of bounds");
        assert!((rbo_ext(&a, &b, p) - rbo_ext(&b, &a, p)).abs() < 1e-12, "asymmetric");
        assert!((rbo_ext(&a, &a, p) - 1.0).abs() < 1e-9, "self-rbo != 1");
        // truncation consistency: a prefix of itself scores >= any permutation
        let k = g.usize(1..n + 1);
        let prefix = &a[..k];
        assert!(rbo_ext(prefix, &a, p) >= rbo_ext(&b, &a, p) - 1e-9);
    });
}

/// top_k_ids is exactly the head of a stable full sort.
#[test]
fn prop_topk_matches_sort() {
    forall(60, 0xA7, |g| {
        let n = g.usize(1..200);
        let ids: Vec<u64> = (0..n as u64).collect();
        let scores: Vec<f64> = (0..n).map(|_| g.f64(0.0..1.0)).collect();
        let k = g.usize(0..n + 1);
        let got = top_k_ids(&ids, &scores, k);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&x, &y| scores[y].partial_cmp(&scores[x]).unwrap().then(x.cmp(&y)));
        let want: Vec<u64> = order[..k].iter().map(|&i| ids[i]).collect();
        assert_eq!(got, want);
    });
}

/// Parallel executors are a pure scheduling change: for every shard count
/// the sharded run must match the serial run within 1e-12 L∞ — on random
/// graphs (which include dangling and isolated vertices by construction)
/// and on both PageRank variants.
#[test]
fn prop_parallel_pagerank_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(25, 0xB1, |g| {
        let dg = random_graph(g, 80, 400);
        let csr = dg.snapshot();
        let mut cfg = PageRankConfig {
            epsilon: 0.0, // fixed iteration count ⇒ comparison is exact
            max_iters: g.usize(1..40),
            normalized: g.bool(0.5),
            dangling_redistribution: g.bool(0.3),
            ..Default::default()
        };
        let serial = PageRank::new(cfg).run(&csr);
        for shards in [1usize, 2, 4, 7] {
            cfg.parallelism = shards;
            let par = PageRank::new(cfg).run_parallel(&csr, &pool);
            assert_eq!(par.iterations, serial.iterations, "shards={shards}");
            let linf = serial
                .ranks
                .iter()
                .zip(&par.ranks)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(linf < 1e-12, "shards={shards}: L∞ {linf}");
        }
    });
    // Edge cases the random corpus cannot hit: the empty graph, and a
    // graph that is ALL dangling vertices (no edges at all).
    for (n, edges) in [(0usize, vec![]), (9usize, vec![])] {
        let csr = veilgraph::graph::csr::Csr::from_edges(n, &edges);
        let mut cfg = PageRankConfig { epsilon: 0.0, max_iters: 5, ..Default::default() };
        let serial = PageRank::new(cfg).run(&csr);
        for shards in [1usize, 2, 4, 7] {
            cfg.parallelism = shards;
            let par = PageRank::new(cfg).run_parallel(&csr, &pool);
            assert_eq!(par.ranks, serial.ranks, "|V|={n} shards={shards}");
        }
    }
}

/// Same guarantee for the summarized executor: sharded runs over random
/// summaries (random graph, random hot subset, random warm start) match
/// the serial sparse executor within 1e-12 L∞.
#[test]
fn prop_parallel_summarized_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(25, 0xB2, |g| {
        let dg = random_graph(g, 60, 250);
        let n = dg.num_vertices();
        let ranks: Vec<f64> = (0..n).map(|_| g.f64(0.01..1.5)).collect();
        let mut hot = vec![false; n];
        let mut k_r = Vec::new();
        for v in 0..n as u32 {
            if g.bool(0.4) {
                hot[v as usize] = true;
                k_r.push(v);
            }
        }
        let hs = HotSet { k_r, k_n: vec![], k_delta: vec![], hot };
        let s = SummaryGraph::build(&dg, &hs, &ranks, 1.0);
        let mut cfg =
            PageRankConfig { epsilon: 0.0, max_iters: g.usize(1..30), ..Default::default() };
        let serial = run_summarized(&s, &cfg);
        for shards in [1usize, 2, 4, 7] {
            cfg.parallelism = shards;
            let par = veilgraph::pagerank::summarized::run_summarized_parallel(&s, &cfg, &pool);
            assert_eq!(par.iterations, serial.iterations);
            let linf = serial
                .ranks
                .iter()
                .zip(&par.ranks)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(linf < 1e-12, "shards={shards}: L∞ {linf}");
        }
    });
}

/// Csr::shards always yields a valid partition whose per-shard edge
/// weight respects the greedy balance bound.
#[test]
fn prop_shards_partition_and_balance() {
    forall(60, 0xB3, |g| {
        let dg = random_graph(g, 100, 500);
        let csr = dg.snapshot();
        let n = csr.num_vertices();
        let k = g.usize(1..12);
        let cuts = csr.shards(k);
        assert_eq!(cuts[0], 0);
        assert_eq!(*cuts.last().unwrap(), n);
        assert!(cuts.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(cuts.len(), k.min(n.max(1)) + 1);
        let weight = |lo: usize, hi: usize| -> u64 {
            (lo..hi).map(|v| csr.in_degree(v as u32) as u64 + 1).sum()
        };
        let total = weight(0, n);
        let keff = (cuts.len() - 1) as u64;
        let max_row = (0..n).map(|v| csr.in_degree(v as u32) as u64 + 1).max().unwrap_or(1);
        for w in cuts.windows(2) {
            assert!(weight(w[0], w[1]) <= total.div_ceil(keff) + max_row + keff);
        }
    });
}

/// Engine invariant: ranks vector always matches graph size, all finite,
/// regardless of the op/query interleaving.
#[test]
fn prop_engine_rank_vector_integrity() {
    forall(25, 0xA8, |g| {
        let base = g.edges(30, 80);
        let mut engine = EngineBuilder::new()
            .params(random_params(g))
            .build_from_edges(base)
            .unwrap();
        for _ in 0..g.usize(1..8) {
            for _ in 0..g.usize(0..10) {
                let (u, v) = (g.u64(0..50), g.u64(0..50));
                if u == v {
                    continue;
                }
                if g.bool(0.85) {
                    engine.ingest(EdgeOp::add(u, v));
                } else {
                    engine.ingest(EdgeOp::remove(u, v));
                }
            }
            let r = engine.query().unwrap();
            assert_eq!(r.ranks().len(), engine.graph().num_vertices());
            assert_eq!(r.ids().len(), r.ranks().len());
            assert!(r.ranks().iter().all(|&x| x.is_finite() && x >= 0.0));
        }
    });
}

/// Read/write-split invariant: after every query, the published snapshot
/// IS the engine's current ranking — same ranks, same ids, same graph
/// version — and its precomputed top-K index matches a fresh selection
/// over that snapshot's own data.
#[test]
fn prop_published_snapshot_matches_engine_state() {
    forall(20, 0xA9, |g| {
        let base = g.edges(25, 70);
        let cap = g.usize(1..20);
        let mut engine = EngineBuilder::new()
            .params(random_params(g))
            .published_top_k(cap)
            .build_from_edges(base)
            .unwrap();
        let mut last_version = engine.latest_snapshot().version;
        for _ in 0..g.usize(1..6) {
            for _ in 0..g.usize(0..12) {
                let (u, v) = (g.u64(0..40), g.u64(0..40));
                if u == v {
                    continue;
                }
                match g.usize(0..10) {
                    0 => engine.ingest(EdgeOp::remove(u, v)),
                    1 => engine.ingest(EdgeOp::AddVertex(u)),
                    _ => engine.ingest(EdgeOp::add(u, v)),
                }
            }
            let r = engine.query().unwrap();
            let snap = engine.latest_snapshot();
            assert!(std::sync::Arc::ptr_eq(&r.snapshot, &snap), "query returns the published Arc");
            assert_eq!(snap.ranks, engine.ranks(), "published ranks == engine ranks");
            assert_eq!(snap.ids, engine.graph().ids(), "published ids == graph ids");
            assert_eq!(snap.graph_version, engine.graph().version());
            assert!(snap.version >= last_version, "versions never move backwards");
            last_version = snap.version;
            let k = snap.top_k_cap();
            assert_eq!(
                snap.top_ids(k),
                top_k_ids(&snap.ids, &snap.ranks, k),
                "precomputed top-K index == fresh deterministic selection"
            );
        }
    });
}

/// The batched write pipeline end to end: coalesced-batch apply is
/// behaviorally identical to op-by-op apply — final CSR (bit-for-bit,
/// including adjacency append order), dense-index assignment, edge count
/// and incremental-snapshot stamps — under arbitrary add/remove
/// interleavings including duplicate adds, cancelling pairs, vertex
/// inserts and vertex removals.
#[test]
fn prop_batched_apply_matches_op_by_op() {
    forall(60, 0xB5, |g| {
        let base = random_graph(g, 40, 150);
        let mut seq = base.clone();
        let mut bat = base.clone();
        for round in 0..g.usize(1..4) {
            // A raw sequence biased toward collisions, so duplicates and
            // cancelling pairs actually occur.
            let mut ops: Vec<EdgeOp> = Vec::new();
            for _ in 0..g.usize(0..40) {
                let (u, v) = (g.u64(0..50), g.u64(0..50));
                match g.usize(0..12) {
                    0..=5 => ops.push(EdgeOp::add(u, v)),
                    6..=8 => ops.push(EdgeOp::remove(u, v)),
                    9 => {
                        ops.push(EdgeOp::add(u, v));
                        ops.push(EdgeOp::remove(u, v)); // cancelling pair
                    }
                    10 => ops.push(EdgeOp::AddVertex(u)),
                    _ => ops.push(EdgeOp::RemoveVertex(u)),
                }
            }
            // Oracle: the shared sequential reference path.
            veilgraph::testing::oracle::seq_apply(&mut seq, &ops);
            // Batch path: coalesce, then grouped apply.
            let mut bbuf = UpdateBuffer::new();
            bbuf.register_batch(ops.iter().copied());
            let prev = bat.snapshot();
            let pv = bat.version();
            let batch = bbuf.take_batch(&bat);
            // No effective-vs-raw inequality: coalescing drops no-ops but
            // also synthesizes AddVertex ops for new edge endpoints, so a
            // single raw add can become up to three effective ops.
            let res = bat.apply_batch(batch.ops(), None, 1);
            assert!(!res.fallback, "coalesced batches are conflict-free");
            assert_eq!(res.skipped, 0, "coalescing drops every no-op up front");
            // Behavioral identity with the sequential path.
            assert_eq!(bat.ids(), seq.ids(), "dense index assignment (round {round})");
            assert_eq!(bat.num_edges(), seq.num_edges(), "round {round}");
            assert_eq!(bat.snapshot(), seq.snapshot(), "bit-identical CSR (round {round})");
            // Version semantics: an all-no-op batch must not invalidate
            // snapshot caches; effective batches must.
            if res.applied == 0 {
                assert_eq!(bat.version(), pv, "no-op batch bumped the version");
            } else {
                assert!(bat.version() > pv, "effective batch must bump the version");
            }
            // The single stamp pass keeps incremental rebuilds exact.
            assert_eq!(bat.snapshot_from(&prev, pv, None, 1), bat.snapshot(), "round {round}");
        }
    });
}

/// `apply_batch` sharded over a pool == serial `apply_batch`, bit for
/// bit, for shard counts {2, 4, 7} on batches large enough to cross the
/// parallel-dispatch threshold.
#[test]
fn prop_batched_apply_parallel_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(12, 0xB6, |g| {
        let base = random_graph(g, 60, 400);
        let mut ops: Vec<EdgeOp> = Vec::new();
        for _ in 0..1_200 {
            let (u, v) = (g.u64(0..300), g.u64(0..300));
            ops.push(if g.bool(0.8) { EdgeOp::add(u, v) } else { EdgeOp::remove(u, v) });
        }
        let mut buf = UpdateBuffer::new();
        buf.register_batch(ops.iter().copied());
        let batch = buf.take_batch(&base);
        let mut serial = base.clone();
        let rs = serial.apply_batch(batch.ops(), None, 1);
        for shards in [2usize, 4, 7] {
            let mut par = base.clone();
            let rp = par.apply_batch(batch.ops(), Some(&pool), shards);
            assert_eq!(rp, rs, "shards={shards}");
            assert_eq!(par.ids(), serial.ids(), "shards={shards}");
            assert_eq!(par.version(), serial.version(), "shards={shards}");
            assert_eq!(par.snapshot(), serial.snapshot(), "shards={shards}");
        }
    });
}

/// `StalenessPolicy` escalation is monotone: growing any staleness
/// signal (accumulated effective updates, snapshot age in queries, age
/// in seconds) never de-escalates the chosen action.
#[test]
fn prop_staleness_policy_escalation_is_monotone() {
    fn severity(a: Action) -> u8 {
        match a {
            Action::RepeatLast => 0,
            Action::ComputeApproximate => 1,
            Action::ComputeExact => 2,
        }
    }
    forall(200, 0xB7, |g| {
        let au = g.u64(1..50);
        let aq = g.u64(1..50);
        let asecs = g.f64(0.1..20.0);
        let p = StalenessPolicy::new(
            au,
            au + g.u64(0..100),
            aq,
            aq + g.u64(0..100),
            asecs,
            asecs + g.f64(0.0..100.0),
        );
        let updates = g.u64(0..120);
        let queries = g.u64(0..120);
        let secs = g.f64(0.0..60.0);
        let base = p.decide(updates, queries, secs);
        for (du, dq, ds) in [(1, 0, 0.0), (0, 1, 0.0), (0, 0, 1.5), (9, 4, 7.0)] {
            let grown = p.decide(updates + du, queries + dq, secs + ds);
            assert!(
                severity(grown) >= severity(base),
                "({updates},{queries},{secs:.2}) -> {base:?} but +({du},{dq},{ds:.2}) -> {grown:?}"
            );
        }
        // Ceiling behavior: arbitrarily stale always resolves to exact.
        assert_eq!(p.decide(u64::MAX, u64::MAX, f64::MAX), Action::ComputeExact);
    });
}
