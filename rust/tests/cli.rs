//! Black-box tests of the `veilgraph` binary (the leader entrypoint).

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_veilgraph"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = bin().args(args).output().expect("binary runs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_all_subcommands() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    for cmd in ["serve", "generate", "experiment", "figures", "info"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn unknown_command_fails_with_usage() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("frobnicate"));
}

#[test]
fn table1_prints_all_seven_datasets() {
    let (ok, stdout, _) = run(&["figures", "--table1", "--scale", "0.02"]);
    assert!(ok, "{stdout}");
    for ds in [
        "cnr-2000", "eu-2005", "Cit-HepPh", "enron", "dblp-2010", "amazon-2008", "Facebook-ego",
    ] {
        assert!(stdout.contains(ds), "table1 missing {ds}");
    }
}

#[test]
fn generate_roundtrips_through_a_file() {
    let path = std::env::temp_dir().join(format!("vg-cli-gen-{}.tsv", std::process::id()));
    let (ok, stdout, stderr) = run(&[
        "generate",
        "--dataset",
        "social-enron",
        "--scale",
        "0.02",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let edges = veilgraph::graph::io::load_edges(&path).unwrap();
    assert!(edges.len() > 100, "generated {} edges", edges.len());
    std::fs::remove_file(&path).ok();
}

#[test]
fn generate_rejects_unknown_dataset() {
    let (ok, _, stderr) = run(&["generate", "--dataset", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("nope"));
}

#[test]
fn experiment_writes_figure_csvs() {
    let out = std::env::temp_dir().join(format!("vg-cli-exp-{}", std::process::id()));
    let (ok, stdout, stderr) = run(&[
        "experiment",
        "--dataset",
        "social-enron",
        "--scale",
        "0.03",
        "--queries",
        "5",
        "--workers",
        "4",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("best-speedup"));
    let files: Vec<String> = std::fs::read_dir(&out)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
        .collect();
    assert!(files.iter().any(|f| f.contains("rbo")), "{files:?}");
    assert!(files.iter().any(|f| f.contains("speedup")), "{files:?}");
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn figures_requires_a_selection() {
    let (ok, _, stderr) = run(&["figures"]);
    assert!(!ok);
    assert!(stderr.contains("--fig") || stderr.contains("--all"));
}

/// Full protocol run against the real binary: `serve` on an ephemeral
/// port with the staleness/overflow/worker flags set, two clients
/// connected at once, vertex ops (`add_vertex` / `remove_vertex`), the
/// `top` fast path, `rank`, `stats` (reflecting the parsed policy), a
/// typed v1 error, and a clean shutdown.
#[test]
fn serve_speaks_the_line_protocol_with_concurrent_clients() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::process::Stdio;
    use veilgraph::util::json::Json;

    let mut child = bin()
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--no-xla",
            "--queue",
            "1024",
            "--overflow",
            "reject",
            "--workers",
            "2",
            "--policy",
            "repeatlast:300:50,approx:600:500",
        ])
        .env("VEILGRAPH_LOG", "info")
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve");
    // The listening line goes to stderr via the logger.
    let stderr = child.stderr.take().unwrap();
    let mut err_lines = BufReader::new(stderr).lines();
    let addr = loop {
        let line = err_lines.next().expect("serve exited before listening").unwrap();
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.trim().to_string();
        }
    };

    let send = |c: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str| -> Json {
        c.write_all(req.as_bytes()).unwrap();
        c.write_all(b"\n").unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
    };
    let mut c1 = TcpStream::connect(&addr).unwrap();
    let mut r1 = BufReader::new(c1.try_clone().unwrap());
    let mut c2 = TcpStream::connect(&addr).unwrap(); // simultaneous client
    let mut r2 = BufReader::new(c2.try_clone().unwrap());

    // Build a tiny graph over the wire: vertex ops + edges.
    let resp = send(&mut c1, &mut r1, r#"{"op":"add_vertex","id":50}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    for req in [
        r#"{"op":"add","src":1,"dst":2}"#,
        r#"{"op":"add","src":2,"dst":3}"#,
        r#"{"op":"add","src":3,"dst":1}"#,
        r#"{"op":"add","src":50,"dst":1}"#,
    ] {
        assert_eq!(send(&mut c1, &mut r1, req).get("ok").unwrap().as_bool(), Some(true));
    }
    let resp = send(&mut c1, &mut r1, r#"{"op":"remove_vertex","id":50}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    let resp = send(&mut c1, &mut r1, r#"{"op":"query","top":3}"#);
    assert_eq!(resp.get("v").unwrap().as_u64(), Some(1), "responses carry the protocol version");
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 3);

    // Client 2 reads off the published snapshot while client 1 is live.
    let resp = send(&mut c2, &mut r2, r#"{"op":"top","k":2}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 2);
    let resp = send(&mut c2, &mut r2, r#"{"op":"rank","id":1}"#);
    assert!(resp.get("rank").unwrap().as_f64().is_some(), "vertex 1 is ranked");
    let resp = send(&mut c2, &mut r2, r#"{"op":"rank","id":999}"#);
    assert_eq!(resp.get("rank"), Some(&Json::Null), "unknown vertex has no rank");
    let resp = send(&mut c2, &mut r2, r#"{"op":"stats"}"#);
    assert!(resp.get("stats").unwrap().get("serving").is_some());
    let server = resp.get("stats").unwrap().get("server").unwrap();
    assert_eq!(server.get("protocol_version").unwrap().as_u64(), Some(1));
    assert_eq!(server.get("workers").unwrap().as_u64(), Some(2), "--workers reaches the loop");
    let policy = server.get("policy").unwrap();
    assert_eq!(policy.get("approx_after_updates").unwrap().as_u64(), Some(50));
    assert_eq!(policy.get("exact_after_updates").unwrap().as_u64(), Some(500));

    // Unknown ops answer a typed v1 error and leave the connection open.
    let resp = send(&mut c2, &mut r2, r#"{"op":"nope"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(
        resp.get("error").unwrap().get("code").unwrap().as_str(),
        Some("bad_op"),
        "errors carry stable codes"
    );

    let resp = send(&mut c2, &mut r2, r#"{"op":"shutdown"}"#);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    let status = child.wait().expect("serve exits after shutdown");
    assert!(status.success(), "serve exit status {status:?}");
}

#[test]
fn info_reports_artifacts_when_present() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").is_file() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let (ok, stdout, _) = run(&["info", "--artifacts", artifacts.to_str().unwrap()]);
    assert!(ok);
    assert!(stdout.contains("platform: cpu"));
    assert!(stdout.contains("pagerank_run_c128"));
}
