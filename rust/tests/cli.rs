//! Black-box tests of the `veilgraph` binary (the leader entrypoint).

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_veilgraph"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = bin().args(args).output().expect("binary runs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_all_subcommands() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    for cmd in ["serve", "generate", "experiment", "figures", "info"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn unknown_command_fails_with_usage() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("frobnicate"));
}

#[test]
fn table1_prints_all_seven_datasets() {
    let (ok, stdout, _) = run(&["figures", "--table1", "--scale", "0.02"]);
    assert!(ok, "{stdout}");
    for ds in [
        "cnr-2000", "eu-2005", "Cit-HepPh", "enron", "dblp-2010", "amazon-2008", "Facebook-ego",
    ] {
        assert!(stdout.contains(ds), "table1 missing {ds}");
    }
}

#[test]
fn generate_roundtrips_through_a_file() {
    let path = std::env::temp_dir().join(format!("vg-cli-gen-{}.tsv", std::process::id()));
    let (ok, stdout, stderr) = run(&[
        "generate",
        "--dataset",
        "social-enron",
        "--scale",
        "0.02",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let edges = veilgraph::graph::io::load_edges(&path).unwrap();
    assert!(edges.len() > 100, "generated {} edges", edges.len());
    std::fs::remove_file(&path).ok();
}

#[test]
fn generate_rejects_unknown_dataset() {
    let (ok, _, stderr) = run(&["generate", "--dataset", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("nope"));
}

#[test]
fn experiment_writes_figure_csvs() {
    let out = std::env::temp_dir().join(format!("vg-cli-exp-{}", std::process::id()));
    let (ok, stdout, stderr) = run(&[
        "experiment",
        "--dataset",
        "social-enron",
        "--scale",
        "0.03",
        "--queries",
        "5",
        "--workers",
        "4",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("best-speedup"));
    let files: Vec<String> = std::fs::read_dir(&out)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
        .collect();
    assert!(files.iter().any(|f| f.contains("rbo")), "{files:?}");
    assert!(files.iter().any(|f| f.contains("speedup")), "{files:?}");
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn figures_requires_a_selection() {
    let (ok, _, stderr) = run(&["figures"]);
    assert!(!ok);
    assert!(stderr.contains("--fig") || stderr.contains("--all"));
}

#[test]
fn info_reports_artifacts_when_present() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").is_file() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let (ok, stdout, _) = run(&["info", "--artifacts", artifacts.to_str().unwrap()]);
    assert!(ok);
    assert!(stdout.contains("platform: cpu"));
    assert!(stdout.contains("pagerank_run_c128"));
}
