//! Read/write-split serving: concurrency acceptance tests.
//!
//! * A blocked writer (recompute in progress) must never block snapshot
//!   readers.
//! * Readers hammering the snapshot slot while the writer publishes must
//!   never observe a torn snapshot (version / ids / ranks / top-K index
//!   mutually inconsistent).
//! * The readiness-loop TCP front end must serve simultaneous clients,
//!   enforce its connection cap and read rate limit with typed v1 error
//!   codes, and hold a large mostly-idle swarm on a small fixed worker
//!   set.
//! * Under queue pressure the wire path degrades (structured `overload`
//!   errors carrying a stale-but-valid snapshot answer) instead of
//!   queueing unboundedly; a recompute pinned mid-flight blocks neither
//!   readers nor writers.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::server::{handle_request, serve, ServeOptions, ServerHandle};
use veilgraph::coordinator::udf::{Action, QueryContext, UdfSuite};
use veilgraph::metrics::ranking::top_k_ids;
use veilgraph::stream::backpressure::OverflowPolicy;
use veilgraph::stream::event::EdgeOp;
use veilgraph::util::json::Json;

fn ring(n: u64) -> Vec<(u64, u64)> {
    (0..n).map(|i| (i, (i + 1) % n)).collect()
}

fn err_code(resp: &Json) -> &str {
    resp.get("error")
        .and_then(|e| e.get("code"))
        .and_then(Json::as_str)
        .unwrap_or_else(|| panic!("no error.code in {resp:?}"))
}

/// A UDF whose `on_query` parks until released — a deterministic stand-in
/// for an arbitrarily slow recompute holding the engine thread.
struct GatedSuite {
    entered: Arc<AtomicBool>,
    release: Arc<AtomicBool>,
}

impl UdfSuite for GatedSuite {
    fn on_query(&mut self, _ctx: &QueryContext) -> Action {
        self.entered.store(true, Ordering::SeqCst);
        while !self.release.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        Action::ComputeApproximate
    }
}

/// Acceptance: read-only top-k requests are served from the published
/// snapshot while the writer is provably stuck inside a query — no
/// timing assumptions, the writer is gated on an atomic the test flips.
#[test]
fn blocked_writer_does_not_block_snapshot_readers() {
    let entered = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let engine = EngineBuilder::new()
        .udf(Box::new(GatedSuite {
            entered: Arc::clone(&entered),
            release: Arc::clone(&release),
        }))
        .build_from_edges(ring(30))
        .unwrap();
    let h = Arc::new(ServerHandle::spawn(engine, 64, OverflowPolicy::Block));
    let reader = h.reader();
    let baseline = reader.latest();
    assert_eq!(baseline.version, 1);

    // Writer: one query that will park inside on_query.
    h.ingest(EdgeOp::add(0, 15)).unwrap();
    let writer_done = Arc::new(AtomicBool::new(false));
    let writer = {
        let h2 = Arc::clone(&h);
        let done = Arc::clone(&writer_done);
        std::thread::spawn(move || {
            let r = h2.query().unwrap();
            done.store(true, Ordering::SeqCst);
            r
        })
    };
    while !entered.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(1));
    }

    // The writer is inside the engine. Reads must all complete now.
    for _ in 0..500 {
        let top = reader.top(10);
        assert_eq!(top.len(), 10);
        assert_eq!(reader.latest().version, 1, "nothing published mid-query");
        assert!(reader.rank(0).is_some());
    }
    let _ = reader.stats_json();
    assert!(
        !writer_done.load(Ordering::SeqCst),
        "writer must still be blocked after 500 reads — reads bypassed the queue"
    );

    release.store(true, Ordering::SeqCst);
    let r = writer.join().unwrap();
    assert_eq!(r.snapshot.version, 2, "released writer publishes the recompute");
    assert_eq!(reader.latest().version, 2);
    match Arc::try_unwrap(h) {
        Ok(h) => h.shutdown(),
        Err(_) => panic!("handle clones outlived the test"),
    }
}

/// Readers racing a continuously publishing writer never observe a torn
/// snapshot: every observed snapshot is internally consistent (lengths,
/// top-K index vs a fresh selection over its own data, id lookups), and
/// versions are monotone per reader.
#[test]
fn readers_never_observe_a_torn_snapshot() {
    let engine = EngineBuilder::new()
        .published_top_k(16)
        .build_from_edges(ring(40))
        .unwrap();
    let h = Arc::new(ServerHandle::spawn(engine, 4096, OverflowPolicy::Block));
    let done = Arc::new(AtomicBool::new(false));

    let mut readers = Vec::new();
    for _ in 0..4 {
        let reader = h.reader();
        let done2 = Arc::clone(&done);
        readers.push(std::thread::spawn(move || {
            let mut last_version = 0u64;
            let mut observed = 0u64;
            while !done2.load(Ordering::SeqCst) {
                let s = reader.latest();
                assert_eq!(s.ids.len(), s.ranks.len(), "ids and ranks travel together");
                assert!(s.version >= last_version, "version went backwards");
                last_version = s.version;
                let k = s.top_k_cap();
                assert_eq!(
                    s.top_ids(k),
                    top_k_ids(&s.ids, &s.ranks, k),
                    "top-K index inconsistent with its own ids/ranks at v{}",
                    s.version
                );
                for (v, score) in s.top(4) {
                    assert_eq!(s.rank_of(v), Some(score), "rank_of disagrees with top");
                }
                observed += 1;
            }
            observed
        }));
    }

    // Writer: 30 rounds of mutate + query (each publishes a new version).
    for round in 0..30u64 {
        for i in 0..8u64 {
            h.ingest(EdgeOp::add(100 + round * 8 + i, (i * 7 + round) % 40)).unwrap();
        }
        let _ = h.query().unwrap();
    }
    done.store(true, Ordering::SeqCst);
    for r in readers {
        assert!(r.join().unwrap() > 0, "reader made progress");
    }
    assert_eq!(h.reader().latest().version, 31, "30 mutated queries after the initial publish");
    match Arc::try_unwrap(h) {
        Ok(h) => h.shutdown(),
        Err(_) => panic!("handle clones outlived the test"),
    }
}

/// Queue pressure degrades instead of queueing: with the engine thread
/// provably parked and a tiny reject-on-full queue saturated, wire writes
/// answer a structured `overload` error, wire queries answer `overload`
/// carrying the stale-but-valid published snapshot, and the queue depth
/// stays bounded at its capacity.
#[test]
fn overload_degrades_with_code_and_stale_snapshot() {
    let entered = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let engine = EngineBuilder::new()
        .udf(Box::new(GatedSuite {
            entered: Arc::clone(&entered),
            release: Arc::clone(&release),
        }))
        .build_from_edges(ring(12))
        .unwrap();
    let h = Arc::new(ServerHandle::spawn(engine, 2, OverflowPolicy::Reject));
    let v0 = h.reader().latest().version;

    // Park the engine thread inside a sync query.
    let writer = {
        let h2 = Arc::clone(&h);
        std::thread::spawn(move || h2.query())
    };
    while !entered.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(1));
    }

    // Saturate the 2-slot queue behind the parked engine.
    h.try_ingest(EdgeOp::add(0, 5)).unwrap();
    h.try_ingest(EdgeOp::add(1, 6)).unwrap();

    // A wire write now degrades to a typed error, not a blocked worker.
    let (resp, _) = handle_request(&h, r#"{"op":"add","src":2,"dst":7}"#);
    assert_eq!(resp.get("v").unwrap().as_u64(), Some(1));
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(err_code(&resp), "overload");

    // A wire query degrades to the published snapshot instead of
    // queueing: flagged overload, but the answer is still a valid (stale)
    // ranking.
    let (q, _) = handle_request(&h, r#"{"op":"query","top":4}"#);
    assert_eq!(err_code(&q), "overload");
    assert_eq!(q.get("version").unwrap().as_u64(), Some(v0), "served the stale snapshot");
    assert_eq!(q.get("top").unwrap().as_arr().unwrap().len(), 4);

    // Off-queue reads still round-trip, count the sheds, and show the
    // queue bounded at capacity.
    let (stats, _) = handle_request(&h, r#"{"op":"stats"}"#);
    let server = stats.get("stats").unwrap().get("server").unwrap();
    assert!(server.get("overloads").unwrap().as_u64().unwrap() >= 2, "both sheds counted");
    assert!(server.get("queue_len").unwrap().as_u64().unwrap() <= 2, "queue depth stays bounded");
    assert_eq!(server.get("queue_capacity").unwrap().as_u64(), Some(2));

    release.store(true, Ordering::SeqCst);
    writer.join().unwrap().unwrap();
    match Arc::try_unwrap(h) {
        Ok(h) => h.shutdown(),
        Err(_) => panic!("handle clones outlived the test"),
    }
}

/// A recompute pinned mid-flight on the worker blocks neither readers
/// nor writers: ingest and wire queries keep round-tripping (at most one
/// job in flight, so they answer unscheduled), stats report the job, and
/// releasing the worker publishes a real ranking.
#[test]
fn held_recompute_blocks_neither_readers_nor_writers() {
    let engine = EngineBuilder::new().build_from_edges(ring(25)).unwrap();
    let h = ServerHandle::spawn(engine, 256, OverflowPolicy::Block);
    let reader = h.reader();
    let v0 = reader.latest().version;
    h.hold_recompute();

    // Mutate, then a wire query: the staleness policy schedules a
    // recompute, which the gate now pins on the worker thread.
    h.ingest(EdgeOp::add(0, 12)).unwrap();
    let (q, _) = handle_request(&h, r#"{"op":"query","top":3}"#);
    assert_eq!(q.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(q.get("scheduled").unwrap().as_bool(), Some(true), "policy handed a job off-thread");

    // While the recompute is held: reads, writes and further queries all
    // complete.
    for i in 0..50u64 {
        assert_eq!(reader.top(5).len(), 5);
        h.ingest(EdgeOp::add(200 + i, i % 25)).unwrap();
    }
    let (q2, _) = handle_request(&h, r#"{"op":"query","top":3}"#);
    assert_eq!(q2.get("ok").unwrap().as_bool(), Some(true), "queries answer while a job is pinned");
    assert_eq!(
        q2.get("scheduled").unwrap().as_bool(),
        Some(false),
        "at most one recompute in flight"
    );
    let (stats, _) = handle_request(&h, r#"{"op":"stats"}"#);
    let server = stats.get("stats").unwrap().get("server").unwrap();
    assert_eq!(server.get("recompute_in_flight").unwrap().as_bool(), Some(true));

    // Release: the pinned job finishes off-thread and publishes.
    h.release_recompute();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let s = reader.latest();
        if s.version > v0 && s.action != Action::RepeatLast {
            break;
        }
        assert!(Instant::now() < deadline, "recompute never published after release");
        std::thread::sleep(Duration::from_millis(2));
    }
    h.shutdown();
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

fn read_json_line(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
}

/// The readiness-loop TCP front end serves two simultaneous clients:
/// both stay connected the whole time, and each gets responses while the
/// other's connection is open (the serial server would park client 2
/// until client 1 disconnected).
#[test]
fn tcp_server_handles_two_simultaneous_clients() {
    let engine = EngineBuilder::new().build_from_edges(ring(20)).unwrap();
    let h = ServerHandle::spawn(engine, 256, OverflowPolicy::Block);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve(h, listener, ServeOptions::new().max_connections(8).workers(2)).unwrap();
    });

    let mut c1 = TcpStream::connect(addr).unwrap();
    let mut c2 = TcpStream::connect(addr).unwrap();
    c1.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut r1 = BufReader::new(c1.try_clone().unwrap());
    let mut r2 = BufReader::new(c2.try_clone().unwrap());

    // Interleave requests across the two live connections.
    send_line(&mut c1, r#"{"op":"top","k":3}"#);
    let resp = read_json_line(&mut r1);
    assert_eq!(resp.get("v").unwrap().as_u64(), Some(1), "responses are versioned");
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 3);

    // An explicitly versioned request negotiates cleanly over the wire.
    send_line(&mut c2, r#"{"v":1,"op":"top","k":5}"#);
    let resp = read_json_line(&mut r2);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "c2 served while c1 is connected");
    assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 5);

    send_line(&mut c1, r#"{"op":"add","src":0,"dst":10}"#);
    assert_eq!(read_json_line(&mut r1).get("ok").unwrap().as_bool(), Some(true));
    send_line(&mut c1, r#"{"op":"query","top":2}"#);
    let q = read_json_line(&mut r1);
    assert_eq!(q.get("ok").unwrap().as_bool(), Some(true));

    send_line(&mut c2, r#"{"op":"stats"}"#);
    let stats = read_json_line(&mut r2);
    let serving = stats.get("stats").unwrap().get("serving").unwrap();
    assert!(serving.get("version").unwrap().as_u64().unwrap() >= 2, "c2 sees c1's republish");
    let server_stats = stats.get("stats").unwrap().get("server").unwrap();
    assert_eq!(server_stats.get("connections").unwrap().as_u64(), Some(2));

    // c2 shuts the server down while c1 is still connected.
    send_line(&mut c2, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut r2).get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap();
}

/// Clients beyond the connection cap get one `conn_cap` error line and a
/// closed stream; clients within the cap are unaffected.
#[test]
fn tcp_server_enforces_connection_cap() {
    let engine = EngineBuilder::new().build_from_edges(ring(10)).unwrap();
    let h = ServerHandle::spawn(engine, 64, OverflowPolicy::Block);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve(h, listener, ServeOptions::new().max_connections(1).workers(1)).unwrap();
    });

    let mut c1 = TcpStream::connect(addr).unwrap();
    c1.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut r1 = BufReader::new(c1.try_clone().unwrap());
    // Round-trip on c1 proves it is accepted and registered.
    send_line(&mut c1, r#"{"op":"top","k":1}"#);
    assert_eq!(read_json_line(&mut r1).get("ok").unwrap().as_bool(), Some(true));

    // c2 is over the cap: one typed error line, then EOF.
    let c2 = TcpStream::connect(addr).unwrap();
    c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut r2 = BufReader::new(c2);
    let reject = read_json_line(&mut r2);
    assert_eq!(reject.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(err_code(&reject), "conn_cap");
    let mut rest = String::new();
    assert_eq!(r2.read_line(&mut rest).unwrap(), 0, "rejected stream is closed");

    send_line(&mut c1, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut r1).get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap();
}

/// A flood of read requests on one connection trips the per-connection
/// rate limit: the burst is served, over-limit requests get a
/// `rate_limited` error line (connection stays open), and writes are
/// unaffected.
#[test]
fn tcp_server_enforces_read_rate_limit() {
    let engine = EngineBuilder::new().build_from_edges(ring(15)).unwrap();
    let h = ServerHandle::spawn(engine, 256, OverflowPolicy::Block);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let opts = ServeOptions::new().max_connections(4).rate_limit(3.0).workers(1);
        serve(h, listener, opts).unwrap();
    });

    let mut c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut r = BufReader::new(c.try_clone().unwrap());
    // Pipeline 40 reads, then collect the 40 responses.
    for _ in 0..40 {
        send_line(&mut c, r#"{"op":"top","k":2}"#);
    }
    let (mut served, mut limited) = (0, 0);
    for _ in 0..40 {
        let resp = read_json_line(&mut r);
        if resp.get("ok").unwrap().as_bool() == Some(true) {
            served += 1;
        } else {
            assert_eq!(err_code(&resp), "rate_limited");
            limited += 1;
        }
    }
    assert_eq!(served + limited, 40);
    assert!(served >= 1, "the burst allowance serves the first reads");
    assert!(limited >= 1, "a 40-read flood must trip a 3 ops/sec limit");
    // Writes bypass the read limiter entirely.
    send_line(&mut c, r#"{"op":"add","src":100,"dst":3}"#);
    assert_eq!(read_json_line(&mut r).get("ok").unwrap().as_bool(), Some(true));

    send_line(&mut c, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut r).get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap();
}

/// The wire `batch` op registers a whole update set in one round-trip
/// and applies atomically with respect to the serving path: the next
/// query observes either none or all of it (here: all).
#[test]
fn tcp_server_batch_write_roundtrip() {
    let engine = EngineBuilder::new().build_from_edges(ring(10)).unwrap();
    let h = ServerHandle::spawn(engine, 256, OverflowPolicy::Block);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve(h, listener, ServeOptions::new()).unwrap();
    });

    let mut c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut r = BufReader::new(c.try_clone().unwrap());
    let ops: Vec<String> = (0..32u64)
        .map(|i| format!(r#"{{"op":"add","src":{},"dst":{}}}"#, 100 + i, i % 10))
        .collect();
    send_line(&mut c, &format!(r#"{{"op":"batch","ops":[{}]}}"#, ops.join(",")));
    let resp = read_json_line(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("registered").unwrap().as_u64(), Some(32));

    send_line(&mut c, r#"{"op":"query","top":3}"#);
    let q = read_json_line(&mut r);
    assert_eq!(q.get("ok").unwrap().as_bool(), Some(true));
    send_line(&mut c, r#"{"op":"rank","id":131}"#);
    let rank = read_json_line(&mut r);
    assert!(rank.get("rank").unwrap().as_f64().is_some(), "batched vertex 131 is ranked");

    send_line(&mut c, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut r).get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap();
}

/// Soft fd limit for this process, so the swarm test scales to the
/// sandbox it runs in instead of dying on EMFILE.
fn fd_budget() -> usize {
    let limits = std::fs::read_to_string("/proc/self/limits").unwrap_or_default();
    for line in limits.lines() {
        if line.starts_with("Max open files") {
            if let Some(n) = line.split_whitespace().nth(3).and_then(|t| t.parse().ok()) {
                return n;
            }
        }
    }
    1024
}

/// A mostly-idle swarm (as many connections as the fd budget allows, up
/// to 2000) is held open and served by at most 8 poll threads: every
/// sampled idle client still round-trips promptly, and the server's own
/// stats report the full swarm against the small worker set.
#[test]
fn idle_swarm_is_served_by_a_small_worker_set() {
    let engine = EngineBuilder::new().build_from_edges(ring(10)).unwrap();
    let h = ServerHandle::spawn(engine, 1024, OverflowPolicy::Block);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve(h, listener, ServeOptions::new().max_connections(4096).workers(8)).unwrap();
    });

    // 2 fds per connection (client + server end), with headroom for the
    // process's own files.
    let swarm = (fd_budget().saturating_sub(128) / 2).clamp(64, 2000);
    let mut conns = Vec::with_capacity(swarm);
    for i in 0..swarm {
        let c = TcpStream::connect(addr)
            .unwrap_or_else(|e| panic!("connect {i}/{swarm} failed: {e}"));
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        conns.push(c);
    }

    // Accepts are FIFO, so a round-trip on the LAST connection proves
    // the whole swarm is registered.
    let mut last = conns.last().unwrap().try_clone().unwrap();
    let mut rl = BufReader::new(last.try_clone().unwrap());
    send_line(&mut last, r#"{"op":"top","k":1}"#);
    assert_eq!(read_json_line(&mut rl).get("ok").unwrap().as_bool(), Some(true));

    // Sampled idle clients wake up and are served promptly while the
    // rest of the swarm sits connected.
    for i in (0..swarm).step_by(97) {
        let mut c = conns[i].try_clone().unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        send_line(&mut c, r#"{"op":"rank","id":3}"#);
        let resp = read_json_line(&mut r);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "idle client {i} served");
    }

    send_line(&mut last, r#"{"op":"stats"}"#);
    let stats = read_json_line(&mut rl);
    let server_stats = stats.get("stats").unwrap().get("server").unwrap();
    let connected = server_stats.get("connections").unwrap().as_u64().unwrap() as usize;
    assert!(connected >= swarm, "all {swarm} clients held open (server saw {connected})");
    assert!(
        server_stats.get("workers").unwrap().as_u64().unwrap() <= 8,
        "swarm served by a small fixed poll-thread set"
    );

    send_line(&mut last, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut rl).get("ok").unwrap().as_bool(), Some(true));
    drop(conns);
    server.join().unwrap();
}
