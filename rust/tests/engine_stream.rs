//! Integration: the full engine over realistic streams — the paper's
//! protocol end-to-end (split → chunk → replay → RBO/speedup), plus
//! stream-operation coverage the paper leaves to future work (removals),
//! and failure injection.

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::policies::{AlwaysExact, ChangeRatioPolicy, SlaPolicy, SlaTier};
use veilgraph::coordinator::udf::Action;
use veilgraph::graph::generate;
use veilgraph::metrics::rbo::rbo_ext;
use veilgraph::pagerank::power::PageRankConfig;
use veilgraph::stream::event::{EdgeOp, UpdateEvent};
use veilgraph::stream::source::{chunked_events, split_stream};
use veilgraph::summary::params::SummaryParams;

fn pr_cfg() -> PageRankConfig {
    PageRankConfig { epsilon: 1e-8, max_iters: 100, ..Default::default() }
}

/// The paper's core claim at test scale: summarized replays keep RBO
/// high while touching a small fraction of the graph.
#[test]
fn paper_protocol_keeps_rbo_high_with_small_summaries() {
    let edges = generate::copying_web(3000, 8, 0.7, 1234);
    let (initial, stream) = split_stream(&edges, 600, true, 99);
    let events = chunked_events(&stream, 10);

    let mut approx = EngineBuilder::new()
        .params(SummaryParams::new(0.2, 1, 0.1))
        .pagerank(pr_cfg())
        .build_from_edges(initial.iter().copied())
        .unwrap();
    let mut exact = EngineBuilder::new()
        .udf(Box::new(AlwaysExact))
        .pagerank(pr_cfg())
        .build_from_edges(initial.iter().copied())
        .unwrap();

    let ra = approx.run_stream(events.clone()).unwrap();
    let re = exact.run_stream(events).unwrap();
    assert_eq!(ra.len(), 10);
    assert_eq!(re.len(), 10);

    let mut rbo_sum = 0.0;
    let mut vr_sum = 0.0;
    for (a, e) in ra.iter().zip(&re) {
        let rbo = rbo_ext(&a.top_ids(500), &e.top_ids(500), 0.99);
        rbo_sum += rbo;
        vr_sum += a.exec.summary_vertices as f64 / a.ids().len() as f64;
    }
    let rbo_avg = rbo_sum / 10.0;
    let vr_avg = vr_sum / 10.0;
    assert!(rbo_avg > 0.93, "avg RBO {rbo_avg}");
    assert!(vr_avg < 0.5, "avg vertex ratio {vr_avg} should be well under 1");
}

/// Edge removals (`e-`) — the paper's model includes them even though the
/// evaluation streams are additions-only.
#[test]
fn removals_are_tracked_and_affect_ranks() {
    let base = generate::barabasi_albert(200, 3, 0.5, 5);
    let mut e = EngineBuilder::new()
        .params(SummaryParams::new(0.1, 1, 0.1))
        .pagerank(pr_cfg())
        .build_from_edges(base.iter().copied())
        .unwrap();
    // Remove a batch of the hub's in-edges: its rank must fall.
    let hub = {
        let r0 = e.query().unwrap();
        r0.top(1)[0].0
    };
    let victims: Vec<EdgeOp> = base
        .iter()
        .filter(|&&(_, v)| v == hub)
        .take(10)
        .map(|&(u, v)| EdgeOp::remove(u, v))
        .collect();
    assert!(!victims.is_empty());
    let before = e.query().unwrap().top(50);
    let rank_before = before.iter().find(|(v, _)| *v == hub).unwrap().1;
    e.ingest_many(victims);
    let after = e.query().unwrap();
    assert_eq!(after.action, Action::ComputeApproximate);
    assert!(after.exec.summary_vertices > 0, "removals must mark hot vertices");
    let rank_after = after.top(200).iter().find(|(v, _)| *v == hub).map(|(_, s)| *s).unwrap_or(0.0);
    assert!(rank_after < rank_before, "hub rank should drop: {rank_before} -> {rank_after}");
}

/// Vertex removal (`v-`) drops all incident edges and keeps serving.
#[test]
fn vertex_removal_keeps_engine_consistent() {
    let mut e = EngineBuilder::new()
        .pagerank(pr_cfg())
        .build_from_edges(vec![(0, 1), (1, 2), (2, 0), (2, 3), (3, 0)])
        .unwrap();
    e.ingest(EdgeOp::RemoveVertex(2));
    let r = e.query().unwrap();
    assert_eq!(e.graph().num_edges(), 2); // 0->1 and 3->0 survive
    assert_eq!(r.ranks().len(), 4);
    // another query still works
    let _ = e.query().unwrap();
}

/// ChangeRatio policy switches between all three actions over a stream.
#[test]
fn change_ratio_policy_exercises_all_actions() {
    let base = generate::erdos_renyi(500, 3000, 9);
    let mut e = EngineBuilder::new()
        .udf(Box::new(ChangeRatioPolicy::new(0.01, 0.2)))
        .pagerank(pr_cfg())
        .build_from_edges(base.iter().copied())
        .unwrap();
    // tiny update ⇒ repeat
    e.ingest(EdgeOp::add(0, 499));
    assert_eq!(e.query().unwrap().action, Action::RepeatLast);
    // moderate update ⇒ approximate
    e.ingest_many((0..30u64).map(|i| EdgeOp::add(i, 400 + (i % 50))));
    assert_eq!(e.query().unwrap().action, Action::ComputeApproximate);
    // massive update ⇒ exact
    e.ingest_many((0..400u64).map(|i| EdgeOp::add(1000 + i, i % 500)));
    assert_eq!(e.query().unwrap().action, Action::ComputeExact);
}

/// SLA tiers: gold always exact; bronze repeats tiny updates.
#[test]
fn sla_tiers_differ_in_work() {
    // Bronze repeats only when < 0.1 % of vertices are touched — needs a
    // graph big enough that one edge is below that bar.
    let base = generate::barabasi_albert(3000, 3, 0.5, 17);
    let mut gold = EngineBuilder::new()
        .udf(Box::new(SlaPolicy { tier: SlaTier::Gold }))
        .pagerank(pr_cfg())
        .build_from_edges(base.iter().copied())
        .unwrap();
    let mut bronze = EngineBuilder::new()
        .udf(Box::new(SlaPolicy { tier: SlaTier::Bronze }))
        .pagerank(pr_cfg())
        .build_from_edges(base.iter().copied())
        .unwrap();
    gold.ingest(EdgeOp::add(0, 2999));
    bronze.ingest(EdgeOp::add(0, 2999));
    assert_eq!(gold.query().unwrap().action, Action::ComputeExact);
    assert_eq!(bronze.query().unwrap().action, Action::RepeatLast);
}

/// Duplicate adds and bogus removes in the stream must not poison the
/// engine (failure injection).
#[test]
fn malformed_stream_operations_are_tolerated() {
    let mut e = EngineBuilder::new()
        .pagerank(pr_cfg())
        .build_from_edges(vec![(0, 1), (1, 2)])
        .unwrap();
    e.ingest(EdgeOp::add(0, 1)); // duplicate
    e.ingest(EdgeOp::remove(5, 6)); // nonexistent
    e.ingest(EdgeOp::remove(0, 2)); // nonexistent edge between real vertices
    e.ingest(EdgeOp::add(2, 0)); // legitimate
    let r = e.query().unwrap();
    assert_eq!(e.graph().num_edges(), 3);
    assert!(r.ranks().iter().all(|&x| x.is_finite()));
}

/// A long stream with interleaved empty queries: query count, metrics and
/// monotone ids stay consistent.
#[test]
fn long_stream_bookkeeping() {
    let base = generate::erdos_renyi(100, 600, 3);
    let mut e = EngineBuilder::new()
        .pagerank(pr_cfg())
        .build_from_edges(base.iter().copied())
        .unwrap();
    let mut events = Vec::new();
    for i in 0..20u64 {
        if i % 3 != 2 {
            events.push(UpdateEvent::Op(EdgeOp::add(200 + i, i % 100)));
        }
        events.push(UpdateEvent::Query);
    }
    events.push(UpdateEvent::Stop);
    let rs = e.run_stream(events).unwrap();
    assert_eq!(rs.len(), 20);
    for (i, r) in rs.iter().enumerate() {
        assert_eq!(r.query_id, i as u64 + 1);
    }
    assert_eq!(e.metrics().counter("queries"), 20);
    assert!(e.metrics().timing("query_secs").unwrap().count() == 20);
}

/// Exact-vs-approximate divergence is bounded over a long stream even
/// without periodic refresh (the paper's RBO decay curves).
#[test]
fn rbo_decays_gracefully_not_catastrophically() {
    let edges = generate::barabasi_albert(2000, 4, 0.6, 44);
    let (initial, stream) = split_stream(&edges, 800, true, 7);
    let events = chunked_events(&stream, 20);
    let mut approx = EngineBuilder::new()
        .params(SummaryParams::new(0.1, 1, 0.01)) // accuracy-oriented
        .pagerank(pr_cfg())
        .build_from_edges(initial.iter().copied())
        .unwrap();
    let mut exact = EngineBuilder::new()
        .udf(Box::new(AlwaysExact))
        .pagerank(pr_cfg())
        .build_from_edges(initial.iter().copied())
        .unwrap();
    let ra = approx.run_stream(events.clone()).unwrap();
    let re = exact.run_stream(events).unwrap();
    let last_rbo = rbo_ext(&ra[19].top_ids(500), &re[19].top_ids(500), 0.99);
    assert!(last_rbo > 0.9, "RBO after 20 queries {last_rbo}");
}
