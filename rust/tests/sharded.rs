//! Sharded scale-out acceptance: partitioning laws, cross-shard rank
//! equivalence, and the wire protocol against a multi-shard cluster.
//!
//! * Property: the hash partitioner is total, a pure function of the id,
//!   and routes every op to exactly the shards that must see it.
//! * Property: row-range split ∘ concat reproduces any frozen CSR.
//! * Property: a 2- and a 4-shard cluster driven by random mutation
//!   streams stay rank-equivalent (L1 < 1e-6) to an exact single-engine
//!   PageRank over the mirrored graph, and the combined top-K merge
//!   agrees with a direct selection.
//! * The full line protocol works unchanged against `--shards 4`:
//!   partition-routed ranks, batch writes fanning out to every shard,
//!   and `stats` carrying the per-shard gauge section.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use veilgraph::coordinator::engine::ScheduleMode;
use veilgraph::coordinator::policies::StalenessPolicy;
use veilgraph::coordinator::server::{serve, ServeOptions, ServerHandle};
use veilgraph::coordinator::sharded::ShardedEngineBuilder;
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::partition::{concat_rows, split_rows, Partitioner};
use veilgraph::pagerank::power::{PageRank, PageRankConfig};
use veilgraph::stream::event::EdgeOp;
use veilgraph::testing::oracle::seq_apply;
use veilgraph::testing::vprop::{forall, Gen};
use veilgraph::util::json::Json;

fn ring(n: u64) -> Vec<(u64, u64)> {
    (0..n).map(|i| (i, (i + 1) % n)).collect()
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

fn read_json_line(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
}

// ---------------------------------------------------------------------------
// Partitioning laws
// ---------------------------------------------------------------------------

/// Property: assignment is total (every id owned by a shard in range),
/// pure (re-asking never moves an id), and routing delivers each op to
/// exactly the shards that must see it — source owner for edges, plus
/// one ghost registration at the destination owner for cross-shard
/// adds, and a broadcast for vertex removals.
#[test]
fn partitioner_is_total_pure_and_routes_minimally() {
    forall(40, 0x5AAD, |g: &mut Gen| {
        let k = g.usize(1..6);
        let p = Partitioner::new(k);
        for _ in 0..40 {
            let id = g.u64(0..u64::MAX);
            let s = p.shard_of(id);
            assert!(s < k, "owner out of range");
            assert_eq!(s, p.shard_of(id), "assignment is a pure function of the id");
        }
        let n = g.usize(2..40) as u64;
        for _ in 0..30 {
            let (a, b) = (g.u64(0..n), g.u64(0..n));
            let op = if g.bool(0.1) {
                EdgeOp::RemoveVertex(a)
            } else if g.bool(0.25) {
                EdgeOp::remove(a, b)
            } else {
                EdgeOp::add(a, b)
            };
            let mut deliveries: Vec<(usize, EdgeOp)> = Vec::new();
            p.for_each_route(op, |s, op| deliveries.push((s, op)));
            match op {
                EdgeOp::AddEdge(s, d) => {
                    assert_eq!(deliveries[0], (p.shard_of(s), op), "edge lives with its source");
                    if p.shard_of(s) == p.shard_of(d) {
                        assert_eq!(deliveries.len(), 1, "same-shard add stays local");
                    } else {
                        assert_eq!(deliveries.len(), 2);
                        assert_eq!(
                            deliveries[1],
                            (p.shard_of(d), EdgeOp::AddVertex(d)),
                            "cross-shard add registers the destination with its owner"
                        );
                    }
                }
                EdgeOp::RemoveEdge(s, _) => {
                    assert_eq!(deliveries, vec![(p.shard_of(s), op)], "removal follows the source");
                }
                EdgeOp::RemoveVertex(_) => {
                    let shards: Vec<usize> = deliveries.iter().map(|&(s, _)| s).collect();
                    assert_eq!(shards, (0..k).collect::<Vec<_>>(), "vertex removal broadcasts");
                }
                EdgeOp::AddVertex(_) => unreachable!("generator emits no bare AddVertex"),
            }
        }
    });
}

/// Property: slicing a frozen CSR into contiguous row ranges and
/// re-concatenating the parts reproduces it exactly, for random graphs
/// and random shard counts.
#[test]
fn row_split_concat_roundtrips_on_random_graphs() {
    forall(40, 0xC5A1, |g: &mut Gen| {
        let n = g.usize(2..60);
        let m = g.usize(1..120);
        let mut edges = g.edges(n, m);
        edges.push((0, 1)); // never a vertexless graph
        let (dg, _) = DynamicGraph::from_edges(edges);
        let csr = dg.snapshot();
        let k = g.usize(1..8);
        let cuts = csr.shards(k);
        assert_eq!(concat_rows(&split_rows(&csr, &cuts)), csr, "k={k}");
    });
}

// ---------------------------------------------------------------------------
// Cross-shard rank equivalence
// ---------------------------------------------------------------------------

/// Property (the headline acceptance): 2- and 4-shard clusters driven
/// by an arbitrary mutation stream — adds, removals, vertex drops,
/// interleaved queries — converge to the same ranking as an exact
/// single-engine PageRank over the mirrored graph, within the
/// documented `L1 < 1e-6` summation-order tolerance; and the combined
/// snapshot's k-way top-K merge agrees with a direct selection.
#[test]
fn sharded_ranks_match_single_engine_under_mutation() {
    forall(10, 0x51A2DED, |g: &mut Gen| {
        let n = g.usize(8..16);
        let mut initial = g.edges(n, 24);
        initial.extend((0..n as u64).map(|i| (i, (i + 1) % n as u64)));
        let (mut mirror, _) = DynamicGraph::from_edges(initial.clone());
        let mut engines: Vec<_> = [2usize, 4]
            .iter()
            .map(|&k| ShardedEngineBuilder::new(k).build_from_edges(initial.clone()).unwrap())
            .collect();

        for _ in 0..g.usize(1..4) {
            let mut batch = Vec::new();
            for _ in 0..g.usize(1..8) {
                let (a, b) = (g.u64(0..n as u64 + 6), g.u64(0..n as u64 + 6));
                if a == b {
                    continue;
                }
                batch.push(if g.bool(0.08) {
                    EdgeOp::RemoveVertex(a)
                } else if g.bool(0.25) {
                    EdgeOp::remove(a, b)
                } else {
                    EdgeOp::add(a, b)
                });
            }
            seq_apply(&mut mirror, &batch);
            let query_mid_stream = g.bool(0.5);
            for e in &mut engines {
                e.ingest_batch(batch.iter().copied());
                if query_mid_stream {
                    e.query().unwrap();
                }
            }
        }

        let exact = PageRank::new(PageRankConfig::default()).run(&mirror.snapshot());
        let mut exact_sorted = exact.ranks.clone();
        exact_sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for e in &mut engines {
            e.query().unwrap(); // settle: one exchange over the final topology
            let snap = e.latest_snapshot();
            let k = e.shard_count();
            assert_eq!(
                snap.ids.len(),
                mirror.num_vertices(),
                "shards={k}: owned union != single-engine vertex set"
            );
            let mut l1 = 0.0;
            for (idx, &id) in mirror.ids().iter().enumerate() {
                let r = snap.rank_of(id).expect("combined snapshot misses a vertex");
                l1 += (r - exact.ranks[idx]).abs();
            }
            assert!(l1 < 1e-6, "shards={k}: L1={l1}");
            let top = snap.top(5.min(mirror.num_vertices()));
            for (i, (_, r)) in top.iter().enumerate() {
                assert!(
                    (r - exact_sorted[i]).abs() < 1e-6,
                    "shards={k}: merged top-{i} rank diverges from direct selection"
                );
            }
        }
    });
}

/// Property (fence reconciliation): an off-thread recompute that loses
/// its version fence to writes landing mid-flight is salvaged — the
/// post-fence ops replay onto the fenced ranks — and the reconciled
/// publish tracks the blocking-recompute oracle: the vertex set equals
/// the mirror graph's, every rank is positive and finite, and one
/// follow-up blocking query restores exact agreement (L1 < 1e-6).
#[test]
fn fence_reconciled_publish_tracks_blocking_oracle() {
    forall(12, 0xF17CE, |g: &mut Gen| {
        let n = g.usize(8..14);
        let mut initial = g.edges(n, 20);
        initial.extend((0..n as u64).map(|i| (i, (i + 1) % n as u64)));
        let (mut mirror, _) = DynamicGraph::from_edges(initial.clone());
        let k = g.usize(2..5);
        let mut engine = ShardedEngineBuilder::new(k).build_from_edges(initial).unwrap();
        let policy = StalenessPolicy::new(1, 1, 8, 64, 5.0, 120.0);

        // Pre-fence batch (may include vertex drops — the fence log only
        // records what lands AFTER the job is cut). One guaranteed-new
        // edge keeps the policy escalating.
        let mut batch = vec![EdgeOp::add(500, 0)];
        for _ in 0..g.usize(0..6) {
            let (a, b) = (g.u64(0..n as u64 + 4), g.u64(0..n as u64 + 4));
            if a == b {
                continue;
            }
            batch.push(if g.bool(0.1) {
                EdgeOp::RemoveVertex(a)
            } else if g.bool(0.25) {
                EdgeOp::remove(a, b)
            } else {
                EdgeOp::add(a, b)
            });
        }
        seq_apply(&mut mirror, &batch);
        engine.ingest_batch(batch.iter().copied());
        let (_, job) = engine.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        let job = job.expect("one effective update must escalate the policy");
        let res = job.run();

        // Post-fence batch: edge-only mutations (a vertex drop would
        // taint the log and demote the miss to a plain merge).
        let mut post = Vec::new();
        for _ in 0..g.usize(1..6) {
            let (a, b) = (g.u64(0..n as u64 + 8), g.u64(0..n as u64 + 8));
            if a == b {
                continue;
            }
            post.push(if g.bool(0.3) { EdgeOp::remove(a, b) } else { EdgeOp::add(a, b) });
        }
        let (applied, _) = seq_apply(&mut mirror, &post);
        engine.ingest_batch(post.iter().copied());
        engine.flush_pending();

        let out = engine.finish_recompute(res);
        if applied > 0 {
            assert!(!out.fence_ok, "effective post-fence ops must miss the fence");
            assert!(out.reconciled, "a clean fence log must reconcile the miss");
            assert_eq!(engine.metrics().counter("recomputes_reconciled"), 1);
            assert_eq!(engine.metrics().counter("recompute_fence_misses"), 0);
        } else {
            assert!(out.fence_ok, "no effective post-fence ops ⇒ the fence holds");
            assert!(!out.reconciled);
        }

        // The reconciled publish tracks the oracle's vertex set, with
        // every rank positive and finite.
        let snap = engine.latest_snapshot();
        assert_eq!(snap.ids.len(), mirror.num_vertices(), "k={k}: published vertex set");
        for &id in mirror.ids() {
            let r = snap.rank_of(id).expect("reconciled snapshot misses a live vertex");
            assert!(r.is_finite() && r > 0.0, "k={k}: rank({id})={r}");
        }

        // One blocking exchange over the settled topology restores exact
        // agreement with the oracle graph.
        engine.query().unwrap();
        let exact = PageRank::new(PageRankConfig::default()).run(&mirror.snapshot());
        let snap = engine.latest_snapshot();
        let mut l1 = 0.0;
        for (idx, &id) in mirror.ids().iter().enumerate() {
            l1 += (snap.rank_of(id).unwrap() - exact.ranks[idx]).abs();
        }
        assert!(l1 < 1e-6, "k={k}: post-reconcile exchange diverges, L1={l1}");
    });
}

// ---------------------------------------------------------------------------
// Wire protocol against a 4-shard cluster
// ---------------------------------------------------------------------------

/// Acceptance: the unchanged line protocol (v1 and v2 framing) works
/// against `serve --shards 4`: reads come off the combined merge, `rank`
/// routes to the owning shard's snapshot, batch writes fan out across
/// all four shards, and `stats` carries the per-shard gauge section
/// alongside the server counters (including `recomputes_cancelled`).
#[test]
fn wire_protocol_over_four_shards() {
    let mut edges = ring(32);
    edges.extend((0..8u64).map(|i| (4 * i, (i * 11 + 2) % 32)));
    let engine = ShardedEngineBuilder::new(4).build_from_edges(edges).unwrap();
    let h = ServerHandle::spawn_sharded(engine, &ServeOptions::new());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve(h, listener, ServeOptions::new().max_connections(4).workers(2)).unwrap();
    });

    let mut c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut r = BufReader::new(c.try_clone().unwrap());

    // v1 read: the combined k-way merge serves `top`.
    send_line(&mut c, r#"{"v":1,"op":"top","k":5}"#);
    let resp = read_json_line(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 5);

    // Partition-routed rank: vertices answer wherever they are owned.
    for id in [0u64, 7, 13, 31] {
        send_line(&mut c, &format!(r#"{{"op":"rank","id":{id}}}"#));
        let resp = read_json_line(&mut r);
        assert!(resp.get("rank").unwrap().as_f64().is_some(), "vertex {id} unranked");
    }
    send_line(&mut c, r#"{"op":"rank","id":424242}"#);
    assert_eq!(read_json_line(&mut r).get("rank"), Some(&Json::Null), "unknown id ranks null");

    // A batch write fans out to every shard; the next query absorbs it.
    let ops: Vec<String> = (0..16u64)
        .map(|i| format!(r#"{{"op":"add","src":{},"dst":{}}}"#, 100 + i, i % 32))
        .collect();
    send_line(&mut c, &format!(r#"{{"op":"batch","ops":[{}]}}"#, ops.join(",")));
    let resp = read_json_line(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("registered").unwrap().as_u64(), Some(16));
    send_line(&mut c, r#"{"v":2,"op":"query","top":3}"#);
    assert_eq!(read_json_line(&mut r).get("ok").unwrap().as_bool(), Some(true));
    send_line(&mut c, r#"{"op":"rank","id":107}"#);
    assert!(
        read_json_line(&mut r).get("rank").unwrap().as_f64().is_some(),
        "batched vertex 107 is ranked by its owning shard"
    );

    // `stats` carries the per-shard section next to the server counters.
    send_line(&mut c, r#"{"op":"stats"}"#);
    let stats = read_json_line(&mut r);
    let shards = stats.get("stats").unwrap().get("shards").unwrap().as_arr().unwrap();
    assert_eq!(shards.len(), 4);
    let vertices: u64 = shards.iter().map(|s| s.get("vertices").unwrap().as_u64().unwrap()).sum();
    assert_eq!(vertices, 48, "owned vertices partition the 32 + 16 live ids exactly");
    let server_stats = stats.get("stats").unwrap().get("server").unwrap();
    assert!(
        server_stats.get("recomputes_cancelled").unwrap().as_u64().is_some(),
        "supersession counter is exported"
    );

    send_line(&mut c, r#"{"op":"shutdown"}"#);
    assert_eq!(read_json_line(&mut r).get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap();
}
