//! `cargo bench --bench ingest` — write-path benchmark for the batched
//! ingestion pipeline.
//!
//! Two measurements:
//! * **Wire**: a TCP client registering N edges as one request per op
//!   (one round-trip each) vs `{"op":"batch","ops":[…]}` lines of 512
//!   ops (one round-trip per 512). The `ingest_batch_vs_per_op` ratio is
//!   the headline: what a client gains by batching its writes.
//! * **Apply**: draining a pending buffer into the graph op-by-op
//!   (`UpdateBuffer::apply`) vs coalesce + grouped `apply_batch`, on a
//!   duplicate-free stream (coalescing off — pure grouped-apply cost)
//!   and a duplicate/cancel-heavy stream (coalescing on).
//!
//! Two durability measurements ride along:
//! * **WAL overhead** (`ingest_wal_batch_vs_none`): the same ingest +
//!   flush loop against no WAL, a WAL under `none` sync (buffered
//!   appends) and a WAL under `batch` sync (fsync per batch) — the
//!   per-batch price of crash safety.
//! * **Recovery replay** (`recovery_replay_100k`): 100k WAL'd ops
//!   replayed through the ordinary batch path on restart, reported as
//!   replay ops/sec.
//!
//! Emits `results/ingest_bench.json` and — when the serving bench ran
//! first (CI does) — merges `results/bench_4.json` into
//! `results/bench_10.json`, the BENCH_10 perf-trajectory artifact
//! (superset of the BENCH_9 schema: micro + serving + saturation +
//! subscriptions + sharded scale-out + ingest speedups + durability +
//! the recompute-plane exchange/plan-cache ratios).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::time::Instant;

use veilgraph::coordinator::checkpoint::DurabilityConfig;
use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::server::{serve, ServeOptions, ServerHandle};
use veilgraph::coordinator::wal::SyncPolicy;
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::generate;
use veilgraph::stream::backpressure::OverflowPolicy;
use veilgraph::stream::buffer::UpdateBuffer;
use veilgraph::stream::event::EdgeOp;
use veilgraph::util::json::Json;

const WIRE_OPS: usize = 2_000;
const WIRE_BATCH: usize = 512;
const APPLY_OPS: usize = 40_000;
const APPLY_ROUNDS: usize = 5;
const WAL_BATCHES: usize = 200;
const WAL_OPS_PER_BATCH: usize = 64;
const REPLAY_OPS: usize = 100_000;
const REPLAY_BATCH: usize = 512;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// One request per line, one response per line — the client pays a full
/// round-trip per call, exactly like a driver without batch support.
fn wire_per_op(c: &mut TcpStream, r: &mut BufReader<TcpStream>, base: u64, n: usize) -> f64 {
    let mut line = String::new();
    let t0 = Instant::now();
    for i in 0..n as u64 {
        let req = format!("{{\"op\":\"add\",\"src\":{},\"dst\":{}}}\n", base + i, i % 10_000);
        c.write_all(req.as_bytes()).unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("true"), "write rejected: {line}");
    }
    t0.elapsed().as_secs_f64()
}

/// The same op count shipped as `batch` lines of `WIRE_BATCH` ops.
fn wire_batched(c: &mut TcpStream, r: &mut BufReader<TcpStream>, base: u64, n: usize) -> f64 {
    let mut line = String::new();
    let t0 = Instant::now();
    let mut i = 0u64;
    while (i as usize) < n {
        let take = WIRE_BATCH.min(n - i as usize) as u64;
        let ops: Vec<String> = (i..i + take)
            .map(|j| format!("{{\"op\":\"add\",\"src\":{},\"dst\":{}}}", base + j, j % 10_000))
            .collect();
        let req = format!("{{\"op\":\"batch\",\"ops\":[{}]}}\n", ops.join(","));
        c.write_all(req.as_bytes()).unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("true"), "batch rejected: {line}");
        i += take;
    }
    t0.elapsed().as_secs_f64()
}

/// Op-by-op reference drain vs coalesce + grouped apply, median of
/// `APPLY_ROUNDS` runs each. Returns (seq_secs, batch_secs, effective).
fn apply_pair(base: &DynamicGraph, ops: &[EdgeOp]) -> (f64, f64, usize) {
    let mut seq_times = Vec::new();
    let mut batch_times = Vec::new();
    let mut effective = 0;
    for _ in 0..APPLY_ROUNDS {
        let mut g = base.clone();
        let mut buf = UpdateBuffer::new();
        buf.register_batch(ops.iter().copied());
        let t0 = Instant::now();
        buf.apply(&mut g).unwrap();
        seq_times.push(t0.elapsed().as_secs_f64());

        let mut g = base.clone();
        let mut buf = UpdateBuffer::new();
        buf.register_batch(ops.iter().copied());
        let t0 = Instant::now();
        let batch = buf.take_batch(&g);
        g.apply_batch(batch.ops(), None, 1);
        batch_times.push(t0.elapsed().as_secs_f64());
        effective = batch.effective_ops();
    }
    (median(seq_times), median(batch_times), effective)
}

fn bench_dir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("vg-bench-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// `WAL_BATCHES` batches of `WAL_OPS_PER_BATCH` fresh adds through
/// ingest + flush, optionally behind a WAL under `sync`. Returns the
/// wall-clock seconds for the whole loop.
fn durable_ingest(sync: Option<SyncPolicy>) -> f64 {
    let initial = generate::copying_web(5_000, 8, 0.7, 11);
    let dir = bench_dir("wal");
    let mut engine = match sync {
        Some(policy) => {
            let cfg = DurabilityConfig::new(&dir).sync(policy).checkpoint_every(1_000_000);
            EngineBuilder::new().durability(cfg).build_durable(initial).unwrap().0
        }
        None => EngineBuilder::new().build_from_edges(initial).unwrap(),
    };
    let t0 = Instant::now();
    for b in 0..WAL_BATCHES as u64 {
        let base = 1_000_000 + b * WAL_OPS_PER_BATCH as u64;
        engine.ingest_batch(
            (0..WAL_OPS_PER_BATCH as u64).map(|i| EdgeOp::add(base + i, (base + i) % 5_000)),
        );
        engine.flush_pending();
    }
    let secs = t0.elapsed().as_secs_f64();
    drop(engine);
    let _ = std::fs::remove_dir_all(&dir);
    secs
}

/// Write `REPLAY_OPS` ops into the WAL (no checkpoint), drop the
/// engine, then time a cold `build_durable` that replays the whole log
/// through the batch path. Returns (recovery_secs, replayed_batches,
/// replayed_ops).
fn recovery_replay() -> (f64, usize, usize) {
    let dir = bench_dir("replay");
    let initial = ring_edges(64);
    let cfg = || DurabilityConfig::new(&dir).sync(SyncPolicy::None).checkpoint_every(1_000_000);
    let (mut engine, _) =
        EngineBuilder::new().durability(cfg()).build_durable(initial.clone()).unwrap();
    let mut i = 0u64;
    while (i as usize) < REPLAY_OPS {
        let take = REPLAY_BATCH.min(REPLAY_OPS - i as usize) as u64;
        engine.ingest_batch((i..i + take).map(|j| EdgeOp::add(2_000_000 + j, j % 50_000)));
        engine.flush_pending();
        i += take;
    }
    drop(engine);
    let t0 = Instant::now();
    let (engine, report) =
        EngineBuilder::new().durability(cfg()).build_durable(initial).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    assert!(engine.graph().num_vertices() > 64, "replay actually rebuilt the stream");
    drop(engine);
    let _ = std::fs::remove_dir_all(&dir);
    (secs, report.replayed_batches, report.replayed_ops)
}

fn ring_edges(n: u64) -> Vec<(u64, u64)> {
    (0..n).map(|i| (i, (i + 1) % n)).collect()
}

fn main() {
    // ---- wire: per-op vs batched writes over TCP ----------------------
    let engine = EngineBuilder::new()
        .build_from_edges(generate::copying_web(10_000, 8, 0.7, 42))
        .expect("build engine");
    let handle = ServerHandle::spawn(engine, 1 << 16, OverflowPolicy::Block);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve(handle, listener, ServeOptions::new().workers(2)).unwrap();
    });
    let mut c = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(c.try_clone().unwrap());
    // Warm up the connection and allocator off the clock.
    wire_per_op(&mut c, &mut r, 500_000, 100);
    let per_op_secs = wire_per_op(&mut c, &mut r, 1_000_000, WIRE_OPS);
    let batch_secs = wire_batched(&mut c, &mut r, 2_000_000, WIRE_OPS);
    let wire_speedup = per_op_secs / batch_secs;
    println!("wire: {WIRE_OPS} ops per-op {per_op_secs:.4}s, x{WIRE_BATCH} {batch_secs:.4}s");
    println!("ingest_batch_vs_per_op: {wire_speedup:.1}x");
    c.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    server.join().unwrap();

    // ---- apply: op-by-op vs coalesce + grouped batch ------------------
    let (base, _) = DynamicGraph::from_edges(generate::copying_web(20_000, 10, 0.7, 7));
    // Coalescing off: every op is a distinct effective add.
    let unique: Vec<EdgeOp> =
        (0..APPLY_OPS as u64).map(|i| EdgeOp::add(100_000 + i, i % 20_000)).collect();
    let (squ, sbu, eff_u) = apply_pair(&base, &unique);
    // Coalescing on: 4 raw edge ops per pair collapse to 1 surviving add
    // (+1 synthesized AddVertex, each src is fresh) — ~2x coalescing.
    let mut heavy: Vec<EdgeOp> = Vec::with_capacity(APPLY_OPS);
    for i in 0..(APPLY_OPS / 4) as u64 {
        let (u, v) = (300_000 + i, i % 20_000);
        heavy.push(EdgeOp::add(u, v));
        heavy.push(EdgeOp::remove(u, v));
        heavy.push(EdgeOp::add(u, v));
        heavy.push(EdgeOp::add(u, v));
    }
    let (sqh, sbh, eff_h) = apply_pair(&base, &heavy);
    let apply_speedup_unique = squ / sbu;
    let apply_speedup_heavy = sqh / sbh;
    let (su, sh) = (apply_speedup_unique, apply_speedup_heavy);
    println!("apply unique:    seq {squ:.4}s vs batch {sbu:.4}s ({su:.2}x), eff {eff_u}");
    println!("apply coalesced: seq {sqh:.4}s vs batch {sbh:.4}s ({sh:.2}x), eff {eff_h}");

    // ---- durability: WAL overhead + recovery replay -------------------
    let wal_ops = WAL_BATCHES * WAL_OPS_PER_BATCH;
    let plain_secs = durable_ingest(None);
    let wal_none_secs = durable_ingest(Some(SyncPolicy::None));
    let wal_batch_secs = durable_ingest(Some(SyncPolicy::Batch));
    let none_x = wal_none_secs / plain_secs;
    let batch_x = wal_batch_secs / plain_secs;
    println!(
        "ingest_wal_batch_vs_none: {wal_ops} ops plain {plain_secs:.4}s, \
         wal(none) {wal_none_secs:.4}s ({none_x:.2}x), \
         wal(batch) {wal_batch_secs:.4}s ({batch_x:.2}x)"
    );
    let (replay_secs, replay_batches, replay_ops) = recovery_replay();
    let replay_rate = replay_ops as f64 / replay_secs.max(1e-9);
    println!(
        "recovery_replay_100k: {replay_ops} ops / {replay_batches} batches \
         in {replay_secs:.4}s ({replay_rate:.0} ops/s)"
    );

    // ---- machine-readable artifact ------------------------------------
    std::fs::create_dir_all("results").ok();
    let ingest = Json::obj(vec![
        (
            "wire",
            Json::obj(vec![
                ("ops", Json::Num(WIRE_OPS as f64)),
                ("batch_size", Json::Num(WIRE_BATCH as f64)),
                ("per_op_secs", Json::Num(per_op_secs)),
                ("batch_secs", Json::Num(batch_secs)),
            ]),
        ),
        (
            "apply",
            Json::obj(vec![
                ("ops", Json::Num(APPLY_OPS as f64)),
                ("seq_secs_unique", Json::Num(squ)),
                ("batch_secs_unique", Json::Num(sbu)),
                ("effective_unique", Json::Num(eff_u as f64)),
                ("seq_secs_coalesced", Json::Num(sqh)),
                ("batch_secs_coalesced", Json::Num(sbh)),
                ("effective_coalesced", Json::Num(eff_h as f64)),
            ]),
        ),
    ]);
    std::fs::write("results/ingest_bench.json", ingest.to_string_pretty())
        .expect("write ingest json");
    println!("JSON written to results/ingest_bench.json");

    // BENCH_10 = BENCH_9 schema (micro + serving + saturation +
    // subscriptions + ingest + durability + sharded scale-out) + the
    // recompute-plane ratios (`exchange_par4_vs_serial`,
    // `plan_reuse_vs_rebuild`) the serving bench folded into
    // bench_4.json.
    let mut doc = std::fs::read_to_string("results/bench_4.json")
        .or_else(|_| std::fs::read_to_string("results/micro_bench.json"))
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .unwrap_or_else(|| Json::obj(Vec::new()));
    if let Json::Obj(map) = &mut doc {
        let ratios = [
            ("ingest_batch_vs_per_op", wire_speedup),
            ("ingest_apply_batch_vs_seq", apply_speedup_unique),
            ("ingest_apply_coalesced_vs_seq", apply_speedup_heavy),
        ];
        match map.get_mut("speedups") {
            Some(Json::Obj(speedups)) => {
                for (k, v) in ratios {
                    speedups.insert(k.into(), Json::Num(v));
                }
            }
            _ => {
                map.insert(
                    "speedups".into(),
                    Json::obj(ratios.iter().map(|&(k, v)| (k, Json::Num(v))).collect()),
                );
            }
        }
        map.insert("ingest".into(), ingest);
        map.insert(
            "durability".into(),
            Json::obj(vec![
                (
                    "ingest_wal_batch_vs_none",
                    Json::obj(vec![
                        ("ops", Json::Num(wal_ops as f64)),
                        ("batches", Json::Num(WAL_BATCHES as f64)),
                        ("plain_secs", Json::Num(plain_secs)),
                        ("wal_none_secs", Json::Num(wal_none_secs)),
                        ("wal_batch_secs", Json::Num(wal_batch_secs)),
                        ("wal_none_overhead_x", Json::Num(none_x)),
                        ("wal_batch_overhead_x", Json::Num(batch_x)),
                    ]),
                ),
                (
                    "recovery_replay_100k",
                    Json::obj(vec![
                        ("ops", Json::Num(replay_ops as f64)),
                        ("batches", Json::Num(replay_batches as f64)),
                        ("recovery_secs", Json::Num(replay_secs)),
                        ("replay_ops_per_sec", Json::Num(replay_rate)),
                    ]),
                ),
            ]),
        );
    }
    std::fs::write("results/bench_10.json", doc.to_string_pretty())
        .expect("write bench_10 json");
    println!("JSON written to results/bench_10.json");
}
