//! `cargo bench --bench ablation` — ablations of the design choices
//! DESIGN.md §6 calls out:
//!
//! * **A1** summarized-XLA vs summarized-rust-sparse vs exact.
//! * **A2** frozen big-vertex contributions vs recomputing them per
//!   iteration (correctness-neutral; shows why freezing matters).
//! * **A3** K_Δ on vs off (accuracy + summary-size impact).
//! * **A4** pull (CSR) vs push PageRank traversal.
//! * **A5** shuffled vs incidence-ordered streams (paper §5, cnr-2000).
//! * **A6** fused 10-iteration artifact vs per-step execute round-trips.
//! * **A8** stream nature (paper §7): power-law growth vs Erdős–Rényi vs
//!   sliding-window streams over the same base graph.
//! * **A9** parallel sharding: serial vs degree-balanced sharded
//!   execution of both executors across shard counts.

use veilgraph::bench::{BenchConfig, Bencher};
use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::policies::{AlwaysApproximate, AlwaysExact};
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::generate;
use veilgraph::metrics::rbo::rbo_ext;
use veilgraph::pagerank::power::{PageRank, PageRankConfig};
use veilgraph::pagerank::summarized::run_summarized;
use veilgraph::runtime::artifact::Variant;
use veilgraph::runtime::client::XlaRuntime;
use veilgraph::stream::source::{chunked_events, split_stream};
use veilgraph::summary::bigvertex::SummaryGraph;
use veilgraph::summary::hot::HotSet;
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::threadpool::ThreadPool;

/// Push-style PageRank iteration (A4 comparator): scatter contributions
/// along out-edges instead of gathering along in-edges.
fn pagerank_push(out_csr: &[(u32, u32)], n: usize, iters: usize, beta: f64) -> Vec<f64> {
    let mut out_deg = vec![0u32; n];
    for &(u, _) in out_csr {
        out_deg[u as usize] += 1;
    }
    let teleport = 1.0 - beta;
    let mut ranks = vec![1.0f64; n];
    let mut next = vec![0.0f64; n];
    for _ in 0..iters {
        for x in next.iter_mut() {
            *x = teleport;
        }
        for &(u, v) in out_csr {
            next[v as usize] += beta * ranks[u as usize] / out_deg[u as usize] as f64;
        }
        std::mem::swap(&mut ranks, &mut next);
    }
    ranks
}

fn full_hot(g: &DynamicGraph) -> HotSet {
    let idxs: Vec<u32> = (0..g.num_vertices() as u32).collect();
    HotSet { k_r: idxs, k_n: vec![], k_delta: vec![], hot: vec![true; g.num_vertices()] }
}

fn main() {
    let mut b = Bencher::with_config(BenchConfig { warmup: 2, iters: 10, min_secs: 0.2 });
    let cfg = PageRankConfig { epsilon: 1e-8, max_iters: 100, ..Default::default() };

    // ================= A1: executor comparison =========================
    println!("== A1: summarized executors vs exact (|K| = 1500 of 20k) ==");
    let edges = generate::copying_web(20_000, 10, 0.7, 7);
    let (graph, _) = DynamicGraph::from_edges(edges.iter().copied());
    let csr = graph.snapshot();
    let exact_runner = PageRank::new(cfg);
    let full = exact_runner.run(&csr);
    // hot set: the 1500 highest-degree vertices (a realistic K shape);
    // tiers must be index-sorted (HotSet's invariant), so re-sort after
    // the degree-based selection.
    let mut by_deg: Vec<u32> = (0..graph.num_vertices() as u32).collect();
    by_deg.sort_by_key(|&v| std::cmp::Reverse(graph.degree(v)));
    let mut k_set: Vec<u32> = by_deg[..1500].to_vec();
    k_set.sort_unstable();
    let mut hot = vec![false; graph.num_vertices()];
    for &v in &k_set {
        hot[v as usize] = true;
    }
    let hs = HotSet { k_r: k_set, k_n: vec![], k_delta: vec![], hot };
    let summary = SummaryGraph::build(&graph, &hs, &full.ranks, 1.0);
    b.bench("a1_exact_full_graph", || exact_runner.run(&csr));
    b.bench("a1_summarized_sparse", || run_summarized(&summary, &cfg));
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have_artifacts = artifacts.join("manifest.json").is_file();
    if have_artifacts {
        let mut rt = XlaRuntime::new(&artifacts).unwrap();
        let cap = rt.ensure_tier(Variant::Run, summary.num_vertices()).unwrap();
        let dense = summary.to_dense(cap);
        let teleport = cfg.teleport(summary.full_n) as f32;
        b.bench(&format!("a1_summarized_xla_c{cap}"), || {
            let (a, r0, bb, m) = (&dense.a, &dense.r0, &dense.b, &dense.mask);
            rt.execute(Variant::Run, cap, a, r0, bb, m, 0.85, teleport).unwrap()
        });
    }

    // ================= A2: frozen vs recomputed boundary ================
    println!("\n== A2: frozen b_z vs recomputing boundary each iteration ==");
    b.bench("a2_frozen_boundary", || run_summarized(&summary, &cfg));
    // recompute = rebuild the summary every iteration (the naive scheme)
    b.bench("a2_recompute_boundary", || {
        let mut ranks = full.ranks.clone();
        for _ in 0..10 {
            let s = SummaryGraph::build(&graph, &hs, &ranks, 1.0);
            let one = PageRankConfig { max_iters: 1, epsilon: 0.0, ..cfg };
            let r = run_summarized(&s, &one);
            for (li, &v) in s.vertices.iter().enumerate() {
                ranks[v as usize] = r.ranks[li];
            }
        }
        ranks
    });

    // ================= A3: K_Δ on/off ===================================
    println!("\n== A3: K_Δ contribution (accuracy & summary size) ==");
    let ds_edges = generate::barabasi_albert(8_000, 4, 0.6, 11);
    let (initial, stream) = split_stream(&ds_edges, 2_000, true, 3);
    let events = chunked_events(&stream, 10);
    for (label, params) in [
        ("a3_with_kdelta", SummaryParams::new(0.2, 1, 0.01)),
        ("a3_without_kdelta", SummaryParams::new(0.2, 1, 1e9_f64)), // Δ→∞ ⇒ radius 0
    ] {
        let mut approx = EngineBuilder::new()
            .params(params)
            .udf(Box::new(AlwaysApproximate))
            .pagerank(cfg)
            .build_from_edges(initial.iter().copied())
            .unwrap();
        let mut exact = EngineBuilder::new()
            .udf(Box::new(AlwaysExact))
            .pagerank(cfg)
            .build_from_edges(initial.iter().copied())
            .unwrap();
        let ra = approx.run_stream(events.clone()).unwrap();
        let re = exact.run_stream(events.clone()).unwrap();
        let mut rbo = 0.0;
        let mut k_avg = 0.0;
        for (a, e) in ra.iter().zip(&re) {
            rbo += rbo_ext(&a.top_ids(1000), &e.top_ids(1000), 0.99);
            k_avg += a.exec.summary_vertices as f64;
        }
        println!(
            "{label}: avg RBO {:.4}, avg |K| {:.0}",
            rbo / ra.len() as f64,
            k_avg / ra.len() as f64
        );
    }

    // ================= A4: pull vs push =================================
    println!("\n== A4: pull (CSR gather) vs push (edge scatter), 10 iters ==");
    let el: Vec<(u32, u32)> = graph
        .edges()
        .collect();
    let ten = PageRankConfig { max_iters: 10, epsilon: 0.0, ..cfg };
    let pr10 = PageRank::new(ten);
    b.bench("a4_pull_10iters", || pr10.run(&csr));
    b.bench("a4_push_10iters", || {
        pagerank_push(&el, graph.num_vertices(), 10, 0.85)
    });
    // numerics agree
    let pull = pr10.run(&csr).ranks;
    let push = pagerank_push(&el, graph.num_vertices(), 10, 0.85);
    let max_diff = pull
        .iter()
        .zip(&push)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("a4 max |pull - push| = {max_diff:.2e} (must be ~0)");

    // ================= A5: shuffled vs incidence stream =================
    println!("\n== A5: shuffled vs incidence-ordered stream (web graph) ==");
    let web = generate::copying_web(10_000, 10, 0.7, 5);
    for (label, shuffled) in [("a5_incidence", false), ("a5_shuffled", true)] {
        let (init, stream) = split_stream(&web, 2_000, shuffled, 13);
        let ev = chunked_events(&stream, 10);
        let mut eng = EngineBuilder::new()
            .params(SummaryParams::new(0.2, 1, 0.1))
            .pagerank(cfg)
            .build_from_edges(init.iter().copied())
            .unwrap();
        let rs = eng.run_stream(ev).unwrap();
        let k_avg: f64 =
            rs.iter().map(|r| r.exec.summary_vertices as f64).sum::<f64>() / rs.len() as f64;
        let t_avg: f64 =
            rs.iter().map(|r| r.exec.elapsed_secs).sum::<f64>() / rs.len() as f64;
        println!("{label}: avg |K| {k_avg:.0}, avg query {:.2}ms", t_avg * 1e3);
    }

    // ================= A6: fused vs per-step round-trips ================
    if have_artifacts {
        println!("\n== A6: fused run-artifact (10 iters/call) vs step-artifact ==");
        let mut rt = XlaRuntime::new(&artifacts).unwrap();
        let small = generate::barabasi_albert(400, 3, 0.4, 23);
        let (g2, _) = DynamicGraph::from_edges(small);
        let f2 = PageRank::new(cfg).run(&g2.snapshot());
        let s2 = SummaryGraph::build(&g2, &full_hot(&g2), &f2.ranks, 1.0);
        let cap = rt.ensure_tier(Variant::Run, s2.num_vertices()).unwrap();
        rt.ensure_tier(Variant::Step, s2.num_vertices()).unwrap();
        let d2 = s2.to_dense(cap);
        let teleport = cfg.teleport(s2.full_n) as f32;
        b.bench("a6_fused_10iters_1call", || {
            rt.execute(Variant::Run, cap, &d2.a, &d2.r0, &d2.b, &d2.mask, 0.85, teleport).unwrap()
        });
        b.bench("a6_step_10iters_10calls", || {
            let mut r = d2.r0.clone();
            for _ in 0..10 {
                r = rt
                    .execute(Variant::Step, cap, &d2.a, &r, &d2.b, &d2.mask, 0.85, teleport)
                    .unwrap()
                    .ranks;
            }
            r
        });
    }

    // ================= A8: stream nature (paper §7) =====================
    println!("\n== A8: stream nature — power-law growth vs ER vs sliding window ==");
    {
        use veilgraph::stream::event::UpdateEvent;
        use veilgraph::stream::synthetic::{
            er_stream, powerlaw_growth_stream, sliding_window_stream,
        };
        let base_edges = generate::barabasi_albert(6_000, 4, 0.6, 51);
        let (base_graph, _) = DynamicGraph::from_edges(base_edges.iter().copied());
        let streams: Vec<(&str, Vec<veilgraph::stream::event::EdgeOp>)> = vec![
            ("a8_powerlaw_growth", powerlaw_growth_stream(&base_graph, 2_000, 0.3, 9)),
            ("a8_erdos_renyi", er_stream(6_000, 2_000, 9)),
            ("a8_sliding_window", {
                let extra: Vec<(u64, u64)> =
                    er_stream(6_000, 1_000, 10).iter().filter_map(|op| match op {
                        veilgraph::stream::event::EdgeOp::AddEdge(u, v) => Some((*u, *v)),
                        _ => None,
                    }).collect();
                sliding_window_stream(&extra, 300)
            }),
        ];
        for (label, ops) in streams {
            let mut engine = EngineBuilder::new()
                .params(SummaryParams::new(0.2, 1, 0.1))
                .udf(Box::new(AlwaysApproximate))
                .pagerank(cfg)
                .build_from_edges(base_edges.iter().copied())
                .unwrap();
            let mut events: Vec<UpdateEvent> = Vec::new();
            let q = 10;
            for (i, op) in ops.iter().enumerate() {
                events.push(UpdateEvent::Op(*op));
                if (i + 1) % (ops.len() / q).max(1) == 0 {
                    events.push(UpdateEvent::Query);
                }
            }
            let rs = engine.run_stream(events).unwrap();
            let k_avg: f64 = rs.iter().map(|r| r.exec.summary_vertices as f64).sum::<f64>()
                / rs.len().max(1) as f64;
            let t_avg: f64 =
                rs.iter().map(|r| r.exec.elapsed_secs).sum::<f64>() / rs.len().max(1) as f64;
            println!(
                "{label}: {} queries, avg |K| {k_avg:.0}, avg query {:.2}ms, final |V| {}",
                rs.len(),
                t_avg * 1e3,
                engine.graph().num_vertices()
            );
        }
    }

    // ================= A9: parallel sharding ============================
    println!("\n== A9: serial vs degree-balanced sharded executors ==");
    {
        let pool = ThreadPool::with_default_size();
        let ten = PageRankConfig { max_iters: 10, epsilon: 0.0, ..cfg };
        let t_serial = b.bench("a9_exact_serial_10iters", || PageRank::new(ten).run(&csr));
        let t_serial = t_serial.median_secs();
        for shards in [2usize, 4, 8] {
            let pcfg = PageRankConfig { parallelism: shards, ..ten };
            let r = b.bench(&format!("a9_exact_par{shards}_10iters"), || {
                PageRank::new(pcfg).run_parallel(&csr, &pool)
            });
            println!("a9 exact par{shards}: {:.2}x vs serial", t_serial / r.median_secs());
        }
        // numerics agree exactly (fixed iteration count)
        let serial = PageRank::new(ten).run(&csr).ranks;
        let pcfg = PageRankConfig { parallelism: 4, ..ten };
        let par = PageRank::new(pcfg).run_parallel(&csr, &pool).ranks;
        let max_diff =
            serial.iter().zip(&par).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        println!("a9 max |serial - par4| = {max_diff:.2e} (must be 0)");
        // summarized executor over the A1 summary
        let t_sparse = b.bench("a9_summarized_serial", || run_summarized(&summary, &cfg));
        let t_sparse = t_sparse.median_secs();
        let p4 = PageRankConfig { parallelism: 4, ..cfg };
        let r = b.bench("a9_summarized_par4", || {
            veilgraph::pagerank::summarized::run_summarized_parallel(&summary, &p4, &pool)
        });
        println!("a9 summarized par4: {:.2}x vs serial", t_sparse / r.median_secs());
    }

    println!("\n{}", b.report());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/ablation_bench.csv", b.to_csv()).expect("write csv");
    println!("CSV written to results/ablation_bench.csv");
}
