//! `cargo bench --bench figures` — regenerates every evaluation artifact
//! of the paper: Table 1 and Figures 3–30 (7 datasets × {vertex ratio,
//! edge ratio, RBO, speedup}), writing CSVs + quick-look ASCII plots to
//! `results/` and a summary to stdout.
//!
//! Scale: `VEILGRAPH_SCALE` env var (default 0.1 ⇒ ~1/10 of the
//! DESIGN.md Table 1b stand-in sizes, minutes not hours;
//! `VEILGRAPH_SCALE=1.0` reproduces the full stand-ins). The parameter
//! grid is always the paper's full 18 combinations.

use veilgraph::experiments::datasets::{all_datasets, table1};
use veilgraph::experiments::figures::{figure_summary, figures_for_dataset};
use veilgraph::experiments::harness::{run_experiment, HarnessConfig, Metric};
use veilgraph::experiments::report::{headline, markdown_rows, write_experiment};
use veilgraph::util::timer::{fmt_duration, Stopwatch};

fn main() {
    let scale: f64 = std::env::var("VEILGRAPH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let cfg = HarnessConfig::default();
    println!("== VeilGraph figure regeneration (scale {scale}, Q={}, 18 combos) ==\n", cfg.q);
    println!("Table 1 (paper vs stand-ins):\n{}", table1(scale));

    let total = Stopwatch::start();
    let mut md = String::new();
    let mut headline_best = (0.0f64, 0.0f64);
    for spec in all_datasets() {
        let sw = Stopwatch::start();
        let edges = spec.generate(scale);
        let result = run_experiment(
            spec.name,
            &edges,
            spec.stream_len_at(scale),
            spec.shuffled,
            &cfg,
        )
        .expect("experiment failed");
        write_experiment("results", &result).expect("write results");
        let (speedup, rbo) = headline(&result);
        if speedup > headline_best.0 {
            headline_best = (speedup, rbo);
        }
        println!(
            "\n-- {} (paper: {}) done in {} --",
            spec.name,
            spec.paper_name,
            fmt_duration(sw.secs())
        );
        for fig in figures_for_dataset(spec.name) {
            println!("{}", figure_summary(&fig, &result));
        }
        // paper-shape checks, printed not asserted (bench, not test)
        let best_rbo = result.ranked(Metric::Rbo)[0].avg(Metric::Rbo);
        let best_speedup = result.ranked(Metric::Speedup)[0].avg(Metric::Speedup);
        println!(
            "   paper-shape: best RBO {best_rbo:.4} (paper: >0.95 achievable), \
             best speedup {best_speedup:.2}x (paper: 3-4x+)"
        );
        md.push_str(&markdown_rows(&result));
    }
    println!("\n== all figures regenerated in {} ==", fmt_duration(total.secs()));
    println!("headline: best speedup {:.2}x at RBO {:.4}", headline_best.0, headline_best.1);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/figures_summary.md", md).expect("write summary");
    println!("CSVs + quicklooks in results/, markdown in results/figures_summary.md");
}
