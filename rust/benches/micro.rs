//! `cargo bench --bench micro` — hot-path micro-benchmarks (§Perf):
//! exact PageRank iteration, hot-set selection, summary construction,
//! densification, sparse summarized run, XLA execute round-trip, RBO,
//! CSR snapshot, top-k. Results feed EXPERIMENTS.md §Perf.

use std::collections::HashMap;

use veilgraph::bench::{BenchConfig, Bencher};
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::generate;
use veilgraph::metrics::ranking::top_k_ids;
use veilgraph::metrics::rbo::rbo_ext;
use veilgraph::pagerank::power::{PageRank, PageRankConfig};
use veilgraph::pagerank::summarized::run_summarized;
use veilgraph::runtime::artifact::Variant;
use veilgraph::runtime::client::XlaRuntime;
use veilgraph::summary::bigvertex::SummaryGraph;
use veilgraph::summary::hot::{compute_hot_set, HotSet, HotSetInputs};
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::rng::Xoshiro256pp;
use veilgraph::util::threadpool::ThreadPool;

fn main() {
    let mut b = Bencher::with_config(BenchConfig { warmup: 2, iters: 12, min_secs: 0.2 });

    // -- substrate: a mid-size web graph -------------------------------
    let edges = generate::copying_web(50_000, 10, 0.7, 42);
    let (graph, _) = DynamicGraph::from_edges(edges.iter().copied());
    let csr = graph.snapshot();
    let n = graph.num_vertices();
    println!("workload: copying-web |V|={n} |E|={}\n", graph.num_edges());

    b.bench("csr_snapshot_50k", || graph.snapshot());

    let pr = PageRank::new(PageRankConfig { epsilon: 0.0, max_iters: 1, ..Default::default() });
    b.bench("pagerank_1iter_50k", || pr.run(&csr));

    let pr_full =
        PageRank::new(PageRankConfig { epsilon: 1e-8, max_iters: 100, ..Default::default() });
    let full = pr_full.run(&csr);
    println!("  (full exact run: {} iterations)\n", full.iterations);
    b.bench("pagerank_converged_50k", || pr_full.run(&csr));

    // -- serial vs sharded parallel exact PageRank ----------------------
    // Fixed iteration count so every configuration does identical work;
    // the speedup line is the tentpole number ROADMAP tracks.
    let pool = ThreadPool::with_default_size();
    println!("  (pool: {} workers)\n", pool.size());
    let ten = PageRankConfig { epsilon: 0.0, max_iters: 10, ..Default::default() };
    let serial_t = b.bench("pagerank_10iter_serial", || PageRank::new(ten).run(&csr)).median_secs();
    let mut speedup_at_4 = 0.0f64;
    for shards in [2usize, 4, 8] {
        let cfg = PageRankConfig { parallelism: shards, ..ten };
        let name = format!("pagerank_10iter_par{shards}");
        let t = b.bench(&name, || PageRank::new(cfg).run_parallel(&csr, &pool)).median_secs();
        let speedup = serial_t / t;
        if shards == 4 {
            speedup_at_4 = speedup;
        }
        println!("  ({name}: {speedup:.2}x vs serial)");
    }
    println!("  (serial-vs-parallel speedup at 4 shards: {speedup_at_4:.2}x)\n");

    // -- hot-set selection over a realistic update batch ----------------
    let mut prev_degree: HashMap<u64, usize> = HashMap::new();
    let mut rng = Xoshiro256pp::new(9);
    for _ in 0..800 {
        let id = rng.next_below(n as u64);
        if let Some(idx) = graph.index(id) {
            prev_degree.insert(id, graph.degree(idx).saturating_sub(2).max(1));
        }
    }
    let params = SummaryParams::new(0.1, 1, 0.1);
    let inputs = HotSetInputs {
        graph: &graph,
        prev_degree: &prev_degree,
        new_vertices: &[],
        prev_ranks: &full.ranks,
    };
    let hot = compute_hot_set(&inputs, &params);
    println!(
        "  (hot set: |K_r|={} |K_n|={} |K_Δ|={})\n",
        hot.k_r.len(),
        hot.k_n.len(),
        hot.k_delta.len()
    );
    b.bench("hot_set_800_touched", || compute_hot_set(&inputs, &params));

    // -- summary build + executors --------------------------------------
    b.bench("summary_build", || SummaryGraph::build(&graph, &hot, &full.ranks, 1.0));
    let summary = SummaryGraph::build(&graph, &hot, &full.ranks, 1.0);
    println!(
        "  (summary: |K|={} |E_K|={} |E_B|={})\n",
        summary.num_vertices(),
        summary.num_internal_edges(),
        summary.num_boundary_edges
    );
    let cfg = PageRankConfig { epsilon: 1e-8, max_iters: 100, ..Default::default() };
    b.bench("summarized_sparse", || run_summarized(&summary, &cfg));
    let par_cfg = PageRankConfig { parallelism: 4, ..cfg };
    b.bench("summarized_sparse_par4", || {
        veilgraph::pagerank::summarized::run_summarized_parallel(&summary, &par_cfg, &pool)
    });

    // -- XLA path (capacity-tiered) --------------------------------------
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let skip_xla = std::env::var("VEILGRAPH_SKIP_XLA").is_ok();
    if !skip_xla && artifacts.join("manifest.json").is_file() {
        let mut rt = XlaRuntime::new(&artifacts).unwrap();
        for cap in [128usize, 512, 2048] {
            rt.ensure_tier(Variant::Run, cap).unwrap();
            // synthetic dense problem at this capacity
            let k = cap * 3 / 4;
            let mut hs = HotSet::default();
            hs.hot = vec![false; n];
            let dense = {
                // random small summary padded to cap
                let mut rng = Xoshiro256pp::new(cap as u64);
                let mut a = vec![0.0f32; cap * cap];
                for _ in 0..(k * 8) {
                    let z = rng.range(0, k);
                    let u = rng.range(0, k);
                    a[z * cap + u] = 0.125;
                }
                let r = vec![1.0f32; cap];
                let b = vec![0.1f32; cap];
                let mut mask = vec![0.0f32; cap];
                for m in mask.iter_mut().take(k) {
                    *m = 1.0;
                }
                (a, r, b, mask)
            };
            let _ = hs;
            b.bench(&format!("xla_run10_c{cap}"), || {
                rt.execute(Variant::Run, cap, &dense.0, &dense.1, &dense.2, &dense.3, 0.85, 0.15)
                    .unwrap()
            });
            // §Perf runtime-1: device-resident constants, only r uploaded.
            let prepared = rt.prepare_dense(cap, &dense.0, &dense.2, &dense.3, 0.85, 0.15).unwrap();
            b.bench(&format!("xla_run10_prepared_c{cap}"), || {
                rt.execute_prepared(Variant::Run, &prepared, &dense.1).unwrap()
            });
        }
    } else if skip_xla {
        println!("(VEILGRAPH_SKIP_XLA set — skipping XLA benches)");
    } else {
        println!("(artifacts/ missing — skipping XLA benches; run `make artifacts`)");
    }

    // -- metrics ----------------------------------------------------------
    let ids: Vec<u64> = (0..n as u64).collect();
    b.bench("top_k_4000_of_50k", || top_k_ids(&ids, &full.ranks, 4000));
    let ranking_a = top_k_ids(&ids, &full.ranks, 4000);
    let mut ranking_b = ranking_a.clone();
    ranking_b.swap(10, 500);
    ranking_b.swap(3, 7);
    b.bench("rbo_ext_4000", || rbo_ext(&ranking_a, &ranking_b, 0.99));

    println!("{}", b.report());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/micro_bench.csv", b.to_csv()).expect("write csv");
    println!("CSV written to results/micro_bench.csv");
}
