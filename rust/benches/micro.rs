//! `cargo bench --bench micro` — hot-path micro-benchmarks (§Perf):
//! exact PageRank iteration, snapshot pipeline (serial / parallel /
//! cached / incremental), hot-set selection and summary construction
//! (serial vs sharded, scratch-recycling), densification, sparse
//! summarized run, XLA execute round-trip, RBO, top-k. Results feed
//! EXPERIMENTS.md §Perf and — merged with the serving bench — the CI
//! `bench` job's `BENCH_4.json` perf-trajectory artifact
//! (results/micro_bench.json).

use std::collections::HashMap;

use veilgraph::bench::{BenchConfig, Bencher};
use veilgraph::graph::csr::Csr;
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::generate;
use veilgraph::graph::snapshot::SnapshotCache;
use veilgraph::metrics::ranking::top_k_ids;
use veilgraph::metrics::rbo::rbo_ext;
use veilgraph::pagerank::power::{PageRank, PageRankConfig};
use veilgraph::pagerank::summarized::run_summarized;
use veilgraph::runtime::artifact::Variant;
use veilgraph::runtime::client::XlaRuntime;
use veilgraph::summary::bigvertex::SummaryGraph;
use veilgraph::summary::hot::{compute_hot_set, compute_hot_set_pooled, HotSet, HotSetInputs};
use veilgraph::summary::params::SummaryParams;
use veilgraph::summary::scratch::SummaryScratch;
use veilgraph::util::json::Json;
use veilgraph::util::rng::Xoshiro256pp;
use veilgraph::util::threadpool::ThreadPool;

fn main() {
    let mut b = Bencher::with_config(BenchConfig { warmup: 2, iters: 12, min_secs: 0.2 });

    // -- substrate: a mid-size web graph -------------------------------
    let edges = generate::copying_web(50_000, 10, 0.7, 42);
    let (graph, _) = DynamicGraph::from_edges(edges.iter().copied());
    let csr = graph.snapshot();
    let n = graph.num_vertices();
    println!("workload: copying-web |V|={n} |E|={}\n", graph.num_edges());

    // One pool for every sharded bench — the engine architecture (shard
    // counts above the worker count just queue; no extra threads exist).
    let pool = ThreadPool::with_default_size();
    println!("  (pool: {} workers)\n", pool.size());

    // -- snapshot pipeline: serial vs parallel vs cached vs incremental --
    let snap_serial_t = b.bench("csr_snapshot_50k", || graph.snapshot()).median_secs();
    let mut snap_speedup_at_4 = 0.0f64;
    for shards in [2usize, 4, 8] {
        let name = format!("csr_snapshot_50k_par{shards}");
        let t = b.bench(&name, || graph.snapshot_with(Some(&pool), shards)).median_secs();
        let speedup = snap_serial_t / t;
        if shards == 4 {
            snap_speedup_at_4 = speedup;
        }
        println!("  ({name}: {speedup:.2}x vs serial)");
    }
    println!("  (snapshot-build speedup at 4 shards: {snap_speedup_at_4:.2}x)\n");

    // Cache hit: a repeat query on an unmutated graph — zero allocations.
    let mut cache = SnapshotCache::new();
    let _ = cache.get(&graph, None, 1);
    b.bench("csr_snapshot_cached_hit", || cache.get(&graph, None, 1).0);

    // Incremental: ~500 dirty rows against a fixed previous snapshot.
    // The toggles are applied ONCE, outside the timed closure, so the
    // number is the pure rebuild cost and compares against
    // csr_snapshot_50k directly.
    let mut live = graph.clone();
    let v0 = live.version();
    let base_csr = live.snapshot();
    let mut rng_inc = Xoshiro256pp::new(77);
    for _ in 0..500 {
        let u = rng_inc.next_below(n as u64);
        let v = rng_inc.next_below(n as u64);
        if live.has_edge(u, v) {
            live.remove_edge(u, v).unwrap();
        } else {
            let _ = live.add_edge(u, v);
        }
    }
    let inc_t = b
        .bench("csr_snapshot_incremental_500", || live.snapshot_from(&base_csr, v0, None, 1))
        .median_secs();
    println!("  (csr_snapshot_incremental_500: {:.2}x vs full serial)\n", snap_serial_t / inc_t);

    // Parallel counting-sort edge-list build.
    let dense_edges: Vec<(u32, u32)> = graph.edges().collect();
    let fe_serial_t =
        b.bench("csr_from_edges_50k", || Csr::from_edges(n, &dense_edges)).median_secs();
    let fe_par_t = b
        .bench("csr_from_edges_50k_par4", || {
            Csr::from_edges_pooled(n, &dense_edges, Some(&pool), 4)
        })
        .median_secs();
    println!("  (csr_from_edges_50k_par4: {:.2}x vs serial)\n", fe_serial_t / fe_par_t);

    let pr = PageRank::new(PageRankConfig { epsilon: 0.0, max_iters: 1, ..Default::default() });
    b.bench("pagerank_1iter_50k", || pr.run(&csr));

    let pr_full =
        PageRank::new(PageRankConfig { epsilon: 1e-8, max_iters: 100, ..Default::default() });
    let full = pr_full.run(&csr);
    println!("  (full exact run: {} iterations)\n", full.iterations);
    b.bench("pagerank_converged_50k", || pr_full.run(&csr));

    // -- serial vs sharded parallel exact PageRank ----------------------
    // Fixed iteration count so every configuration does identical work;
    // the speedup line is the tentpole number ROADMAP tracks.
    let ten = PageRankConfig { epsilon: 0.0, max_iters: 10, ..Default::default() };
    let serial_t = b.bench("pagerank_10iter_serial", || PageRank::new(ten).run(&csr)).median_secs();
    let mut speedup_at_4 = 0.0f64;
    for shards in [2usize, 4, 8] {
        let cfg = PageRankConfig { parallelism: shards, ..ten };
        let name = format!("pagerank_10iter_par{shards}");
        let t = b.bench(&name, || PageRank::new(cfg).run_parallel(&csr, &pool)).median_secs();
        let speedup = serial_t / t;
        if shards == 4 {
            speedup_at_4 = speedup;
        }
        println!("  ({name}: {speedup:.2}x vs serial)");
    }
    println!("  (serial-vs-parallel speedup at 4 shards: {speedup_at_4:.2}x)\n");

    // -- hot-set selection over a realistic update batch ----------------
    let mut prev_degree: HashMap<u64, usize> = HashMap::new();
    let mut rng = Xoshiro256pp::new(9);
    for _ in 0..800 {
        let id = rng.next_below(n as u64);
        if let Some(idx) = graph.index(id) {
            prev_degree.insert(id, graph.degree(idx).saturating_sub(2).max(1));
        }
    }
    let params = SummaryParams::new(0.1, 1, 0.1);
    let inputs = HotSetInputs {
        graph: &graph,
        prev_degree: &prev_degree,
        new_vertices: &[],
        prev_ranks: &full.ranks,
    };
    let hot = compute_hot_set(&inputs, &params);
    println!(
        "  (hot set: |K_r|={} |K_n|={} |K_Δ|={})\n",
        hot.k_r.len(),
        hot.k_n.len(),
        hot.k_delta.len()
    );
    let hot_serial_t =
        b.bench("hot_set_800_touched", || compute_hot_set(&inputs, &params)).median_secs();
    // Sharded + scratch-recycling twin: one long-lived workspace, zero
    // O(|V|) allocations per call after the first (the engine shape).
    let mut scratch = SummaryScratch::new();
    let mut hot_speedup_at_4 = 0.0f64;
    for shards in [2usize, 4, 8] {
        let name = format!("hot_set_800_touched_par{shards}");
        let t = b
            .bench(&name, || {
                let hs =
                    compute_hot_set_pooled(&inputs, &params, &mut scratch, Some(&pool), shards);
                let k = hs.len();
                scratch.recycle_hot(hs);
                k
            })
            .median_secs();
        let speedup = hot_serial_t / t;
        if shards == 4 {
            hot_speedup_at_4 = speedup;
        }
        println!("  ({name}: {speedup:.2}x vs serial)");
    }
    println!("  (hot-set speedup at 4 shards: {hot_speedup_at_4:.2}x)\n");

    // -- summary build + executors --------------------------------------
    let sb_serial_t = b
        .bench("summary_build", || SummaryGraph::build(&graph, &hot, &full.ranks, 1.0))
        .median_secs();
    let mut sb_speedup_at_4 = 0.0f64;
    for shards in [2usize, 4, 8] {
        let name = format!("summary_build_par{shards}");
        let t = b
            .bench(&name, || {
                SummaryGraph::build_pooled(
                    &graph,
                    &hot,
                    &full.ranks,
                    1.0,
                    &mut scratch,
                    Some(&pool),
                    shards,
                )
            })
            .median_secs();
        let speedup = sb_serial_t / t;
        if shards == 4 {
            sb_speedup_at_4 = speedup;
        }
        println!("  ({name}: {speedup:.2}x vs serial)");
    }
    println!("  (summary-build speedup at 4 shards: {sb_speedup_at_4:.2}x)\n");
    let summary = SummaryGraph::build(&graph, &hot, &full.ranks, 1.0);
    println!(
        "  (summary: |K|={} |E_K|={} |E_B|={})\n",
        summary.num_vertices(),
        summary.num_internal_edges(),
        summary.num_boundary_edges
    );
    let cfg = PageRankConfig { epsilon: 1e-8, max_iters: 100, ..Default::default() };
    b.bench("summarized_sparse", || run_summarized(&summary, &cfg));
    let par_cfg = PageRankConfig { parallelism: 4, ..cfg };
    b.bench("summarized_sparse_par4", || {
        veilgraph::pagerank::summarized::run_summarized_parallel(&summary, &par_cfg, &pool)
    });

    // -- XLA path (capacity-tiered) --------------------------------------
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let skip_xla = std::env::var("VEILGRAPH_SKIP_XLA").is_ok();
    if !skip_xla && artifacts.join("manifest.json").is_file() {
        let mut rt = XlaRuntime::new(&artifacts).unwrap();
        for cap in [128usize, 512, 2048] {
            rt.ensure_tier(Variant::Run, cap).unwrap();
            // synthetic dense problem at this capacity
            let k = cap * 3 / 4;
            let mut hs = HotSet::default();
            hs.hot = vec![false; n];
            let dense = {
                // random small summary padded to cap
                let mut rng = Xoshiro256pp::new(cap as u64);
                let mut a = vec![0.0f32; cap * cap];
                for _ in 0..(k * 8) {
                    let z = rng.range(0, k);
                    let u = rng.range(0, k);
                    a[z * cap + u] = 0.125;
                }
                let r = vec![1.0f32; cap];
                let b = vec![0.1f32; cap];
                let mut mask = vec![0.0f32; cap];
                for m in mask.iter_mut().take(k) {
                    *m = 1.0;
                }
                (a, r, b, mask)
            };
            let _ = hs;
            b.bench(&format!("xla_run10_c{cap}"), || {
                rt.execute(Variant::Run, cap, &dense.0, &dense.1, &dense.2, &dense.3, 0.85, 0.15)
                    .unwrap()
            });
            // §Perf runtime-1: device-resident constants, only r uploaded.
            let prepared = rt.prepare_dense(cap, &dense.0, &dense.2, &dense.3, 0.85, 0.15).unwrap();
            b.bench(&format!("xla_run10_prepared_c{cap}"), || {
                rt.execute_prepared(Variant::Run, &prepared, &dense.1).unwrap()
            });
        }
    } else if skip_xla {
        println!("(VEILGRAPH_SKIP_XLA set — skipping XLA benches)");
    } else {
        println!("(artifacts/ missing — skipping XLA benches; run `make artifacts`)");
    }

    // -- metrics ----------------------------------------------------------
    let ids: Vec<u64> = (0..n as u64).collect();
    b.bench("top_k_4000_of_50k", || top_k_ids(&ids, &full.ranks, 4000));
    let ranking_a = top_k_ids(&ids, &full.ranks, 4000);
    let mut ranking_b = ranking_a.clone();
    ranking_b.swap(10, 500);
    ranking_b.swap(3, 7);
    b.bench("rbo_ext_4000", || rbo_ext(&ranking_a, &ranking_b, 0.99));

    println!("{}", b.report());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/micro_bench.csv", b.to_csv()).expect("write csv");
    println!("CSV written to results/micro_bench.csv");

    // Machine-readable perf trajectory — the serving bench merges this
    // into bench_4.json, which the CI bench job uploads as BENCH_4.json
    // so speedups are tracked across PRs.
    let mut benches = std::collections::BTreeMap::new();
    for r in b.results() {
        benches.insert(
            r.name.clone(),
            Json::obj(vec![
                ("median_secs", Json::Num(r.summary.p50)),
                ("mean_secs", Json::Num(r.summary.mean)),
                ("iters", Json::Num(r.samples.len() as f64)),
            ]),
        );
    }
    let doc = Json::obj(vec![
        (
            "workload",
            Json::obj(vec![
                ("graph", Json::Str("copying-web".into())),
                ("vertices", Json::Num(n as f64)),
                ("edges", Json::Num(graph.num_edges() as f64)),
            ]),
        ),
        ("pool_workers", Json::Num(pool.size() as f64)),
        (
            "speedups",
            Json::obj(vec![
                ("pagerank_10iter_par4_vs_serial", Json::Num(speedup_at_4)),
                ("snapshot_par4_vs_serial", Json::Num(snap_speedup_at_4)),
                ("hot_set_par4_vs_serial", Json::Num(hot_speedup_at_4)),
                ("summary_build_par4_vs_serial", Json::Num(sb_speedup_at_4)),
            ]),
        ),
        ("benches", Json::Obj(benches)),
    ]);
    std::fs::write("results/micro_bench.json", doc.to_string_pretty()).expect("write json");
    println!("JSON written to results/micro_bench.json");
}
