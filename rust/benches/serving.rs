//! `cargo bench --bench serving` — serving benchmarks for the read/write
//! split and the readiness-loop front end.
//!
//! * **Throughput**: N concurrent readers × 1 writer, read-queries/sec
//!   with reads serialized through the engine command queue (the old
//!   architecture) vs reads off the published snapshot (the split).
//! * **Saturation**: a wire-level scenario — a mostly-idle slow-client
//!   swarm, a hot batch writer, and continuous off-thread recomputes —
//!   measuring one fast client's read throughput and latency against the
//!   same client on an idle server (`serve_saturated_vs_idle`,
//!   `recompute_overlap_read_p99`).
//!
//! * **Push plane**: publish cost with N standing subscriptions
//!   registered, worst-case diffs where every publish flips top-K, rank
//!   and hot-set membership (`publish_subs{1,64,1024}`).
//!
//! * **Sharded scale-out**: the same mixed mutation + recompute stream
//!   absorbed by a 2- and a 4-shard cluster vs the single engine
//!   (`sharded2_vs_single`, `sharded4_vs_single`).
//!
//! * **Recompute plane**: one full cross-shard exchange serial vs on a
//!   4-worker pool, asserted bit-identical before timing
//!   (`exchange_par4_vs_serial`); a one-dirty-shard plan rebuild vs a
//!   fresh full build (`plan_reuse_vs_rebuild`); and the saturation
//!   scenario asserting `recompute_fence_misses` ≈ 0 with fence
//!   reconciliation on.
//!
//! Emits `results/serving_bench.json` and — when the micro bench ran
//! first (CI does) — merges its numbers into `results/bench_4.json`,
//! which the ingest bench folds into the final BENCH_10 perf-trajectory
//! artifact.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::server::{serve, ServeOptions, ServerHandle};
use veilgraph::coordinator::serving::{RankSnapshot, SnapshotPublisher};
use veilgraph::coordinator::sharded::ShardedEngineBuilder;
use veilgraph::coordinator::subscription::{Mailbox, Subscription};
use veilgraph::coordinator::udf::{Action, ExecStats};
use veilgraph::graph::dynamic::DynamicGraph;
use veilgraph::graph::generate;
use veilgraph::graph::partition::Partitioner;
use veilgraph::pagerank::power::PageRankConfig;
use veilgraph::pagerank::sharded::{run_exchange_pooled, ExchangeScratch, ShardPlan};
use veilgraph::stream::backpressure::OverflowPolicy;
use veilgraph::stream::event::EdgeOp;
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::json::Json;
use veilgraph::util::threadpool::ThreadPool;

const READ_K: usize = 100;
const MEASURE_SECS: f64 = 1.5;
const SWARM_CONNS: usize = 48;
const SATURATION_MEASURE_SECS: f64 = 1.5;

/// Fresh vertex ids across every mode, so each mode's mutations are real
/// (a repeated id range would be skipped as duplicates and flatten the
/// writer load for later modes).
static NEXT_VERTEX: AtomicU64 = AtomicU64::new(1_000_000);

/// Read-queries/sec with `readers` concurrent reader threads and one
/// writer continuously ingesting + recomputing. `split == false` sends
/// every read through the engine command queue (each read is a full
/// engine query); `split == true` serves reads from the published
/// snapshot.
fn throughput(handle: &Arc<ServerHandle>, readers: usize, split: bool) -> f64 {
    let stop = Arc::new(AtomicBool::new(false));
    let total = Arc::new(AtomicU64::new(0));

    // 1 writer: a steady mutation + recompute load.
    let writer = {
        let h = Arc::clone(handle);
        let stop2 = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                for _ in 0..64 {
                    let next = NEXT_VERTEX.fetch_add(1, Ordering::Relaxed);
                    let _ = h.ingest(EdgeOp::add(next, next % 50_000));
                }
                let _ = h.query();
            }
        })
    };

    let mut threads = Vec::new();
    for _ in 0..readers {
        let h = Arc::clone(handle);
        let stop2 = Arc::clone(&stop);
        let total2 = Arc::clone(&total);
        threads.push(std::thread::spawn(move || {
            let reader = h.reader();
            let mut count = 0u64;
            while !stop2.load(Ordering::Relaxed) {
                if split {
                    let top = reader.top(READ_K);
                    assert!(!top.is_empty());
                } else {
                    let top = h.query().expect("queued read").top(READ_K);
                    assert!(!top.is_empty());
                }
                count += 1;
            }
            total2.fetch_add(count, Ordering::Relaxed);
        }));
    }

    let t0 = Instant::now();
    std::thread::sleep(Duration::from_secs_f64(MEASURE_SECS));
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        t.join().unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    writer.join().unwrap();
    total.load(Ordering::Relaxed) as f64 / elapsed
}

fn percentile(mut xs: Vec<f64>, p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((xs.len() - 1) as f64 * p).round() as usize;
    xs[idx]
}

fn wire_send(c: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> String {
    c.write_all(req.as_bytes()).unwrap();
    c.write_all(b"\n").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line
}

/// Sequential wire reads (`top`) on one fresh connection for `secs`.
/// Returns reads/sec plus every per-request round-trip latency.
fn wire_read_rate(addr: std::net::SocketAddr, secs: f64) -> (f64, Vec<f64>) {
    let mut c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut r = BufReader::new(c.try_clone().unwrap());
    let req = format!("{{\"op\":\"top\",\"k\":{READ_K}}}");
    let mut lats = Vec::new();
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < secs {
        let q0 = Instant::now();
        let line = wire_send(&mut c, &mut r, &req);
        lats.push(q0.elapsed().as_secs_f64());
        assert!(line.contains("\"ok\":true"), "read failed under load: {line}");
    }
    (lats.len() as f64 / t0.elapsed().as_secs_f64(), lats)
}

/// Wire-level saturation: `SWARM_CONNS` slow clients poking the server,
/// one hot batch writer, and a query client forcing continuous
/// off-thread recomputes — all against the readiness loop, while one
/// fast client measures read throughput and latency. Returns
/// (idle reads/sec, saturated reads/sec, saturated p99 latency secs).
fn saturation(addr: std::net::SocketAddr) -> (f64, f64, f64) {
    // Baseline: the fast client alone on an idle server.
    let (idle_rps, _) = wire_read_rate(addr, 1.0);

    let stop = Arc::new(AtomicBool::new(false));
    let mut load = Vec::new();
    // Slow swarm: mostly-idle connections that each read every ~100 ms.
    for _ in 0..4 {
        let stop2 = Arc::clone(&stop);
        load.push(std::thread::spawn(move || {
            let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> = (0..SWARM_CONNS / 4)
                .map(|_| {
                    let c = TcpStream::connect(addr).unwrap();
                    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                    let r = BufReader::new(c.try_clone().unwrap());
                    (c, r)
                })
                .collect();
            while !stop2.load(Ordering::Relaxed) {
                for (c, r) in &mut conns {
                    let line = wire_send(c, r, "{\"op\":\"rank\",\"id\":1}");
                    assert!(line.contains("\"ok\":true"), "swarm read failed: {line}");
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }));
    }
    // Hot writer: 256-op batch lines back to back.
    {
        let stop2 = Arc::clone(&stop);
        load.push(std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let mut r = BufReader::new(c.try_clone().unwrap());
            while !stop2.load(Ordering::Relaxed) {
                let base = NEXT_VERTEX.fetch_add(256, Ordering::Relaxed);
                let ops: Vec<String> = (base..base + 256)
                    .map(|i| format!("{{\"op\":\"add\",\"src\":{},\"dst\":{}}}", i, i % 50_000))
                    .collect();
                let req = format!("{{\"op\":\"batch\",\"ops\":[{}]}}", ops.join(","));
                let line = wire_send(&mut c, &mut r, &req);
                assert!(line.contains("\"ok\":"), "writer got no answer: {line}");
            }
        }));
    }
    // Query client: keeps a recompute in flight for most of the window.
    {
        let stop2 = Arc::clone(&stop);
        load.push(std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let mut r = BufReader::new(c.try_clone().unwrap());
            while !stop2.load(Ordering::Relaxed) {
                let _ = wire_send(&mut c, &mut r, "{\"op\":\"query\",\"top\":10}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }));
    }

    // Let the load ramp, then measure the fast client under saturation.
    std::thread::sleep(Duration::from_millis(200));
    let (sat_rps, sat_lats) = wire_read_rate(addr, SATURATION_MEASURE_SECS);
    stop.store(true, Ordering::Relaxed);
    for t in load {
        t.join().unwrap();
    }
    (idle_rps, sat_rps, percentile(sat_lats, 0.99))
}

const SHARDED_TOTAL_OPS: usize = 1 << 18;
const SHARDED_BATCH: usize = 4_096;

/// Deterministic mixed mutation stream, identical for every mode:
/// fresh-vertex adds against the 50k base id space with every fourth op
/// removing the edge added two ops earlier, cut into
/// [`SHARDED_BATCH`]-op batches.
fn sharded_stream(total: usize) -> Vec<Vec<EdgeOp>> {
    let mut out = Vec::new();
    let mut batch = Vec::with_capacity(SHARDED_BATCH);
    for i in 0..total as u64 {
        batch.push(if i % 4 == 3 {
            EdgeOp::remove(2_000_000 + i - 2, (i - 2).wrapping_mul(17) % 50_000)
        } else {
            EdgeOp::add(2_000_000 + i, i.wrapping_mul(17) % 50_000)
        });
        if batch.len() == SHARDED_BATCH {
            out.push(std::mem::take(&mut batch));
        }
    }
    if !batch.is_empty() {
        out.push(batch);
    }
    out
}

/// Ops/sec absorbing the pre-generated stream batch by batch, with one
/// blocking recompute mid-stream and one at the end. `shards == 1`
/// drives the single engine through its adaptive query path; a cluster
/// always runs the exact cross-shard boundary exchange (the
/// conservative side of the comparison).
fn sharded_absorb_rate(shards: usize, edges: Vec<(u64, u64)>, batches: &[Vec<EdgeOp>]) -> f64 {
    let total: usize = batches.iter().map(Vec::len).sum();
    let mid = batches.len() / 2;
    if shards == 1 {
        let mut e = EngineBuilder::new().build_from_edges(edges).expect("build engine");
        let t0 = Instant::now();
        for (i, b) in batches.iter().enumerate() {
            e.ingest_batch(b.iter().copied());
            e.flush_pending();
            if i == mid {
                e.query().expect("single query");
            }
        }
        e.query().expect("single query");
        total as f64 / t0.elapsed().as_secs_f64()
    } else {
        let mut e =
            ShardedEngineBuilder::new(shards).build_from_edges(edges).expect("build cluster");
        let t0 = Instant::now();
        for (i, b) in batches.iter().enumerate() {
            e.ingest_batch(b.iter().copied());
            e.flush_pending();
            if i == mid {
                e.query().expect("cluster query");
            }
        }
        e.query().expect("cluster query");
        total as f64 / t0.elapsed().as_secs_f64()
    }
}

const EXCHANGE_SHARDS: usize = 4;
const EXCHANGE_RUNS: usize = 5;

/// Route an edge list into per-shard graphs — the sharded engine's
/// build path, minus the engine.
fn shard_graphs(edges: &[(u64, u64)], shards: usize) -> (Vec<DynamicGraph>, Partitioner) {
    let parts = Partitioner::new(shards);
    let ops: Vec<EdgeOp> = edges.iter().map(|&(s, d)| EdgeOp::add(s, d)).collect();
    let routed = parts.route(&ops);
    let mut graphs: Vec<DynamicGraph> = (0..shards).map(|_| DynamicGraph::new()).collect();
    for (g, ops) in graphs.iter_mut().zip(&routed) {
        g.apply_batch(ops, None, 1);
    }
    (graphs, parts)
}

/// Median wall seconds per full exchange over [`EXCHANGE_RUNS`] runs,
/// reusing one scratch (the engine's steady state).
fn time_exchange(plan: &ShardPlan, pool: Option<&ThreadPool>) -> f64 {
    let cfg = PageRankConfig::default();
    let mut scratch = ExchangeScratch::new();
    let mut times: Vec<f64> = (0..EXCHANGE_RUNS)
        .map(|_| {
            let t0 = Instant::now();
            let ex = run_exchange_pooled(plan, &cfg, None, pool, &mut scratch);
            assert!(ex.iterations > 0, "exchange must iterate");
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[EXCHANGE_RUNS / 2]
}

const SUB_VERTICES: usize = 10_000;
const SUB_PUBLISHES: usize = 500;

/// Publish cost with `n_subs` standing subscriptions registered against
/// the push plane. Two pre-built snapshots alternate so every publish
/// flips top-K membership, rank crossings and hot-set membership — the
/// worst case where every subscription has a diff to evaluate and most
/// fire. The timing includes draining the mailboxes, which is what the
/// poll loop pays per publish. Returns nanoseconds per publish.
fn publish_with_subs(n_subs: usize) -> f64 {
    let n = SUB_VERTICES;
    let ids: Vec<u64> = (0..n as u64).collect();
    let snap = |version: u64, flip: bool| {
        let ranks: Vec<f64> = (0..n)
            .map(|i| if flip { (i + 1) as f64 / n as f64 } else { (n - i) as f64 / n as f64 })
            .collect();
        let hot: Vec<u64> =
            (0..1_000).map(|i| 2 * i + u64::from(flip)).collect();
        let mut s = RankSnapshot::new(
            version,
            version,
            version,
            Action::ComputeApproximate,
            ExecStats::default(),
            ids.clone(),
            ranks,
            128,
            Json::Null,
        );
        s.set_hot_set(hot);
        Arc::new(s)
    };
    let a = snap(1, false);
    let b = snap(2, true);

    let publisher = SnapshotPublisher::new();
    let mut mailboxes = Vec::new();
    for j in 0..n_subs {
        let mb = Mailbox::new();
        let spec = match j % 3 {
            0 => Subscription::TopK { k: 10 },
            1 => Subscription::RankThreshold { id: (j % n) as u64, tau: 0.5 },
            _ => Subscription::HotSet { id: (j % 2_000) as u64 },
        };
        publisher.subscriptions().subscribe(spec, &mb);
        mailboxes.push(mb);
    }

    // Warm up the diff path (first publish transitions from the empty
    // snapshot, which is not the steady state being measured).
    publisher.publish(Arc::clone(&a));
    for mb in &mailboxes {
        mb.drain();
    }

    let t0 = Instant::now();
    for i in 0..SUB_PUBLISHES {
        publisher.publish(Arc::clone(if i % 2 == 0 { &b } else { &a }));
        for mb in &mailboxes {
            mb.drain();
        }
    }
    t0.elapsed().as_nanos() as f64 / SUB_PUBLISHES as f64
}

fn main() {
    let edges = generate::copying_web(50_000, 10, 0.7, 42);
    let engine = EngineBuilder::new()
        .params(SummaryParams::new(0.2, 1, 0.1))
        .build_from_edges(edges)
        .expect("build engine");
    let n = engine.graph().num_vertices();
    let m = engine.graph().num_edges();
    println!("workload: copying-web |V|={n} |E|={m}, read = top-{READ_K}\n");
    let handle = Arc::new(ServerHandle::spawn(engine, 1 << 16, OverflowPolicy::Block));

    let mut results: Vec<(String, f64)> = Vec::new();
    let mut qps = |label: &str, readers: usize, split: bool| {
        let v = throughput(&handle, readers, split);
        println!("{label:<24} {v:>12.0} reads/sec");
        results.push((label.to_string(), v));
        v
    };
    let queue1 = qps("serve_queue_readers1", 1, false);
    let queue4 = qps("serve_queue_readers4", 4, false);
    let split1 = qps("serve_split_readers1", 1, true);
    let split4 = qps("serve_split_readers4", 4, true);
    let ratio = split4 / queue1;
    println!("\nserve_readers4_vs_single (4 split readers vs serialized reads): {ratio:.1}x");
    let _ = (queue4, split1);
    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!("all bench threads joined"),
    }

    // ---- saturation: readiness loop under a swarm + hot writer --------
    let engine = EngineBuilder::new()
        .params(SummaryParams::new(0.2, 1, 0.1))
        .build_from_edges(generate::copying_web(50_000, 10, 0.7, 43))
        .expect("build engine");
    // Reconciliation on (the default) + a dedicated 2-worker recompute
    // pool: fence misses under the hot writer are salvaged, not recounted.
    let h = ServerHandle::spawn_with(
        engine,
        &ServeOptions::new().queue_capacity(1 << 16).recompute_workers(2),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve(h, listener, ServeOptions::new().workers(4).max_connections(256)).unwrap();
    });
    let (idle_rps, sat_rps, p99) = saturation(addr);
    let sat_ratio = sat_rps / idle_rps;
    println!("\nsaturation: idle {idle_rps:.0} reads/sec, saturated {sat_rps:.0} reads/sec");
    println!("serve_saturated_vs_idle: {sat_ratio:.2}x");
    println!("recompute_overlap_read_p99: {:.3} ms", p99 * 1e3);
    let (fence_misses, reconciled) = {
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        let line = wire_send(&mut c, &mut r, "{\"op\":\"stats\"}");
        let stats = Json::parse(line.trim()).expect("stats json");
        let server = stats.get("stats").unwrap().get("server").unwrap();
        (
            server.get("recompute_fence_misses").unwrap().as_u64().unwrap(),
            server.get("recomputes_reconciled").unwrap().as_u64().unwrap(),
        )
    };
    println!("saturation fence: {reconciled} reconciled, {fence_misses} missed");
    assert!(
        fence_misses <= 4,
        "reconciliation must absorb fence misses under saturation (got {fence_misses})"
    );
    {
        let mut c = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        wire_send(&mut c, &mut r, "{\"op\":\"shutdown\"}");
    }
    server.join().unwrap();

    // ---- push plane: publish cost vs registered subscriptions --------
    println!();
    let sub_counts = [1usize, 64, 1024];
    let mut sub_results: Vec<(usize, f64)> = Vec::new();
    for &n_subs in &sub_counts {
        let ns = publish_with_subs(n_subs);
        println!("publish_subs{n_subs:<5} {ns:>12.0} ns/publish (diff + mailbox drain)");
        sub_results.push((n_subs, ns));
    }

    // ---- sharded scale-out: cluster vs single-engine absorb rate -----
    println!();
    let base_edges = generate::copying_web(50_000, 10, 0.7, 44);
    let stream = sharded_stream(SHARDED_TOTAL_OPS);
    let single_rate = sharded_absorb_rate(1, base_edges.clone(), &stream);
    println!("sharded_absorb_single   {single_rate:>12.0} ops/sec");
    let sharded2 = sharded_absorb_rate(2, base_edges.clone(), &stream);
    let sharded4 = sharded_absorb_rate(4, base_edges, &stream);
    let s2_ratio = sharded2 / single_rate;
    let s4_ratio = sharded4 / single_rate;
    println!("sharded_absorb_shards2  {sharded2:>12.0} ops/sec ({s2_ratio:.2}x vs single)");
    println!("sharded_absorb_shards4  {sharded4:>12.0} ops/sec ({s4_ratio:.2}x vs single)");

    // ---- recompute plane: pooled exchange + plan cache ---------------
    println!();
    let ex_edges = generate::copying_web(50_000, 10, 0.7, 45);
    let (ex_graphs, ex_parts) = shard_graphs(&ex_edges, EXCHANGE_SHARDS);
    let refs: Vec<&DynamicGraph> = ex_graphs.iter().collect();
    let plan = ShardPlan::build(&refs, &ex_parts);
    let pool = ThreadPool::new(4);
    // Bit-identity first: the pooled run must reproduce the serial one
    // exactly, or the speedup below compares different computations.
    {
        let cfg = PageRankConfig::default();
        let a = run_exchange_pooled(&plan, &cfg, None, None, &mut ExchangeScratch::new());
        let b = run_exchange_pooled(&plan, &cfg, None, Some(&pool), &mut ExchangeScratch::new());
        assert_eq!(a.iterations, b.iterations, "pooled exchange diverged (iterations)");
        for (ra, rb) in a.ranks.iter().zip(&b.ranks) {
            assert!(
                ra.iter().zip(rb).all(|(x, y)| x.to_bits() == y.to_bits()),
                "pooled exchange is not bit-identical to serial"
            );
        }
    }
    let serial_secs = time_exchange(&plan, None);
    let par4_secs = time_exchange(&plan, Some(&pool));
    let ex_ratio = serial_secs / par4_secs;
    println!("exchange_serial         {:>12.1} ms/run", serial_secs * 1e3);
    println!("exchange_par4           {:>12.1} ms/run", par4_secs * 1e3);
    println!("exchange_par4_vs_serial: {ex_ratio:.2}x");
    // Plan cache: a one-dirty-shard rebuild vs a fresh full build.
    let mut fresh_times: Vec<f64> = (0..EXCHANGE_RUNS)
        .map(|_| {
            let t0 = Instant::now();
            let p = ShardPlan::build(&refs, &ex_parts);
            assert_eq!(p.total_vertices(), plan.total_vertices());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    fresh_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let fresh_secs = fresh_times[EXCHANGE_RUNS / 2];
    let dirty: Vec<bool> = (0..EXCHANGE_SHARDS).map(|s| s == 0).collect();
    let mut cached = plan.clone();
    let mut rebuild_times: Vec<f64> = (0..EXCHANGE_RUNS)
        .map(|_| {
            let t0 = Instant::now();
            cached.rebuild_shards(&refs, &ex_parts, &dirty);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    rebuild_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rebuild_secs = rebuild_times[EXCHANGE_RUNS / 2];
    let plan_ratio = fresh_secs / rebuild_secs;
    println!("plan_build_fresh        {:>12.2} ms", fresh_secs * 1e3);
    println!("plan_rebuild_1of4       {:>12.2} ms", rebuild_secs * 1e3);
    println!("plan_reuse_vs_rebuild: {plan_ratio:.2}x");

    // ---- machine-readable artifact -----------------------------------
    std::fs::create_dir_all("results").ok();
    let serving = Json::obj(vec![
        ("readers", Json::Num(4.0)),
        ("read_top_k", Json::Num(READ_K as f64)),
        ("measure_secs", Json::Num(MEASURE_SECS)),
        (
            "reads_per_sec",
            Json::Obj(
                results
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect(),
            ),
        ),
        (
            "subscriptions",
            Json::obj(vec![
                ("vertices", Json::Num(SUB_VERTICES as f64)),
                ("publishes", Json::Num(SUB_PUBLISHES as f64)),
                (
                    "ns_per_publish",
                    Json::Obj(
                        sub_results
                            .iter()
                            .map(|&(n_subs, ns)| (format!("subs{n_subs}"), Json::Num(ns)))
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "saturation",
            Json::obj(vec![
                ("swarm_conns", Json::Num(SWARM_CONNS as f64)),
                ("measure_secs", Json::Num(SATURATION_MEASURE_SECS)),
                ("idle_reads_per_sec", Json::Num(idle_rps)),
                ("saturated_reads_per_sec", Json::Num(sat_rps)),
                ("serve_saturated_vs_idle", Json::Num(sat_ratio)),
                ("recompute_overlap_read_p99", Json::Num(p99)),
                ("recompute_fence_misses", Json::Num(fence_misses as f64)),
                ("recomputes_reconciled", Json::Num(reconciled as f64)),
            ]),
        ),
        (
            "recompute_plane",
            Json::obj(vec![
                ("shards", Json::Num(EXCHANGE_SHARDS as f64)),
                ("exchange_serial_secs", Json::Num(serial_secs)),
                ("exchange_par4_secs", Json::Num(par4_secs)),
                ("exchange_par4_vs_serial", Json::Num(ex_ratio)),
                ("plan_build_fresh_secs", Json::Num(fresh_secs)),
                ("plan_rebuild_dirty1_secs", Json::Num(rebuild_secs)),
                ("plan_reuse_vs_rebuild", Json::Num(plan_ratio)),
            ]),
        ),
        (
            "sharded",
            Json::obj(vec![
                ("total_ops", Json::Num(SHARDED_TOTAL_OPS as f64)),
                ("batch_ops", Json::Num(SHARDED_BATCH as f64)),
                ("single_ops_per_sec", Json::Num(single_rate)),
                ("shards2_ops_per_sec", Json::Num(sharded2)),
                ("shards4_ops_per_sec", Json::Num(sharded4)),
                ("sharded2_vs_single", Json::Num(s2_ratio)),
                ("sharded4_vs_single", Json::Num(s4_ratio)),
            ]),
        ),
    ]);
    std::fs::write("results/serving_bench.json", serving.to_string_pretty())
        .expect("write serving json");
    println!("JSON written to results/serving_bench.json");

    // BENCH_4 = BENCH_3 schema (the micro bench's output) + serving.
    let mut doc = std::fs::read_to_string("results/micro_bench.json")
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .unwrap_or_else(|| Json::obj(Vec::new()));
    if let Json::Obj(map) = &mut doc {
        let ratios = [
            ("serve_readers4_vs_single", ratio),
            ("serve_saturated_vs_idle", sat_ratio),
            ("sharded2_vs_single", s2_ratio),
            ("sharded4_vs_single", s4_ratio),
            ("exchange_par4_vs_serial", ex_ratio),
            ("plan_reuse_vs_rebuild", plan_ratio),
        ];
        match map.get_mut("speedups") {
            Some(Json::Obj(speedups)) => {
                for (k, v) in ratios {
                    speedups.insert(k.into(), Json::Num(v));
                }
            }
            _ => {
                map.insert(
                    "speedups".into(),
                    Json::obj(ratios.iter().map(|&(k, v)| (k, Json::Num(v))).collect()),
                );
            }
        }
        map.insert("recompute_overlap_read_p99".into(), Json::Num(p99));
        map.insert("serving".into(), serving);
    }
    std::fs::write("results/bench_4.json", doc.to_string_pretty()).expect("write bench_4 json");
    println!("JSON written to results/bench_4.json");
}
