//! `cargo bench --bench serving` — serving-throughput benchmark for the
//! read/write split: N concurrent readers × 1 writer, read-queries/sec
//! with reads serialized through the engine command queue (the old
//! architecture) vs reads off the published snapshot (the split).
//!
//! Emits `results/serving_bench.json` and — when the micro bench ran
//! first (CI does) — merges its numbers into `results/bench_4.json`, the
//! BENCH_4 perf-trajectory artifact (superset of the BENCH_3 schema plus
//! the `serve_readers4_vs_single` throughput ratio).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use veilgraph::coordinator::engine::EngineBuilder;
use veilgraph::coordinator::server::ServerHandle;
use veilgraph::graph::generate;
use veilgraph::stream::backpressure::OverflowPolicy;
use veilgraph::stream::event::EdgeOp;
use veilgraph::summary::params::SummaryParams;
use veilgraph::util::json::Json;

const READ_K: usize = 100;
const MEASURE_SECS: f64 = 1.5;

/// Fresh vertex ids across every mode, so each mode's mutations are real
/// (a repeated id range would be skipped as duplicates and flatten the
/// writer load for later modes).
static NEXT_VERTEX: AtomicU64 = AtomicU64::new(1_000_000);

/// Read-queries/sec with `readers` concurrent reader threads and one
/// writer continuously ingesting + recomputing. `split == false` sends
/// every read through the engine command queue (each read is a full
/// engine query); `split == true` serves reads from the published
/// snapshot.
fn throughput(handle: &Arc<ServerHandle>, readers: usize, split: bool) -> f64 {
    let stop = Arc::new(AtomicBool::new(false));
    let total = Arc::new(AtomicU64::new(0));

    // 1 writer: a steady mutation + recompute load.
    let writer = {
        let h = Arc::clone(handle);
        let stop2 = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                for _ in 0..64 {
                    let next = NEXT_VERTEX.fetch_add(1, Ordering::Relaxed);
                    let _ = h.ingest(EdgeOp::add(next, next % 50_000));
                }
                let _ = h.query();
            }
        })
    };

    let mut threads = Vec::new();
    for _ in 0..readers {
        let h = Arc::clone(handle);
        let stop2 = Arc::clone(&stop);
        let total2 = Arc::clone(&total);
        threads.push(std::thread::spawn(move || {
            let reader = h.reader();
            let mut count = 0u64;
            while !stop2.load(Ordering::Relaxed) {
                if split {
                    let top = reader.top(READ_K);
                    assert!(!top.is_empty());
                } else {
                    let top = h.query().expect("queued read").top(READ_K);
                    assert!(!top.is_empty());
                }
                count += 1;
            }
            total2.fetch_add(count, Ordering::Relaxed);
        }));
    }

    let t0 = Instant::now();
    std::thread::sleep(Duration::from_secs_f64(MEASURE_SECS));
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        t.join().unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    writer.join().unwrap();
    total.load(Ordering::Relaxed) as f64 / elapsed
}

fn main() {
    let edges = generate::copying_web(50_000, 10, 0.7, 42);
    let engine = EngineBuilder::new()
        .params(SummaryParams::new(0.2, 1, 0.1))
        .build_from_edges(edges)
        .expect("build engine");
    let n = engine.graph().num_vertices();
    let m = engine.graph().num_edges();
    println!("workload: copying-web |V|={n} |E|={m}, read = top-{READ_K}\n");
    let handle = Arc::new(ServerHandle::spawn(engine, 1 << 16, OverflowPolicy::Block));

    let mut results: Vec<(String, f64)> = Vec::new();
    let mut qps = |label: &str, readers: usize, split: bool| {
        let v = throughput(&handle, readers, split);
        println!("{label:<24} {v:>12.0} reads/sec");
        results.push((label.to_string(), v));
        v
    };
    let queue1 = qps("serve_queue_readers1", 1, false);
    let queue4 = qps("serve_queue_readers4", 4, false);
    let split1 = qps("serve_split_readers1", 1, true);
    let split4 = qps("serve_split_readers4", 4, true);
    let ratio = split4 / queue1;
    println!("\nserve_readers4_vs_single (4 split readers vs serialized reads): {ratio:.1}x");
    let _ = (queue4, split1);

    // ---- machine-readable artifact -----------------------------------
    std::fs::create_dir_all("results").ok();
    let serving = Json::obj(vec![
        ("readers", Json::Num(4.0)),
        ("read_top_k", Json::Num(READ_K as f64)),
        ("measure_secs", Json::Num(MEASURE_SECS)),
        (
            "reads_per_sec",
            Json::Obj(
                results
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("results/serving_bench.json", serving.to_string_pretty())
        .expect("write serving json");
    println!("JSON written to results/serving_bench.json");

    // BENCH_4 = BENCH_3 schema (the micro bench's output) + serving.
    let mut doc = std::fs::read_to_string("results/micro_bench.json")
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .unwrap_or_else(|| Json::obj(Vec::new()));
    if let Json::Obj(map) = &mut doc {
        match map.get_mut("speedups") {
            Some(Json::Obj(speedups)) => {
                speedups.insert("serve_readers4_vs_single".into(), Json::Num(ratio));
            }
            _ => {
                map.insert(
                    "speedups".into(),
                    Json::obj(vec![("serve_readers4_vs_single", Json::Num(ratio))]),
                );
            }
        }
        map.insert("serving".into(), serving);
    }
    std::fs::write("results/bench_4.json", doc.to_string_pretty()).expect("write bench_4 json");
    println!("JSON written to results/bench_4.json");

    match Arc::try_unwrap(handle) {
        Ok(h) => h.shutdown(),
        Err(_) => unreachable!("all bench threads joined"),
    }
}
