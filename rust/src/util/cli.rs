//! Small CLI argument parser (substrate for the unavailable `clap`).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, repeated
//! options, and positional arguments, with generated usage text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Specification of one option/flag.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A parsed command line: option values + positionals.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, Vec<String>>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Parsed {
    /// Last value of `--name`, if given (or its default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeatable option.
    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Whether a boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Typed accessor with parse error reporting. The target type's own
    /// parse error rides along, so rich parsers (policy specs, overflow
    /// policies) surface *why* the value was rejected, not just that it
    /// was.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| Error::Usage(format!("invalid value for --{name}: {s:?} ({e})"))),
        }
    }

    /// Typed accessor with a required default already set in the spec.
    pub fn req_parse<T: std::str::FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.get_parse::<T>(name)?
            .ok_or_else(|| Error::Usage(format!("missing required --{name}")))
    }
}

/// A command (or subcommand) definition.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

impl Command {
    /// New command with a name and description.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    /// Add a value-taking option.
    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let tail = if o.takes_value {
                match o.default {
                    Some(d) => format!(" <value>   (default: {d})"),
                    None => " <value>".to_string(),
                }
            } else {
                String::new()
            };
            s.push_str(&format!("  --{}{tail}\n      {}\n", o.name, o.help));
        }
        s
    }

    /// Parse `args` (not including argv[0] / the subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut out = Parsed::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                out.values.insert(o.name.to_string(), vec![d.to_string()]);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(raw) = a.strip_prefix("--") {
                let (name, inline) = match raw.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (raw, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| {
                        Error::Usage(format!("unknown option --{name}\n\n{}", self.usage()))
                    })?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| Error::Usage(format!("--{name} needs a value")))?
                        }
                    };
                    out.values.entry(name.to_string()).or_default().push(v);
                } else {
                    if inline.is_some() {
                        return Err(Error::Usage(format!("--{name} takes no value")));
                    }
                    out.flags.insert(name.to_string(), true);
                }
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .opt("count", "how many", Some("3"))
            .opt("name", "a name", None)
            .flag("verbose", "talk more")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(p.get("count"), Some("3"));
        assert_eq!(p.get("name"), None);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn values_flags_positionals() {
        let p = cmd()
            .parse(&argv(&["--count", "7", "--verbose", "pos1", "--name=zed", "pos2"]))
            .unwrap();
        assert_eq!(p.req_parse::<u32>("count").unwrap(), 7);
        assert_eq!(p.get("name"), Some("zed"));
        assert!(p.flag("verbose"));
        assert_eq!(p.positionals, vec!["pos1", "pos2"]);
    }

    #[test]
    fn repeated_options_accumulate() {
        let p = cmd().parse(&argv(&["--name", "a", "--name", "b"])).unwrap();
        assert_eq!(p.get_all("name"), &["a".to_string(), "b".to_string()]);
        assert_eq!(p.get("name"), Some("b"));
    }

    #[test]
    fn unknown_option_is_usage_error() {
        let e = cmd().parse(&argv(&["--bogus"])).unwrap_err();
        assert!(matches!(e, Error::Usage(_)));
        assert!(e.to_string().contains("--bogus"));
    }

    #[test]
    fn missing_value_is_usage_error() {
        assert!(cmd().parse(&argv(&["--name"])).is_err());
    }

    #[test]
    fn bad_typed_value_reports_option() {
        let p = cmd().parse(&argv(&["--count", "zebra"])).unwrap();
        let e = p.req_parse::<u32>("count").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("count"));
        assert!(msg.contains("digit"), "inner parse error rides along: {msg}");
    }

    #[test]
    fn usage_mentions_all_options() {
        let u = cmd().usage();
        assert!(u.contains("--count") && u.contains("--verbose") && u.contains("default: 3"));
    }
}
