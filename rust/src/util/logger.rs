//! Tiny leveled stderr logger (substrate for `log` + `env_logger`).
//!
//! Level is taken from `VEILGRAPH_LOG` (error|warn|info|debug|trace),
//! default `info`. Thread-safe; messages are single `eprintln!` calls so
//! they do not interleave mid-line.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

/// Log severity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: Once = Once::new();

fn init() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("VEILGRAPH_LOG") {
            LEVEL.store(Level::from_str(&v) as u8, Ordering::Relaxed);
        }
    });
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    init();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current level.
pub fn level() -> Level {
    init();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// True if `lvl` would be emitted.
pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Emit a message (used by the macros; prefer those).
pub fn emit(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{} {target}] {msg}", lvl.tag());
    }
}

/// Log at error level.
#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => { $crate::util::logger::emit($crate::util::logger::Level::Error, module_path!(), format_args!($($t)*)) };
}
/// Log at warn level.
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::util::logger::emit($crate::util::logger::Level::Warn, module_path!(), format_args!($($t)*)) };
}
/// Log at info level.
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::util::logger::emit($crate::util::logger::Level::Info, module_path!(), format_args!($($t)*)) };
}
/// Log at debug level.
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::util::logger::emit($crate::util::logger::Level::Debug, module_path!(), format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_output() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }

    #[test]
    fn from_str_parses_known_levels() {
        assert_eq!(Level::from_str("ERROR"), Level::Error);
        assert_eq!(Level::from_str("warning"), Level::Warn);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }
}
