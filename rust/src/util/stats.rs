//! Streaming statistics: Welford moments, percentiles, summaries.
//!
//! Used by the bench harness (criterion substitute), the engine's metrics
//! registry, and the experiment reports.

/// Online mean/variance accumulator (Welford). O(1) memory.
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Moments {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Feed one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    /// Sample variance (n-1 denominator; 0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum observation (+inf if empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation (-inf if empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Moments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample using linear interpolation (type-7 / numpy
/// default). `q` in [0, 100]. Sorts a copy; fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&q), "q out of range");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median = 50th percentile.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// A fixed five-number-ish summary of a sample, for reports.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary of `xs` (must be non-empty).
    pub fn of(xs: &[f64]) -> Self {
        let mut m = Moments::new();
        for &x in xs {
            m.push(x);
        }
        Self {
            count: xs.len(),
            mean: m.mean(),
            stddev: m.stddev(),
            min: m.min(),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            max: m.max(),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.6} σ={:.6} min={:.6} p50={:.6} p95={:.6} max={:.6}",
            self.count, self.mean, self.stddev, self.min, self.p50, self.p95, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_match_closed_form() {
        let mut m = Moments::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            m.push(x);
        }
        assert_eq!(m.count(), 8);
        assert!((m.mean() - 5.0).abs() < 1e-12);
        // population σ = 2 ⇒ sample variance = 32/7
        assert!((m.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(m.min(), 2.0);
        assert_eq!(m.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut whole = Moments::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Moments::new();
        let mut b = Moments::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Moments::new();
        a.push(1.0);
        a.push(3.0);
        let before = (a.mean(), a.variance());
        a.merge(&Moments::new());
        assert_eq!((a.mean(), a.variance()), before);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn summary_display_is_stable() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.count, 3);
        assert!(s.to_string().contains("n=3"));
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn percentile_empty_panics() {
        percentile(&[], 50.0);
    }
}
