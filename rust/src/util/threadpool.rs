//! Fixed-size worker thread pool (substrate for the unavailable `tokio` /
//! `rayon`).
//!
//! The coordinator uses it for parallel experiment grids and for the query
//! server's worker side. Jobs are `FnOnce` closures; [`ThreadPool::scope_map`]
//! gives a rayon-like parallel map with panic propagation.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("veilgraph-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // Panics are contained per-job; scope_map
                                // re-raises them on the caller side.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("failed to spawn worker"),
            );
        }
        Self { tx, handles, size }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget job submission.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx.send(Msg::Run(Box::new(job))).expect("pool shut down");
    }

    /// Parallel map: applies `f` to every item, preserving order.
    ///
    /// Panics in `f` are captured and re-raised on the calling thread after
    /// all jobs finish (first panic wins).
    pub fn scope_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n {
            let (i, res) = rrx.recv().expect("worker vanished");
            match res {
                Ok(v) => slots[i] = Some(v),
                Err(p) => {
                    if panic.is_none() {
                        panic = Some(p);
                    }
                }
            }
        }
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.scope_map((0..200).collect(), |x: i32| x * x);
        assert_eq!(out, (0..200).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_handles_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.scope_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scope_map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_map(vec![1, 2, 3], |x| {
                if x == 2 {
                    panic!("boom");
                }
                x
            })
        }));
        assert!(res.is_err());
        // Pool must still be usable after a contained panic.
        let ok = pool.scope_map(vec![1, 2], |x| x + 1);
        assert_eq!(ok, vec![2, 3]);
    }

    #[test]
    fn zero_size_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.scope_map(vec![5], |x| x), vec![5]);
    }
}
