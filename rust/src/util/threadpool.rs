//! Fixed-size worker thread pool (substrate for the unavailable `tokio` /
//! `rayon`).
//!
//! The coordinator uses it for parallel experiment grids and for the query
//! server's worker side. Jobs are `FnOnce` closures; [`ThreadPool::scope_map`]
//! gives a rayon-like parallel map with panic propagation.
//!
//! The pool is `Sync` (the submission side is a mutex-guarded sender), so a
//! single `Arc<ThreadPool>` can be shared by many engines — the experiment
//! harness hands one pool to every combination replay instead of letting
//! each engine spawn its own (the `--workers 8 --parallelism 8`
//! oversubscription fix). Sharing note: callers of the scoped helpers block
//! until their own jobs finish, so the pool must never be entered from one
//! of its *own* workers (outer grid pool and inner shard pool are distinct).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Available hardware parallelism, defaulting to 4 when undetectable.
pub fn available_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    /// Mutex-guarded so `&ThreadPool` can submit from any thread (std's
    /// `mpsc::Sender` alone is not `Sync` on every supported toolchain).
    tx: Mutex<mpsc::Sender<Msg>>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("veilgraph-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // Panics are contained per-job; scope_map
                                // re-raises them on the caller side.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("failed to spawn worker"),
            );
        }
        Self { tx: Mutex::new(tx), handles, size }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        Self::new(available_parallelism())
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue one message (lock held only for the send itself).
    fn send(&self, msg: Msg) {
        self.tx.lock().expect("pool sender poisoned").send(msg).expect("pool shut down");
    }

    /// Fire-and-forget job submission.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.send(Msg::Run(Box::new(job)));
    }

    /// Scoped parallel execution over disjoint mutable chunks of a slice
    /// — the substrate for the sharded PageRank executors.
    ///
    /// `cuts` holds `k + 1` ascending cut points with `cuts[0] == 0` and
    /// `cuts[k] == data.len()` (the shape [`crate::graph::csr::Csr::shards`]
    /// produces). Chunk `i` = `data[cuts[i]..cuts[i + 1]]`; `f(i, chunk)`
    /// runs on the pool and its per-chunk results come back in chunk
    /// order, giving callers a deterministic reduction order. Unlike
    /// [`Self::scope_map`] the closure borrows its environment (`f` needs
    /// only `Sync`, not `'static`), so per-iteration dispatch reuses the
    /// caller's buffers instead of moving owned data through the queue.
    ///
    /// A single chunk runs inline on the caller's thread (no dispatch
    /// cost for the `parallelism == 1` path). Panics in `f` are captured
    /// and re-raised on the calling thread after every chunk has finished
    /// (first panic wins) — the borrow of `data` never outlives the call.
    pub fn scope_chunks<T, R, F>(&self, data: &mut [T], cuts: &[usize], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut [T]) -> R + Sync,
    {
        assert!(cuts.len() >= 2, "cuts must hold at least [0, len]");
        assert_eq!(cuts[0], 0, "cuts must start at 0");
        assert_eq!(*cuts.last().unwrap(), data.len(), "cuts must end at data.len()");
        assert!(cuts.windows(2).all(|w| w[0] <= w[1]), "cuts must be ascending");
        let k = cuts.len() - 1;
        if k == 1 {
            return vec![f(0, data)];
        }
        // Disjointness comes from safe borrow splitting — no aliasing to
        // reason about, only the job lifetime below.
        let mut chunks: Vec<&mut [T]> = Vec::with_capacity(k);
        let mut rest = data;
        for i in 0..k {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(cuts[i + 1] - cuts[i]);
            chunks.push(head);
            rest = tail;
        }
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<R>)>();
        let f = &f;
        for (i, chunk) in chunks.into_iter().enumerate() {
            let rtx = rtx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(i, chunk)));
                let _ = rtx.send((i, out));
            });
            // SAFETY: the queue requires 'static jobs, but this function
            // blocks below until all k jobs have reported through the
            // channel (including on panic — jobs always send), so every
            // borrow captured by `job` (the chunk, `f`) strictly outlives
            // its execution. This is the standard scoped-pool erasure.
            let job: Job =
                unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job) };
            self.send(Msg::Run(job));
        }
        drop(rtx);
        drain_results(&rrx, k)
    }

    /// One owned output per shard: runs `f(i)` for `i in 0..k` across the
    /// pool and returns the results in shard order (`k == 0` yields an
    /// empty vec, `k == 1` runs inline). Sugar over [`Self::scope_chunks`]
    /// for sharded jobs that each *produce* private data — per-shard
    /// candidate lists, frontier segments, buckets — instead of writing
    /// disjoint pieces of one shared slice.
    pub fn scope_slots<R, F>(&self, k: usize, f: F) -> Vec<R>
    where
        R: Send + Default,
        F: Fn(usize) -> R + Sync,
    {
        if k == 0 {
            return Vec::new();
        }
        let mut slots: Vec<R> = (0..k).map(|_| R::default()).collect();
        let cuts: Vec<usize> = (0..=k).collect();
        self.scope_chunks(&mut slots, &cuts, |i, chunk| {
            chunk[0] = f(i);
        });
        slots
    }

    /// Parallel map: applies `f` to every item, preserving order.
    ///
    /// Panics in `f` are captured and re-raised on the calling thread after
    /// all jobs finish (first panic wins).
    pub fn scope_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        drain_results(&rrx, n)
    }
}

/// Collect exactly `n` indexed job results in submission order,
/// re-raising the first captured panic only after every job has
/// reported (so scoped borrows never outlive a running job).
fn drain_results<R>(rrx: &mpsc::Receiver<(usize, thread::Result<R>)>, n: usize) -> Vec<R> {
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
    for _ in 0..n {
        let (i, res) = rrx.recv().expect("worker vanished");
        match res {
            Ok(v) => slots[i] = Some(v),
            Err(p) => {
                if panic.is_none() {
                    panic = Some(p);
                }
            }
        }
    }
    if let Some(p) = panic {
        std::panic::resume_unwind(p);
    }
    slots.into_iter().map(|s| s.unwrap()).collect()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            for _ in 0..self.handles.len() {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.scope_map((0..200).collect(), |x: i32| x * x);
        assert_eq!(out, (0..200).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_handles_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.scope_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scope_map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_map(vec![1, 2, 3], |x| {
                if x == 2 {
                    panic!("boom");
                }
                x
            })
        }));
        assert!(res.is_err());
        // Pool must still be usable after a contained panic.
        let ok = pool.scope_map(vec![1, 2], |x| x + 1);
        assert_eq!(ok, vec![2, 3]);
    }

    #[test]
    fn zero_size_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.scope_map(vec![5], |x| x), vec![5]);
    }

    #[test]
    fn scope_chunks_writes_disjoint_slices() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 100];
        let cuts = [0usize, 13, 50, 99, 100];
        let sums = pool.scope_chunks(&mut data, &cuts, |i, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (i * 1000 + off) as u64;
            }
            chunk.iter().sum::<u64>()
        });
        assert_eq!(sums.len(), 4);
        for (i, w) in cuts.windows(2).enumerate() {
            let expect: u64 = (0..(w[1] - w[0])).map(|off| (i * 1000 + off) as u64).sum();
            assert_eq!(sums[i], expect, "chunk {i}");
            for (off, &x) in data[w[0]..w[1]].iter().enumerate() {
                assert_eq!(x, (i * 1000 + off) as u64);
            }
        }
    }

    #[test]
    fn scope_chunks_borrows_environment() {
        // The whole point over scope_map: `f` may borrow caller state.
        let pool = ThreadPool::new(3);
        let weights: Vec<u64> = (0..30).collect();
        let mut out = vec![0u64; 30];
        let cuts = [0usize, 10, 20, 30];
        let totals = pool.scope_chunks(&mut out, &cuts, |i, chunk| {
            let lo = [0usize, 10, 20][i];
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = weights[lo + off] * 2;
            }
            chunk.iter().sum::<u64>()
        });
        assert_eq!(out, weights.iter().map(|w| w * 2).collect::<Vec<_>>());
        assert_eq!(totals.iter().sum::<u64>(), weights.iter().sum::<u64>() * 2);
    }

    #[test]
    fn scope_chunks_single_chunk_runs_inline() {
        let pool = ThreadPool::new(2);
        let mut data = vec![1i32, 2, 3];
        let r = pool.scope_chunks(&mut data, &[0, 3], |i, chunk| {
            assert_eq!(i, 0);
            chunk.iter().sum::<i32>()
        });
        assert_eq!(r, vec![6]);
    }

    #[test]
    fn scope_chunks_allows_empty_chunks_and_empty_data() {
        let pool = ThreadPool::new(2);
        let mut data: Vec<u8> = Vec::new();
        let r = pool.scope_chunks(&mut data, &[0, 0], |_, chunk| chunk.len());
        assert_eq!(r, vec![0]);
        let mut data = vec![7u8; 4];
        let r = pool.scope_chunks(&mut data, &[0, 0, 4, 4], |_, chunk| chunk.len());
        assert_eq!(r, vec![0, 4, 0]);
    }

    #[test]
    fn scope_chunks_propagates_panics_after_completion() {
        let pool = ThreadPool::new(2);
        let mut data = vec![0u32; 8];
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_chunks(&mut data, &[0, 4, 8], |i, chunk| {
                if i == 1 {
                    panic!("shard boom");
                }
                chunk.len()
            })
        }));
        assert!(res.is_err());
        // Pool must still be usable after a contained panic.
        let ok = pool.scope_chunks(&mut data, &[0, 4, 8], |_, chunk| chunk.len());
        assert_eq!(ok, vec![4, 4]);
    }

    #[test]
    fn pool_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ThreadPool>();
    }

    #[test]
    fn one_pool_shared_across_threads_serves_concurrent_scopes() {
        // Two OS threads (neither a pool worker) drive scoped dispatches
        // on the SAME pool concurrently — the shared-inner-pool shape the
        // experiment harness uses. Callers are never workers, so there is
        // no nesting deadlock; results must stay per-caller correct.
        let pool = Arc::new(ThreadPool::new(3));
        let mut joins = Vec::new();
        for t in 0..2usize {
            let pool = Arc::clone(&pool);
            joins.push(thread::spawn(move || {
                let mut total = 0u64;
                for round in 0..20usize {
                    let mut data = vec![0u64; 64];
                    let cuts = [0usize, 16, 32, 64];
                    let sums = pool.scope_chunks(&mut data, &cuts, |i, chunk| {
                        for (off, x) in chunk.iter_mut().enumerate() {
                            *x = (t * 100_000 + round * 1000 + i * 100 + off) as u64;
                        }
                        chunk.iter().sum::<u64>()
                    });
                    for (i, w) in cuts.windows(2).enumerate() {
                        let expect: u64 = (0..(w[1] - w[0]))
                            .map(|off| (t * 100_000 + round * 1000 + i * 100 + off) as u64)
                            .sum();
                        assert_eq!(sums[i], expect, "thread {t} round {round} chunk {i}");
                    }
                    total += sums.iter().sum::<u64>();
                }
                total
            }));
        }
        for j in joins {
            assert!(j.join().unwrap() > 0);
        }
    }

    #[test]
    fn scope_slots_returns_per_shard_outputs_in_order() {
        let pool = ThreadPool::new(3);
        let out: Vec<Vec<usize>> = pool.scope_slots(5, |i| vec![i, i * 10]);
        assert_eq!(out, vec![vec![0, 0], vec![1, 10], vec![2, 20], vec![3, 30], vec![4, 40]]);
        let empty: Vec<Vec<usize>> = pool.scope_slots(0, |_| Vec::new());
        assert!(empty.is_empty());
        let one: Vec<u64> = pool.scope_slots(1, |i| i as u64 + 7);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn scope_chunks_rejects_malformed_cuts() {
        let pool = ThreadPool::new(2);
        let mut data = vec![0u32; 4];
        for bad in [vec![0usize, 3], vec![1, 4], vec![0, 3, 2, 4]] {
            let res = catch_unwind(AssertUnwindSafe(|| {
                pool.scope_chunks(&mut data, &bad, |_, chunk| chunk.len())
            }));
            assert!(res.is_err(), "cuts {bad:?} must be rejected");
        }
    }
}
