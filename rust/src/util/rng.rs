//! Deterministic PRNGs (substrate for the unavailable `rand` crate).
//!
//! Two generators:
//! * [`SplitMix64`] — tiny, used for seeding and cheap decisions.
//! * [`Xoshiro256pp`] — the workhorse; passes BigCrush, 2^128 jump not
//!   needed here. Both are fully deterministic given a seed, which the
//!   experiment harness relies on (same stream replayed across the whole
//!   parameter grid, as in the paper's protocol §5).

/// SplitMix64 — Steele, Lea & Flood (2014). Used to expand seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — Blackman & Vigna (2019).
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (never produces the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm); order is
    /// randomized. Panics if k > n.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        self.shuffle(&mut out);
        out
    }

    /// Weighted index draw proportional to `weights` (all ≥ 0, sum > 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to > 0");
        let mut target = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target < 0.0 {
                return i;
            }
        }
        weights.len() - 1 // fp rounding fell off the end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference value from the public-domain SplitMix64 C code, seed 0.
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220A8397B1DCDAF);
    }

    #[test]
    fn xoshiro_uniform_f64_bounds_and_mean() {
        let mut g = Xoshiro256pp::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_respects_bound_and_covers_values() {
        let mut g = Xoshiro256pp::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = g.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut g = Xoshiro256pp::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut g = Xoshiro256pp::new(5);
        let s = g.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_all_is_full_permutation() {
        let mut g = Xoshiro256pp::new(5);
        let mut s = g.sample_indices(10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy_items() {
        let mut g = Xoshiro256pp::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[g.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5, "{counts:?}");
    }

    #[test]
    #[should_panic(expected = "cannot sample")]
    fn sample_more_than_n_panics() {
        Xoshiro256pp::new(0).sample_indices(3, 4);
    }
}
