//! Minimal JSON (substrate for the unavailable `serde`/`serde_json`).
//!
//! Covers exactly what VeilGraph needs: the artifact manifest written by
//! `python/compile/aot.py`, experiment result files, and the line protocol
//! of the query server. Full RFC 8259 value model, recursive-descent
//! parser, compact + pretty writers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As u64 if numeric and integral ≥ 0.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            // Surrogate pairs: parse low half if present.
                            let c = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                code
                            };
                            s.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                b if b < 0x80 => s.push(b as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the byte slice.
                    let start = self.pos - 1;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos = start + c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"s",null,true],"m":{"n":-3}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn unicode_escapes_and_surrogates() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let v2 = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v2.as_str(), Some("héllo"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "format": "hlo-text", "tile": 128, "iters_fused": 10,
          "scalars_layout": ["beta", "teleport"],
          "artifacts": [{"name": "pagerank_step_c128.hlo.txt",
                         "variant": "step", "capacity": 128,
                         "outputs": 1, "sha256_16": "ab", "bytes": 100}]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("tile").unwrap().as_u64(), Some(128));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("capacity").unwrap().as_u64(), Some(128));
    }

    #[test]
    fn integral_floats_serialize_without_point() {
        assert_eq!(Json::Num(128.0).to_string_compact(), "128");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
