//! Stopwatches and scoped timers used by the engine's statistics and the
//! bench harness.

use std::time::{Duration, Instant};

/// A restartable stopwatch.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    started: Instant,
}

impl Stopwatch {
    /// Start now.
    pub fn start() -> Self {
        Self { started: Instant::now() }
    }

    /// Elapsed time since start/restart.
    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart and return the lap time.
    pub fn lap(&mut self) -> Duration {
        let e = self.started.elapsed();
        self.started = Instant::now();
        e
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Times a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.secs())
}

/// Human-friendly duration formatting for reports (µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_time() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        let lap = sw.lap();
        assert!(lap >= Duration::from_millis(5));
        // After lap, the clock restarts.
        assert!(sw.elapsed() < lap + Duration::from_millis(50));
    }

    #[test]
    fn timed_returns_result_and_positive_time() {
        let (v, t) = timed(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn duration_formatting_picks_units() {
        assert!(fmt_duration(0.0000005).ends_with("µs"));
        assert!(fmt_duration(0.5).ends_with("ms"));
        assert!(fmt_duration(2.0).ends_with('s'));
        assert_eq!(fmt_duration(1.5), "1.500s");
    }
}
