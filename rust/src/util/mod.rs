//! General-purpose substrates.
//!
//! The offline build environment vendors only the `xla` crate closure, so
//! everything a framework normally pulls from crates.io (rand, serde, clap,
//! tokio, criterion, …) is implemented here, small and tested:
//!
//! * [`rng`] — SplitMix64 + Xoshiro256++ PRNGs, shuffling, sampling.
//! * [`stats`] — streaming moments, percentiles, summaries.
//! * [`json`] — minimal JSON value model, parser and writer.
//! * [`logger`] — leveled stderr logger.
//! * [`cli`] — declarative-ish argument parser for the `veilgraph` binary.
//! * [`threadpool`] — fixed worker pool with panic propagation.
//! * [`timer`] — stopwatches and scoped timers.
//! * [`ascii_plot`] — terminal line plots for the figure harness.

pub mod ascii_plot;
pub mod cli;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;
