//! Terminal line plots for the figure harness.
//!
//! The paper's figures are per-query series (vertex ratio, edge ratio,
//! RBO, speedup) for the best-3/worst-3 parameter combinations. The
//! experiment harness writes CSVs for external plotting *and* renders a
//! quick-look ASCII chart so `cargo bench --bench figures` output is
//! self-contained.

/// One named series.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub ys: Vec<f64>,
}

impl Series {
    /// Construct a series.
    pub fn new(label: impl Into<String>, ys: Vec<f64>) -> Self {
        Self { label: label.into(), ys }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render series into a `width`×`height` character grid with axis labels.
/// X is the query index 1..=N (like the paper's figures).
pub fn render(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let width = width.max(16);
    let height = height.max(4);
    let n = series.iter().map(|s| s.ys.len()).max().unwrap_or(0);
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    if n == 0 || series.is_empty() {
        out.push_str("  (no data)\n");
        return out;
    }
    let finite = |v: f64| v.is_finite();
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for s in series {
        for &y in s.ys.iter().filter(|y| finite(**y)) {
            lo = lo.min(y);
            hi = hi.max(y);
        }
    }
    if !lo.is_finite() || !hi.is_finite() {
        out.push_str("  (no finite data)\n");
        return out;
    }
    if (hi - lo).abs() < 1e-12 {
        hi = lo + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for (i, &y) in s.ys.iter().enumerate() {
            if !finite(y) {
                continue;
            }
            let x = if n == 1 { 0 } else { i * (width - 1) / (n - 1) };
            let fy = (y - lo) / (hi - lo);
            let row = height - 1 - ((fy * (height - 1) as f64).round() as usize).min(height - 1);
            grid[row][x] = mark;
        }
    }
    for (ri, row) in grid.iter().enumerate() {
        let yval = hi - (hi - lo) * ri as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:>9.4} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>10}1{:>w$}\n", "", n, w = width - 1));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_single_series_with_extremes_on_edges() {
        let s = Series::new("up", (0..10).map(|i| i as f64).collect());
        let txt = render("t", &[s], 40, 8);
        assert!(txt.starts_with("t\n"));
        assert!(txt.contains("up"));
        // max label appears on first data row, min on last
        assert!(txt.contains("9.0000"));
        assert!(txt.contains("0.0000"));
    }

    #[test]
    fn constant_series_does_not_divide_by_zero() {
        let s = Series::new("flat", vec![2.0; 5]);
        let txt = render("flat", &[s], 30, 5);
        assert!(txt.contains('*'));
    }

    #[test]
    fn empty_series_is_graceful() {
        assert!(render("none", &[], 30, 5).contains("no data"));
        let s = Series::new("nan", vec![f64::NAN]);
        assert!(render("nan", &[s], 30, 5).contains("no finite data"));
    }

    #[test]
    fn multiple_series_use_distinct_marks() {
        let a = Series::new("a", vec![0.0, 1.0]);
        let b = Series::new("b", vec![1.0, 0.0]);
        let txt = render("two", &[a, b], 30, 6);
        assert!(txt.contains('*') && txt.contains('o'));
    }
}
