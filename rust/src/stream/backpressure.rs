//! Bounded ingestion queue with load-shedding policies.
//!
//! The paper's related work (§1, §6) frames load shedding as one of the
//! classic approximation levers for stream systems; VeilGraph's server
//! needs a concrete policy when producers outpace the engine. Three
//! policies:
//!
//! * `Block`    — backpressure proper: the producer waits.
//! * `DropOldest` — shed the oldest buffered update (bounded staleness).
//! * `Reject`   — fail fast; the caller sees [`Error::Backpressure`].

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::error::{Error, Result};

/// What to do when the queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverflowPolicy {
    Block,
    DropOldest,
    Reject,
}

impl std::str::FromStr for OverflowPolicy {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "block" => Ok(Self::Block),
            "drop-oldest" | "dropoldest" => Ok(Self::DropOldest),
            "reject" => Ok(Self::Reject),
            other => Err(Error::Usage(format!(
                "unknown overflow policy {other:?}; expected block, drop-oldest, or reject"
            ))),
        }
    }
}

/// Counters describing shedding behaviour.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub pushed: u64,
    pub popped: u64,
    pub dropped: u64,
    pub rejected: u64,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
    stats: QueueStats,
}

/// A bounded MPMC queue with an overflow policy.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    policy: OverflowPolicy,
}

impl<T> BoundedQueue<T> {
    /// Create a queue with `capacity` slots and an overflow policy.
    pub fn new(capacity: usize, policy: OverflowPolicy) -> Self {
        Self {
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                closed: false,
                stats: QueueStats::default(),
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
            policy,
        }
    }

    /// Push an item, applying the overflow policy when full.
    pub fn push(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::Engine("queue closed".into()));
        }
        while g.q.len() >= self.capacity {
            match self.policy {
                OverflowPolicy::Block => {
                    g = self.not_full.wait(g).unwrap();
                    if g.closed {
                        return Err(Error::Engine("queue closed".into()));
                    }
                }
                OverflowPolicy::DropOldest => {
                    g.q.pop_front();
                    g.stats.dropped += 1;
                }
                OverflowPolicy::Reject => {
                    g.stats.rejected += 1;
                    let n = g.q.len();
                    return Err(Error::Backpressure(n));
                }
            }
        }
        g.q.push_back(item);
        g.stats.pushed += 1;
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop an item, blocking until one is available or the queue closes.
    /// Returns `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.q.pop_front() {
                g.stats.popped += 1;
                drop(g);
                self.not_full.notify_one();
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Push that never blocks the caller, for producers that must stay
    /// responsive (the readiness loop's poll workers). `DropOldest`
    /// sheds the queue head to make room; `Block` and `Reject` both
    /// surface a full queue as [`Error::Backpressure`] so the caller can
    /// degrade instead of stalling.
    pub fn try_push(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::Engine("queue closed".into()));
        }
        while g.q.len() >= self.capacity {
            match self.policy {
                OverflowPolicy::DropOldest => {
                    g.q.pop_front();
                    g.stats.dropped += 1;
                }
                OverflowPolicy::Block | OverflowPolicy::Reject => {
                    g.stats.rejected += 1;
                    let n = g.q.len();
                    return Err(Error::Backpressure(n));
                }
            }
        }
        g.q.push_back(item);
        g.stats.pushed += 1;
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Enqueue bypassing the capacity check. For critical control
    /// messages (at most a handful outstanding at once) that must be
    /// neither shed nor allowed to block their producer — e.g. handing a
    /// finished recompute back to the engine thread. Fails only on a
    /// closed queue.
    pub fn force_push(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::Engine("queue closed".into()));
        }
        g.q.push_back(item);
        g.stats.pushed += 1;
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let x = g.q.pop_front();
        if x.is_some() {
            g.stats.popped += 1;
            self.not_full.notify_one();
        }
        x
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Current length.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// Configured capacity (slots).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Whether [`Self::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Shedding statistics.
    pub fn stats(&self) -> QueueStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10, OverflowPolicy::Reject);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn reject_policy_errors_when_full() {
        let q = BoundedQueue::new(2, OverflowPolicy::Reject);
        q.push(1).unwrap();
        q.push(2).unwrap();
        let e = q.push(3).unwrap_err();
        assert!(matches!(e, Error::Backpressure(2)));
        assert_eq!(q.stats().rejected, 1);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn drop_oldest_policy_shed_head() {
        let q = BoundedQueue::new(2, OverflowPolicy::DropOldest);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.stats().dropped, 1);
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
    }

    #[test]
    fn block_policy_waits_for_consumer() {
        let q = Arc::new(BoundedQueue::new(1, OverflowPolicy::Block));
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_unblocks_consumers_and_fails_producers() {
        let q = Arc::new(BoundedQueue::<u32>::new(4, OverflowPolicy::Block));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
        assert!(q.push(1).is_err());
    }

    #[test]
    fn try_push_never_blocks() {
        // Block policy: full queue surfaces Backpressure instead of waiting.
        let q = BoundedQueue::new(1, OverflowPolicy::Block);
        q.try_push(1).unwrap();
        let e = q.try_push(2).unwrap_err();
        assert!(matches!(e, Error::Backpressure(1)));
        assert_eq!(q.stats().rejected, 1);
        // DropOldest policy: head is shed, push succeeds.
        let q = BoundedQueue::new(1, OverflowPolicy::DropOldest);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.stats().dropped, 1);
    }

    #[test]
    fn capacity_and_closed_are_observable() {
        let q = BoundedQueue::<u32>::new(7, OverflowPolicy::Block);
        assert_eq!(q.capacity(), 7);
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
        assert!(q.try_push(1).is_err());
    }

    #[test]
    fn overflow_policy_parses_from_str() {
        assert_eq!("block".parse::<OverflowPolicy>().unwrap(), OverflowPolicy::Block);
        assert_eq!("drop-oldest".parse::<OverflowPolicy>().unwrap(), OverflowPolicy::DropOldest);
        assert_eq!("DropOldest".parse::<OverflowPolicy>().unwrap(), OverflowPolicy::DropOldest);
        assert_eq!("reject".parse::<OverflowPolicy>().unwrap(), OverflowPolicy::Reject);
        assert!("spill".parse::<OverflowPolicy>().is_err());
    }

    #[test]
    fn drain_after_close() {
        let q = BoundedQueue::new(4, OverflowPolicy::Block);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }
}
