//! Stream event model.
//!
//! The paper's update model `S` supports edge additions/removals and
//! vertex additions/removals (`e+`, `e-`, `v+`, `v-`; §4 “Stream of
//! updates S”), plus client queries interleaved with updates (Alg. 1).
//! The evaluation restricts itself to `e+`; the engine implements all.

use crate::graph::VertexId;

/// A single graph mutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeOp {
    /// `e+` — add edge (src, dst).
    AddEdge(VertexId, VertexId),
    /// `e-` — remove edge (src, dst).
    RemoveEdge(VertexId, VertexId),
    /// `v+` — add an isolated vertex.
    AddVertex(VertexId),
    /// `v-` — remove a vertex and incident edges.
    RemoveVertex(VertexId),
}

impl EdgeOp {
    /// Convenience constructor for the common case.
    pub fn add(src: VertexId, dst: VertexId) -> Self {
        EdgeOp::AddEdge(src, dst)
    }

    /// Convenience constructor.
    pub fn remove(src: VertexId, dst: VertexId) -> Self {
        EdgeOp::RemoveEdge(src, dst)
    }
}

/// An event as consumed by the engine's Alg.-1 loop: either a mutation or
/// a query trigger.
#[derive(Clone, Debug, PartialEq)]
pub enum UpdateEvent {
    /// Graph mutation, buffered until the next query applies updates.
    Op(EdgeOp),
    /// Client query — serve algorithm results now.
    Query,
    /// End of stream.
    Stop,
}

impl From<EdgeOp> for UpdateEvent {
    fn from(op: EdgeOp) -> Self {
        UpdateEvent::Op(op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_conversion() {
        assert_eq!(EdgeOp::add(1, 2), EdgeOp::AddEdge(1, 2));
        assert_eq!(EdgeOp::remove(1, 2), EdgeOp::RemoveEdge(1, 2));
        let ev: UpdateEvent = EdgeOp::add(3, 4).into();
        assert_eq!(ev, UpdateEvent::Op(EdgeOp::AddEdge(3, 4)));
    }
}
