//! Pending-update buffer and update statistics (Alg. 1's
//! `RegisterAddEdge` / `RegisterRemoveEdge` / `graphUpdateStatistics`).
//!
//! “GraphBolt registers updates as they arrive for both statistical and
//! processing purposes. Vertex and edge changes are kept until updates are
//! formally applied to the graph. Until they are applied, statistics …
//! are readily available.” (§3.2)
//!
//! The buffer also captures, at apply time, the *previous* degree
//! `d_{t-1}(u)` of every touched vertex — exactly the quantity Eq. 2's
//! update-ratio threshold needs at the next measurement point.
//!
//! Two apply paths exist. [`UpdateBuffer::apply`] is the sequential
//! reference: one graph mutation per raw op. [`UpdateBuffer::take_batch`]
//! is the batched write pipeline's coalescing stage: it drains the raw
//! ops into an [`UpdateBatch`] of *effective* ops (duplicate adds
//! collapse, add-then-remove pairs cancel, last-writer-wins per
//! (src, dst)) that [`DynamicGraph::apply_batch`] applies with one row
//! mutation per touched row and one version bump per batch — final state
//! bit-identical to the sequential path.

use std::collections::{HashMap, HashSet};

use crate::error::Result;
use crate::graph::dynamic::DynamicGraph;
use crate::graph::VertexId;
use crate::stream::event::EdgeOp;

/// Read-only statistics over pending (unapplied) updates.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct UpdateStatistics {
    /// Pending `e+` count.
    pub pending_add_edges: usize,
    /// Pending `e-` count.
    pub pending_remove_edges: usize,
    /// Pending `v+` count.
    pub pending_add_vertices: usize,
    /// Pending `v-` count.
    pub pending_remove_vertices: usize,
    /// Distinct vertices touched by pending updates.
    pub touched_vertices: usize,
    /// Current total vertices in the graph (pre-apply).
    pub total_vertices: usize,
    /// Current total edges in the graph (pre-apply).
    pub total_edges: usize,
    /// Coalescing *estimate* for the pending ops: distinct pending
    /// (src, dst) pairs (last-writer-wins) plus pending vertex ops.
    /// Graph-free and approximate in both directions — cancellations
    /// against the live topology push the true effective count below
    /// it, while synthesized endpoint creations and re-establish
    /// remove+add pairs can push it slightly above. The exact numbers
    /// land in [`Self::coalesced_raw_ops`] /
    /// [`Self::coalesced_effective_ops`] once a batch is drained.
    pub pending_effective_estimate: usize,
    /// Cumulative raw ops drained through [`UpdateBuffer::take_batch`].
    pub coalesced_raw_ops: usize,
    /// Cumulative effective ops those batches kept after coalescing.
    pub coalesced_effective_ops: usize,
}

impl UpdateStatistics {
    /// Total pending operations.
    pub fn pending_total(&self) -> usize {
        self.pending_add_edges
            + self.pending_remove_edges
            + self.pending_add_vertices
            + self.pending_remove_vertices
    }

    /// Touched vertices as a fraction of the current graph (the kind of
    /// magnitude signal `BeforeUpdates` policies use).
    pub fn touched_ratio(&self) -> f64 {
        if self.total_vertices == 0 {
            if self.touched_vertices > 0 { 1.0 } else { 0.0 }
        } else {
            self.touched_vertices as f64 / self.total_vertices as f64
        }
    }
}

/// Result of applying the buffered updates to the graph.
#[derive(Clone, Debug, Default)]
pub struct AppliedUpdates {
    /// `d_{t-1}` (total degree before apply) per touched vertex.
    /// Vertices new at this measurement point are *absent* from the map.
    pub prev_degree: HashMap<VertexId, usize>,
    /// Vertices that did not exist before this apply (paper footnote 2:
    /// always included in `K_r`).
    pub new_vertices: Vec<VertexId>,
    /// Operations applied / skipped (duplicate edge, missing edge, …).
    pub applied: usize,
    /// Skipped operations with reasons (duplicates are benign in replays).
    pub skipped: usize,
}

/// Per-kind pending-operation counters, maintained incrementally so
/// [`UpdateBuffer::statistics`] is O(1) per query instead of rescanning
/// every pending op.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct PendingCounts {
    add_edges: usize,
    remove_edges: usize,
    add_vertices: usize,
    remove_vertices: usize,
}

impl PendingCounts {
    fn bump(&mut self, op: &EdgeOp) {
        match op {
            EdgeOp::AddEdge(..) => self.add_edges += 1,
            EdgeOp::RemoveEdge(..) => self.remove_edges += 1,
            EdgeOp::AddVertex(..) => self.add_vertices += 1,
            EdgeOp::RemoveVertex(..) => self.remove_vertices += 1,
        }
    }
}

/// The pending-update buffer.
#[derive(Clone, Debug, Default)]
pub struct UpdateBuffer {
    ops: Vec<EdgeOp>,
    touched: HashSet<VertexId>,
    counts: PendingCounts,
    /// Distinct (src, dst) pairs among pending edge ops — the O(1)
    /// last-writer-wins coalescing estimate behind
    /// [`UpdateStatistics::pending_effective_estimate`].
    pairs: HashSet<(VertexId, VertexId)>,
    /// Cumulative (raw, effective) op counts across every drained batch.
    coalesced_raw: usize,
    coalesced_effective: usize,
}

impl UpdateBuffer {
    /// Empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one operation (Alg. 1 lines 4–5).
    pub fn register(&mut self, op: EdgeOp) {
        match op {
            EdgeOp::AddEdge(u, v) | EdgeOp::RemoveEdge(u, v) => {
                self.touched.insert(u);
                self.touched.insert(v);
                self.pairs.insert((u, v));
            }
            EdgeOp::AddVertex(u) | EdgeOp::RemoveVertex(u) => {
                self.touched.insert(u);
            }
        }
        self.counts.bump(&op);
        self.ops.push(op);
    }

    /// Register a whole batch of operations in one call (the write-path
    /// twin of [`crate::graph::dynamic::DynamicGraph::apply_batch`]):
    /// reserves once and returns how many ops were buffered, so callers
    /// pay one bookkeeping step per batch instead of one per op.
    pub fn register_batch(&mut self, ops: impl IntoIterator<Item = EdgeOp>) -> usize {
        let it = ops.into_iter();
        let (lo, _) = it.size_hint();
        self.ops.reserve(lo);
        let before = self.ops.len();
        for op in it {
            self.register(op);
        }
        self.ops.len() - before
    }

    /// Number of pending operations.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True if nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Pending operations (exposed to the `BeforeUpdates` UDF).
    pub fn pending(&self) -> &[EdgeOp] {
        &self.ops
    }

    /// Discard all pending operations without applying them (load
    /// shedding at the buffer level).
    pub fn clear(&mut self) {
        self.ops.clear();
        self.touched.clear();
        self.pairs.clear();
        self.counts = PendingCounts::default();
    }

    /// Cumulative (raw, effective) op counts across every batch drained
    /// with [`Self::take_batch`].
    pub fn coalesce_totals(&self) -> (usize, usize) {
        (self.coalesced_raw, self.coalesced_effective)
    }

    /// O(1) upper bound on the effective ops currently pending (distinct
    /// touched pairs plus vertex ops) — the graph-free slice of
    /// [`Self::statistics`], cheap enough to refresh a live gauge on
    /// every ingest.
    pub fn pending_effective_estimate(&self) -> usize {
        self.pairs.len() + self.counts.add_vertices + self.counts.remove_vertices
    }

    /// Statistics snapshot against the current (pre-apply) graph — O(1):
    /// the per-kind counters are maintained by `register`/`apply`/`clear`
    /// rather than recounted per query.
    pub fn statistics(&self, g: &DynamicGraph) -> UpdateStatistics {
        UpdateStatistics {
            pending_add_edges: self.counts.add_edges,
            pending_remove_edges: self.counts.remove_edges,
            pending_add_vertices: self.counts.add_vertices,
            pending_remove_vertices: self.counts.remove_vertices,
            touched_vertices: self.touched.len(),
            total_vertices: g.num_vertices(),
            total_edges: g.num_edges(),
            pending_effective_estimate: self.pairs.len()
                + self.counts.add_vertices
                + self.counts.remove_vertices,
            coalesced_raw_ops: self.coalesced_raw,
            coalesced_effective_ops: self.coalesced_effective,
        }
    }

    /// Apply all pending updates to `g` (Alg. 1 `ApplyUpdates`), capturing
    /// `d_{t-1}` for every touched vertex and the set of new vertices.
    /// Duplicate adds / missing removes are counted as skipped, not fatal —
    /// stream replays may contain them.
    pub fn apply(&mut self, g: &mut DynamicGraph) -> Result<AppliedUpdates> {
        let mut out = AppliedUpdates::default();
        // Capture previous degrees before any mutation.
        for &id in &self.touched {
            match g.index(id) {
                Some(idx) => {
                    out.prev_degree.insert(id, g.degree(idx));
                }
                None => out.new_vertices.push(id),
            }
        }
        out.new_vertices.sort_unstable();
        for op in self.ops.drain(..) {
            let ok = match op {
                EdgeOp::AddEdge(u, v) => g.add_edge(u, v).is_ok(),
                EdgeOp::RemoveEdge(u, v) => g.remove_edge(u, v).is_ok(),
                EdgeOp::AddVertex(u) => {
                    g.add_vertex(u);
                    true
                }
                EdgeOp::RemoveVertex(u) => g.remove_vertex(u).is_ok(),
            };
            if ok {
                out.applied += 1;
            } else {
                out.skipped += 1;
            }
        }
        self.touched.clear();
        self.pairs.clear();
        self.counts = PendingCounts::default();
        Ok(out)
    }

    /// Drain the pending ops into a coalesced [`UpdateBatch`] against the
    /// current (pre-apply) graph. The batch's effective op list, applied
    /// sequentially, is **bit-identical** to sequentially applying the
    /// raw pending ops — including adjacency append order and vertex
    /// creation (dense-index) order — while dropping every no-op:
    ///
    /// * duplicate adds collapse (the first establishing add survives);
    /// * an add followed by a remove of the same edge cancels outright
    ///   (but the vertices the add created are still created);
    /// * per (src, dst), only the last-written state survives;
    /// * removes of absent edges and re-inserts of existing vertices drop.
    ///
    /// `RemoveVertex` ops act as sequence points: edge ops coalesce
    /// within the segments between them, and cross-segment edge presence
    /// is tracked so later segments coalesce against the state the
    /// earlier ones will have produced.
    pub fn take_batch(&mut self, g: &DynamicGraph) -> UpdateBatch {
        let raw = std::mem::take(&mut self.ops);
        let mut touched: Vec<VertexId> = self.touched.drain().collect();
        touched.sort_unstable();
        self.pairs.clear();
        self.counts = PendingCounts::default();
        let mut batch = UpdateBatch { raw_ops: raw.len(), touched, ..Default::default() };

        // Cross-segment state: `overlay` holds the post-segment presence
        // of every pair the batch touched, stamped with the barrier
        // epoch it was written at; `removed_at` the epoch a barrier last
        // wiped each vertex. An overlay entry older than a wipe of
        // either endpoint is dead — checked lazily at lookup, so a
        // barrier costs O(1) instead of rescanning every overlay pair.
        // `created` tracks the vertices this batch creates.
        let mut overlay: HashMap<(VertexId, VertexId), (bool, u64)> = HashMap::new();
        let mut removed_at: HashMap<VertexId, u64> = HashMap::new();
        let mut epoch: u64 = 0;
        let mut created: HashSet<VertexId> = HashSet::new();

        // Current-segment state: per-pair simulation in first-touch order.
        let mut pairs: HashMap<(VertexId, VertexId), PairSim> = HashMap::new();
        let mut order: Vec<(VertexId, VertexId)> = Vec::new();
        // Lazy per-source hashed neighbor sets: presence probes against a
        // high-degree row hash once instead of scanning O(degree) per
        // first-touched pair (the hub-dismantling batch shape).
        let mut nbrs: HashMap<VertexId, HashSet<VertexId>> = HashMap::new();
        // Emitted (raw position, op) entries; sorted once at the end.
        let mut out: Vec<(usize, EdgeOp)> = Vec::new();

        for (pos, op) in raw.iter().enumerate() {
            match *op {
                EdgeOp::AddVertex(u) => {
                    if g.index(u).is_some() || created.contains(&u) {
                        batch.collapsed += 1; // re-insert of an existing vertex: no-op
                    } else {
                        created.insert(u);
                        out.push((pos, EdgeOp::AddVertex(u)));
                    }
                }
                EdgeOp::AddEdge(u, v) | EdgeOp::RemoveEdge(u, v) => {
                    let is_add = matches!(op, EdgeOp::AddEdge(..));
                    if is_add {
                        // `add_edge` creates missing endpoints before the
                        // duplicate check, so creation order follows the
                        // raw adds even when the edge op itself coalesces
                        // away (the cancelling-pair case).
                        for id in [u, v] {
                            if g.index(id).is_none() && !created.contains(&id) {
                                created.insert(id);
                                out.push((pos, EdgeOp::AddVertex(id)));
                            }
                        }
                    }
                    let st = pairs.entry((u, v)).or_insert_with(|| {
                        order.push((u, v));
                        let wiped = removed_at
                            .get(&u)
                            .copied()
                            .unwrap_or(0)
                            .max(removed_at.get(&v).copied().unwrap_or(0));
                        let p0 = match overlay.get(&(u, v)) {
                            Some(&(present, at)) if at >= wiped => present,
                            Some(_) => false, // wiped by a later barrier
                            None if wiped > 0 => false,
                            None => has_edge_cached(g, &mut nbrs, u, v),
                        };
                        PairSim { p0, present: p0, est: None, fr: None, had_add: false }
                    });
                    if is_add {
                        st.had_add = true;
                        if st.present {
                            batch.collapsed += 1; // duplicate add
                        } else {
                            st.present = true;
                            st.est = Some(pos);
                        }
                    } else if st.present {
                        st.present = false;
                        st.est = None;
                        if st.fr.is_none() {
                            st.fr = Some(pos);
                        }
                    } else {
                        batch.collapsed += 1; // remove of an absent edge
                    }
                }
                EdgeOp::RemoveVertex(u) => {
                    if g.index(u).is_some() || created.contains(&u) {
                        // A real barrier: flush the segment so the apply
                        // step splits exactly here. (A removal of an
                        // unknown vertex is a no-op in the raw sequence,
                        // so edge ops coalesce straight through it.)
                        let b = &mut batch;
                        flush_segment(&mut pairs, &mut order, &mut out, &mut overlay, epoch, b);
                        out.push((pos, EdgeOp::RemoveVertex(u)));
                        epoch += 1;
                        removed_at.insert(u, epoch);
                    } else {
                        batch.collapsed += 1; // unknown vertex: raw op errors
                    }
                }
            }
        }
        flush_segment(&mut pairs, &mut order, &mut out, &mut overlay, epoch, &mut batch);

        // Stable sort: emissions sharing a raw position (a pair's two
        // endpoint creations) keep their emission order.
        out.sort_by_key(|&(pos, _)| pos);
        batch.ops = out.into_iter().map(|(_, op)| op).collect();
        self.coalesced_raw += batch.raw_ops;
        self.coalesced_effective += batch.ops.len();
        batch
    }
}

/// Source out-degree past which [`has_edge_cached`] hashes the row's
/// neighbor set once instead of linearly scanning it per probe.
const HAS_EDGE_HASH_MIN: usize = 64;

/// Pre-batch edge-presence probe with a lazy per-source hash: low-degree
/// rows use the ordinary linear `has_edge`, high-degree rows pay one
/// O(degree) set build on first touch and O(1) per probe after.
fn has_edge_cached(
    g: &DynamicGraph,
    cache: &mut HashMap<VertexId, HashSet<VertexId>>,
    u: VertexId,
    v: VertexId,
) -> bool {
    let s = match g.index(u) {
        Some(s) => s,
        None => return false,
    };
    if g.out_degree(s) < HAS_EDGE_HASH_MIN {
        return g.has_edge(u, v);
    }
    cache
        .entry(u)
        .or_insert_with(|| g.out_neighbors(s).iter().map(|&d| g.id(d)).collect())
        .contains(&v)
}

/// Per-(src, dst) simulation state for one coalescing segment.
struct PairSim {
    /// Presence at segment start.
    p0: bool,
    /// Simulated presence so far.
    present: bool,
    /// Position of the add that establishes the pair's final presence
    /// (cleared by a later remove). Appends replayed in `est` order
    /// reproduce the raw adjacency append order exactly.
    est: Option<usize>,
    /// Position of the first effective remove (where the surviving
    /// removal of an initially-present edge is emitted).
    fr: Option<usize>,
    /// Whether any add was seen (distinguishes cancelled pairs from
    /// pure no-op removes).
    had_add: bool,
}

/// Emit one segment's surviving ops and roll its final presences into
/// the cross-segment overlay, stamped with the current barrier `epoch`
/// (entries older than a wipe of either endpoint are dead — see
/// [`UpdateBuffer::take_batch`]).
fn flush_segment(
    pairs: &mut HashMap<(VertexId, VertexId), PairSim>,
    order: &mut Vec<(VertexId, VertexId)>,
    out: &mut Vec<(usize, EdgeOp)>,
    overlay: &mut HashMap<(VertexId, VertexId), (bool, u64)>,
    epoch: u64,
    batch: &mut UpdateBatch,
) {
    for pair in order.drain(..) {
        let st = &pairs[&pair];
        if st.p0 && (st.est.is_some() || !st.present) {
            // Initially present and either net-removed or re-established
            // (remove-then-add moves the edge to the append position).
            let fr = st.fr.expect("effective remove recorded");
            out.push((fr, EdgeOp::RemoveEdge(pair.0, pair.1)));
        }
        if let Some(p) = st.est {
            out.push((p, EdgeOp::AddEdge(pair.0, pair.1)));
        }
        if !st.p0 && !st.present && st.had_add {
            batch.cancelled_pairs += 1;
        }
        overlay.insert(pair, (st.present, epoch));
    }
    pairs.clear();
}

/// A coalesced batch drained from the buffer: the effective operations
/// whose sequential application is bit-identical to sequentially applying
/// the raw pending operations, plus coalescing statistics. Feed
/// [`Self::ops`] to [`DynamicGraph::apply_batch`] for the grouped,
/// single-version-bump apply.
#[derive(Clone, Debug, Default)]
pub struct UpdateBatch {
    /// Effective operations in canonical (raw-position) order.
    ops: Vec<EdgeOp>,
    /// Distinct vertices the raw ops touched (sorted) — what the degree
    /// baseline capture needs before the batch is applied.
    touched: Vec<VertexId>,
    /// Raw operations drained into this batch.
    pub raw_ops: usize,
    /// Raw operations dropped as no-ops (duplicate adds, removes of
    /// absent edges, re-inserts of existing vertices, unknown-vertex
    /// removals).
    pub collapsed: usize,
    /// Pairs whose adds and removes cancelled outright (the
    /// add-then-remove case; their vertex creations are preserved).
    pub cancelled_pairs: usize,
}

impl UpdateBatch {
    /// The effective ops, in application order.
    pub fn ops(&self) -> &[EdgeOp] {
        &self.ops
    }

    /// Distinct vertices the raw ops touched (sorted).
    pub fn touched(&self) -> &[VertexId] {
        &self.touched
    }

    /// Number of effective ops kept after coalescing.
    pub fn effective_ops(&self) -> usize {
        self.ops.len()
    }

    /// True when coalescing left nothing to apply.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_tracks_touched_and_counts() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(1, 3));
        buf.register(EdgeOp::add(3, 2));
        buf.register(EdgeOp::remove(1, 2));
        let s = buf.statistics(&g);
        assert_eq!(s.pending_add_edges, 2);
        assert_eq!(s.pending_remove_edges, 1);
        assert_eq!(s.touched_vertices, 3);
        assert_eq!(s.pending_total(), 3);
        assert_eq!(s.total_edges, 1);
        assert!((s.touched_ratio() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn apply_captures_prev_degrees_and_new_vertices() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 3)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(2, 9)); // 9 is new
        buf.register(EdgeOp::add(1, 3));
        let out = buf.apply(&mut g).unwrap();
        assert_eq!(out.new_vertices, vec![9]);
        // 2 had degree 2 (in 1, out 1) before apply
        assert_eq!(out.prev_degree[&2], 2);
        assert_eq!(out.prev_degree[&1], 1);
        assert_eq!(out.applied, 2);
        assert_eq!(out.skipped, 0);
        assert!(g.has_edge(2, 9) && g.has_edge(1, 3));
        assert!(buf.is_empty());
    }

    #[test]
    fn duplicate_add_is_skipped_not_fatal() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(1, 2));
        buf.register(EdgeOp::remove(5, 6)); // nothing there
        let out = buf.apply(&mut g).unwrap();
        assert_eq!(out.applied, 0);
        assert_eq!(out.skipped, 2);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn vertex_ops_apply() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 1)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::AddVertex(7));
        buf.register(EdgeOp::RemoveVertex(2));
        let out = buf.apply(&mut g).unwrap();
        assert_eq!(out.applied, 2);
        assert_eq!(g.num_edges(), 0);
        assert!(g.index(7).is_some());
    }

    #[test]
    fn statistics_reset_after_apply() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(2, 3));
        buf.apply(&mut g).unwrap();
        let s = buf.statistics(&g);
        assert_eq!(s.pending_total(), 0);
        assert_eq!(s.touched_vertices, 0);
    }

    #[test]
    fn clear_discards_pending_without_applying() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(2, 3));
        buf.register(EdgeOp::AddVertex(9));
        buf.clear();
        assert!(buf.is_empty());
        let s = buf.statistics(&g);
        assert_eq!(s.pending_total(), 0);
        assert_eq!(s.touched_vertices, 0);
        let out = buf.apply(&mut g).unwrap();
        assert_eq!(out.applied + out.skipped, 0);
        assert_eq!(g.num_edges(), 1);
    }

    /// Recount from scratch — the oracle the incremental counters must
    /// match at every point of an interleaved register/apply/clear run.
    fn rescan(buf: &UpdateBuffer, g: &DynamicGraph) -> UpdateStatistics {
        let (raw, effective) = buf.coalesce_totals();
        let mut s = UpdateStatistics {
            total_vertices: g.num_vertices(),
            total_edges: g.num_edges(),
            coalesced_raw_ops: raw,
            coalesced_effective_ops: effective,
            ..Default::default()
        };
        let mut touched = std::collections::HashSet::new();
        let mut pairs = std::collections::HashSet::new();
        for op in buf.pending() {
            match op {
                EdgeOp::AddEdge(u, v) => {
                    s.pending_add_edges += 1;
                    touched.insert(*u);
                    touched.insert(*v);
                    pairs.insert((*u, *v));
                }
                EdgeOp::RemoveEdge(u, v) => {
                    s.pending_remove_edges += 1;
                    touched.insert(*u);
                    touched.insert(*v);
                    pairs.insert((*u, *v));
                }
                EdgeOp::AddVertex(u) => {
                    s.pending_add_vertices += 1;
                    touched.insert(*u);
                }
                EdgeOp::RemoveVertex(u) => {
                    s.pending_remove_vertices += 1;
                    touched.insert(*u);
                }
            }
        }
        s.touched_vertices = touched.len();
        s.pending_effective_estimate =
            pairs.len() + s.pending_add_vertices + s.pending_remove_vertices;
        s
    }

    #[test]
    fn incremental_counters_match_rescan_under_interleaving() {
        use crate::util::rng::Xoshiro256pp;
        let (mut g, _) = DynamicGraph::from_edges(vec![(0, 1), (1, 2), (2, 0)]);
        let mut buf = UpdateBuffer::new();
        let mut rng = Xoshiro256pp::new(0xBEEF);
        for step in 0..400u32 {
            match rng.next_below(22) {
                0..=9 => {
                    let (u, v) = (rng.next_below(30), rng.next_below(30));
                    buf.register(if rng.next_below(4) == 0 {
                        EdgeOp::remove(u, v)
                    } else {
                        EdgeOp::add(u, v)
                    });
                }
                10..=13 => buf.register(EdgeOp::AddVertex(rng.next_below(40))),
                14..=15 => buf.register(EdgeOp::RemoveVertex(rng.next_below(40))),
                16..=17 => {
                    buf.apply(&mut g).unwrap();
                }
                18..=19 => {
                    let batch = buf.take_batch(&g);
                    g.apply_batch(batch.ops(), None, 1);
                }
                _ => buf.clear(),
            }
            assert_eq!(buf.statistics(&g), rescan(&buf, &g), "step {step}");
        }
    }

    // ---- coalescing ----------------------------------------------------

    // Op-by-op oracle: sequentially applying a batch's effective ops
    // must leave the graph in exactly the state the raw ops would have
    // (shared reference path in crate::testing::oracle).
    use crate::testing::oracle::seq_apply;

    fn assert_same_graph(a: &DynamicGraph, b: &DynamicGraph, what: &str) {
        assert_eq!(a.ids(), b.ids(), "{what}: vertex order");
        assert_eq!(a.num_edges(), b.num_edges(), "{what}: edge count");
        assert_eq!(a.snapshot(), b.snapshot(), "{what}: snapshot");
    }

    #[test]
    fn coalesce_collapses_duplicate_adds() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(2, 3));
        buf.register(EdgeOp::add(2, 3)); // duplicate within the batch
        buf.register(EdgeOp::add(1, 2)); // duplicate against the graph
        let batch = buf.take_batch(&g);
        assert_eq!(batch.raw_ops, 3);
        assert_eq!(batch.effective_ops(), 2, "AddVertex(3) + add(2,3)");
        assert_eq!(batch.collapsed, 2);
        assert!(buf.is_empty());
    }

    #[test]
    fn coalesce_cancels_add_remove_but_keeps_vertices() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(7, 8)); // both endpoints new
        buf.register(EdgeOp::remove(7, 8)); // cancels the add
        let batch = buf.take_batch(&g);
        assert_eq!(batch.cancelled_pairs, 1);
        assert_eq!(batch.ops(), &[EdgeOp::AddVertex(7), EdgeOp::AddVertex(8)]);
        // Oracle: the raw sequence also leaves 7 and 8 as isolated slots.
        let mut a = g.clone();
        seq_apply(&mut a, batch.ops());
        let mut b = g.clone();
        seq_apply(&mut b, &[EdgeOp::add(7, 8), EdgeOp::remove(7, 8)]);
        assert_same_graph(&a, &b, "cancelled pair");
    }

    #[test]
    fn coalesce_last_writer_wins_per_pair() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2), (3, 4)]);
        let mut buf = UpdateBuffer::new();
        // (1,2): present → remove, add, remove ⇒ net remove
        buf.register(EdgeOp::remove(1, 2));
        buf.register(EdgeOp::add(1, 2));
        buf.register(EdgeOp::remove(1, 2));
        // (3,4): present → remove, add ⇒ re-established (moves to append slot)
        buf.register(EdgeOp::remove(3, 4));
        buf.register(EdgeOp::add(3, 4));
        let batch = buf.take_batch(&g);
        assert_eq!(batch.ops(), &[EdgeOp::remove(1, 2), EdgeOp::remove(3, 4), EdgeOp::add(3, 4)]);
        let mut a = g.clone();
        seq_apply(&mut a, batch.ops());
        assert!(!a.has_edge(1, 2) && a.has_edge(3, 4));
    }

    #[test]
    fn coalesce_treats_vertex_removal_as_sequence_point() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 3)]);
        let raw = vec![
            EdgeOp::add(2, 9),
            EdgeOp::RemoveVertex(2), // wipes (1,2), (2,3), (2,9)
            EdgeOp::add(2, 3),       // re-added after the barrier
            EdgeOp::remove(1, 2),    // absent post-barrier: collapses
        ];
        let mut buf = UpdateBuffer::new();
        for op in &raw {
            buf.register(*op);
        }
        let batch = buf.take_batch(&g);
        let mut a = g.clone();
        seq_apply(&mut a, batch.ops());
        let mut b = g.clone();
        seq_apply(&mut b, &raw);
        assert_same_graph(&a, &b, "barrier");
        assert!(a.has_edge(2, 3) && !a.has_edge(1, 2) && !a.has_edge(2, 9));
    }

    #[test]
    fn coalesced_sequential_apply_matches_raw_append_order() {
        // The establishment-order rule: [add(a,x), remove(a,x), add(b,x),
        // add(a,x)] must leave x's in-adjacency as [b, a], exactly as the
        // raw sequence does.
        let g = DynamicGraph::new();
        let raw =
            vec![EdgeOp::add(10, 5), EdgeOp::remove(10, 5), EdgeOp::add(11, 5), EdgeOp::add(10, 5)];
        let mut buf = UpdateBuffer::new();
        for op in &raw {
            buf.register(*op);
        }
        let batch = buf.take_batch(&g);
        let mut a = g.clone();
        seq_apply(&mut a, batch.ops());
        let mut b = g.clone();
        seq_apply(&mut b, &raw);
        assert_same_graph(&a, &b, "append order");
    }
}
