//! Pending-update buffer and update statistics (Alg. 1's
//! `RegisterAddEdge` / `RegisterRemoveEdge` / `graphUpdateStatistics`).
//!
//! “GraphBolt registers updates as they arrive for both statistical and
//! processing purposes. Vertex and edge changes are kept until updates are
//! formally applied to the graph. Until they are applied, statistics …
//! are readily available.” (§3.2)
//!
//! The buffer also captures, at apply time, the *previous* degree
//! `d_{t-1}(u)` of every touched vertex — exactly the quantity Eq. 2's
//! update-ratio threshold needs at the next measurement point.

use std::collections::HashMap;

use crate::error::Result;
use crate::graph::dynamic::DynamicGraph;
use crate::graph::VertexId;
use crate::stream::event::EdgeOp;

/// Read-only statistics over pending (unapplied) updates.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct UpdateStatistics {
    /// Pending `e+` count.
    pub pending_add_edges: usize,
    /// Pending `e-` count.
    pub pending_remove_edges: usize,
    /// Pending `v+` count.
    pub pending_add_vertices: usize,
    /// Pending `v-` count.
    pub pending_remove_vertices: usize,
    /// Distinct vertices touched by pending updates.
    pub touched_vertices: usize,
    /// Current total vertices in the graph (pre-apply).
    pub total_vertices: usize,
    /// Current total edges in the graph (pre-apply).
    pub total_edges: usize,
}

impl UpdateStatistics {
    /// Total pending operations.
    pub fn pending_total(&self) -> usize {
        self.pending_add_edges
            + self.pending_remove_edges
            + self.pending_add_vertices
            + self.pending_remove_vertices
    }

    /// Touched vertices as a fraction of the current graph (the kind of
    /// magnitude signal `BeforeUpdates` policies use).
    pub fn touched_ratio(&self) -> f64 {
        if self.total_vertices == 0 {
            if self.touched_vertices > 0 { 1.0 } else { 0.0 }
        } else {
            self.touched_vertices as f64 / self.total_vertices as f64
        }
    }
}

/// Result of applying the buffered updates to the graph.
#[derive(Clone, Debug, Default)]
pub struct AppliedUpdates {
    /// `d_{t-1}` (total degree before apply) per touched vertex.
    /// Vertices new at this measurement point are *absent* from the map.
    pub prev_degree: HashMap<VertexId, usize>,
    /// Vertices that did not exist before this apply (paper footnote 2:
    /// always included in `K_r`).
    pub new_vertices: Vec<VertexId>,
    /// Operations applied / skipped (duplicate edge, missing edge, …).
    pub applied: usize,
    /// Skipped operations with reasons (duplicates are benign in replays).
    pub skipped: usize,
}

/// Per-kind pending-operation counters, maintained incrementally so
/// [`UpdateBuffer::statistics`] is O(1) per query instead of rescanning
/// every pending op.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct PendingCounts {
    add_edges: usize,
    remove_edges: usize,
    add_vertices: usize,
    remove_vertices: usize,
}

impl PendingCounts {
    fn bump(&mut self, op: &EdgeOp) {
        match op {
            EdgeOp::AddEdge(..) => self.add_edges += 1,
            EdgeOp::RemoveEdge(..) => self.remove_edges += 1,
            EdgeOp::AddVertex(..) => self.add_vertices += 1,
            EdgeOp::RemoveVertex(..) => self.remove_vertices += 1,
        }
    }
}

/// The pending-update buffer.
#[derive(Clone, Debug, Default)]
pub struct UpdateBuffer {
    ops: Vec<EdgeOp>,
    touched: std::collections::HashSet<VertexId>,
    counts: PendingCounts,
}

impl UpdateBuffer {
    /// Empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one operation (Alg. 1 lines 4–5).
    pub fn register(&mut self, op: EdgeOp) {
        match op {
            EdgeOp::AddEdge(u, v) | EdgeOp::RemoveEdge(u, v) => {
                self.touched.insert(u);
                self.touched.insert(v);
            }
            EdgeOp::AddVertex(u) | EdgeOp::RemoveVertex(u) => {
                self.touched.insert(u);
            }
        }
        self.counts.bump(&op);
        self.ops.push(op);
    }

    /// Number of pending operations.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True if nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Pending operations (exposed to the `BeforeUpdates` UDF).
    pub fn pending(&self) -> &[EdgeOp] {
        &self.ops
    }

    /// Discard all pending operations without applying them (load
    /// shedding at the buffer level).
    pub fn clear(&mut self) {
        self.ops.clear();
        self.touched.clear();
        self.counts = PendingCounts::default();
    }

    /// Statistics snapshot against the current (pre-apply) graph — O(1):
    /// the per-kind counters are maintained by `register`/`apply`/`clear`
    /// rather than recounted per query.
    pub fn statistics(&self, g: &DynamicGraph) -> UpdateStatistics {
        UpdateStatistics {
            pending_add_edges: self.counts.add_edges,
            pending_remove_edges: self.counts.remove_edges,
            pending_add_vertices: self.counts.add_vertices,
            pending_remove_vertices: self.counts.remove_vertices,
            touched_vertices: self.touched.len(),
            total_vertices: g.num_vertices(),
            total_edges: g.num_edges(),
        }
    }

    /// Apply all pending updates to `g` (Alg. 1 `ApplyUpdates`), capturing
    /// `d_{t-1}` for every touched vertex and the set of new vertices.
    /// Duplicate adds / missing removes are counted as skipped, not fatal —
    /// stream replays may contain them.
    pub fn apply(&mut self, g: &mut DynamicGraph) -> Result<AppliedUpdates> {
        let mut out = AppliedUpdates::default();
        // Capture previous degrees before any mutation.
        for &id in &self.touched {
            match g.index(id) {
                Some(idx) => {
                    out.prev_degree.insert(id, g.degree(idx));
                }
                None => out.new_vertices.push(id),
            }
        }
        out.new_vertices.sort_unstable();
        for op in self.ops.drain(..) {
            let ok = match op {
                EdgeOp::AddEdge(u, v) => g.add_edge(u, v).is_ok(),
                EdgeOp::RemoveEdge(u, v) => g.remove_edge(u, v).is_ok(),
                EdgeOp::AddVertex(u) => {
                    g.add_vertex(u);
                    true
                }
                EdgeOp::RemoveVertex(u) => g.remove_vertex(u).is_ok(),
            };
            if ok {
                out.applied += 1;
            } else {
                out.skipped += 1;
            }
        }
        self.touched.clear();
        self.counts = PendingCounts::default();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_tracks_touched_and_counts() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(1, 3));
        buf.register(EdgeOp::add(3, 2));
        buf.register(EdgeOp::remove(1, 2));
        let s = buf.statistics(&g);
        assert_eq!(s.pending_add_edges, 2);
        assert_eq!(s.pending_remove_edges, 1);
        assert_eq!(s.touched_vertices, 3);
        assert_eq!(s.pending_total(), 3);
        assert_eq!(s.total_edges, 1);
        assert!((s.touched_ratio() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn apply_captures_prev_degrees_and_new_vertices() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 3)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(2, 9)); // 9 is new
        buf.register(EdgeOp::add(1, 3));
        let out = buf.apply(&mut g).unwrap();
        assert_eq!(out.new_vertices, vec![9]);
        // 2 had degree 2 (in 1, out 1) before apply
        assert_eq!(out.prev_degree[&2], 2);
        assert_eq!(out.prev_degree[&1], 1);
        assert_eq!(out.applied, 2);
        assert_eq!(out.skipped, 0);
        assert!(g.has_edge(2, 9) && g.has_edge(1, 3));
        assert!(buf.is_empty());
    }

    #[test]
    fn duplicate_add_is_skipped_not_fatal() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(1, 2));
        buf.register(EdgeOp::remove(5, 6)); // nothing there
        let out = buf.apply(&mut g).unwrap();
        assert_eq!(out.applied, 0);
        assert_eq!(out.skipped, 2);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn vertex_ops_apply() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 1)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::AddVertex(7));
        buf.register(EdgeOp::RemoveVertex(2));
        let out = buf.apply(&mut g).unwrap();
        assert_eq!(out.applied, 2);
        assert_eq!(g.num_edges(), 0);
        assert!(g.index(7).is_some());
    }

    #[test]
    fn statistics_reset_after_apply() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(2, 3));
        buf.apply(&mut g).unwrap();
        let s = buf.statistics(&g);
        assert_eq!(s.pending_total(), 0);
        assert_eq!(s.touched_vertices, 0);
    }

    #[test]
    fn clear_discards_pending_without_applying() {
        let (mut g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut buf = UpdateBuffer::new();
        buf.register(EdgeOp::add(2, 3));
        buf.register(EdgeOp::AddVertex(9));
        buf.clear();
        assert!(buf.is_empty());
        let s = buf.statistics(&g);
        assert_eq!(s.pending_total(), 0);
        assert_eq!(s.touched_vertices, 0);
        let out = buf.apply(&mut g).unwrap();
        assert_eq!(out.applied + out.skipped, 0);
        assert_eq!(g.num_edges(), 1);
    }

    /// Recount from scratch — the oracle the incremental counters must
    /// match at every point of an interleaved register/apply/clear run.
    fn rescan(buf: &UpdateBuffer, g: &DynamicGraph) -> UpdateStatistics {
        let mut s = UpdateStatistics {
            total_vertices: g.num_vertices(),
            total_edges: g.num_edges(),
            ..Default::default()
        };
        let mut touched = std::collections::HashSet::new();
        for op in buf.pending() {
            match op {
                EdgeOp::AddEdge(u, v) => {
                    s.pending_add_edges += 1;
                    touched.insert(*u);
                    touched.insert(*v);
                }
                EdgeOp::RemoveEdge(u, v) => {
                    s.pending_remove_edges += 1;
                    touched.insert(*u);
                    touched.insert(*v);
                }
                EdgeOp::AddVertex(u) => {
                    s.pending_add_vertices += 1;
                    touched.insert(*u);
                }
                EdgeOp::RemoveVertex(u) => {
                    s.pending_remove_vertices += 1;
                    touched.insert(*u);
                }
            }
        }
        s.touched_vertices = touched.len();
        s
    }

    #[test]
    fn incremental_counters_match_rescan_under_interleaving() {
        use crate::util::rng::Xoshiro256pp;
        let (mut g, _) = DynamicGraph::from_edges(vec![(0, 1), (1, 2), (2, 0)]);
        let mut buf = UpdateBuffer::new();
        let mut rng = Xoshiro256pp::new(0xBEEF);
        for step in 0..400u32 {
            match rng.next_below(20) {
                0..=9 => {
                    let (u, v) = (rng.next_below(30), rng.next_below(30));
                    buf.register(if rng.next_below(4) == 0 {
                        EdgeOp::remove(u, v)
                    } else {
                        EdgeOp::add(u, v)
                    });
                }
                10..=13 => buf.register(EdgeOp::AddVertex(rng.next_below(40))),
                14..=15 => buf.register(EdgeOp::RemoveVertex(rng.next_below(40))),
                16..=17 => {
                    buf.apply(&mut g).unwrap();
                }
                _ => buf.clear(),
            }
            assert_eq!(buf.statistics(&g), rescan(&buf, &g), "step {step}");
        }
    }
}
