//! Synthetic update-stream generators — the paper's §7 future work:
//! “one variation could represent an edge stream corresponding to
//! power-law graph growth, another one could be generated through the
//! insights of the Erdős–Rényi model”, plus removal-mix and
//! sliding-window variants for the `e-` operation study.

use crate::graph::dynamic::DynamicGraph;
use crate::stream::event::EdgeOp;
use crate::util::rng::Xoshiro256pp;

/// Power-law growth stream: each event adds an edge from a (possibly
/// new) vertex to an endpoint chosen preferentially by degree —
/// Fortunato/Flammini/Menczer-style rank-driven growth against the
/// current graph state.
pub fn powerlaw_growth_stream(
    base: &DynamicGraph,
    len: usize,
    new_vertex_prob: f64,
    seed: u64,
) -> Vec<EdgeOp> {
    let mut rng = Xoshiro256pp::new(seed);
    // degree-biased endpoint pool from the base graph
    let mut pool: Vec<u64> = Vec::new();
    for (s, d) in base.edges() {
        pool.push(base.id(s));
        pool.push(base.id(d));
    }
    if pool.is_empty() {
        pool.push(0);
    }
    let mut next_id: u64 = base.ids().iter().copied().max().unwrap_or(0) + 1;
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        let dst = pool[rng.range(0, pool.len())];
        let src = if rng.chance(new_vertex_prob) {
            let id = next_id;
            next_id += 1;
            id
        } else {
            pool[rng.range(0, pool.len())]
        };
        if src == dst {
            continue;
        }
        out.push(EdgeOp::add(src, dst));
        pool.push(src);
        pool.push(dst);
    }
    out
}

/// Erdős–Rényi stream: uniform random pairs over a fixed id universe.
pub fn er_stream(universe: u64, len: usize, seed: u64) -> Vec<EdgeOp> {
    assert!(universe >= 2);
    let mut rng = Xoshiro256pp::new(seed);
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        let u = rng.next_below(universe);
        let v = rng.next_below(universe);
        if u != v {
            out.push(EdgeOp::add(u, v));
        }
    }
    out
}

/// Mixed stream: additions with probability `1 - remove_prob`, removals
/// of *previously added* edges otherwise (so removals are valid).
pub fn mixed_stream(
    base: &DynamicGraph,
    len: usize,
    remove_prob: f64,
    seed: u64,
) -> Vec<EdgeOp> {
    let mut rng = Xoshiro256pp::new(seed);
    let mut live: Vec<(u64, u64)> =
        base.edges().map(|(s, d)| (base.id(s), base.id(d))).collect();
    let universe = (base.num_vertices() as u64).max(2) * 2;
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        if !live.is_empty() && rng.chance(remove_prob) {
            let i = rng.range(0, live.len());
            let (u, v) = live.swap_remove(i);
            out.push(EdgeOp::remove(u, v));
        } else {
            let u = rng.next_below(universe);
            let v = rng.next_below(universe);
            if u != v {
                out.push(EdgeOp::add(u, v));
                live.push((u, v));
            }
        }
    }
    out
}

/// Sliding-window stream over an edge list: every addition beyond the
/// window also emits the removal of the edge leaving the window — models
/// “only the last W edges matter” workloads (monitoring, fraud).
pub fn sliding_window_stream(edges: &[(u64, u64)], window: usize) -> Vec<EdgeOp> {
    let mut out = Vec::with_capacity(edges.len() * 2);
    for (i, &(u, v)) in edges.iter().enumerate() {
        out.push(EdgeOp::add(u, v));
        if i >= window {
            let (ou, ov) = edges[i - window];
            out.push(EdgeOp::remove(ou, ov));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    fn base() -> DynamicGraph {
        DynamicGraph::from_edges(generate::barabasi_albert(200, 3, 0.5, 1)).0
    }

    #[test]
    fn powerlaw_stream_prefers_hubs() {
        let g = base();
        let ops = powerlaw_growth_stream(&g, 2000, 0.3, 7);
        assert_eq!(ops.len(), 2000);
        // count destination frequency: hubs of the base should dominate
        let mut counts: std::collections::HashMap<u64, usize> = Default::default();
        for op in &ops {
            if let EdgeOp::AddEdge(_, d) = op {
                *counts.entry(*d).or_default() += 1;
            }
        }
        let max = counts.values().max().copied().unwrap_or(0);
        let mean = 2000.0 / counts.len() as f64;
        assert!(max as f64 > 5.0 * mean, "max {max} vs mean {mean}");
    }

    #[test]
    fn powerlaw_stream_creates_new_vertices() {
        let g = base();
        let ops = powerlaw_growth_stream(&g, 500, 0.5, 3);
        let base_max = g.ids().iter().copied().max().unwrap();
        let new = ops
            .iter()
            .filter(|op| matches!(op, EdgeOp::AddEdge(s, _) if *s > base_max))
            .count();
        assert!(new > 100, "expected many new-vertex arrivals, got {new}");
    }

    #[test]
    fn er_stream_is_uniformish() {
        let ops = er_stream(100, 5000, 11);
        let mut counts = vec![0usize; 100];
        for op in &ops {
            if let EdgeOp::AddEdge(s, _) = op {
                counts[*s as usize] += 1;
            }
        }
        let max = *counts.iter().max().unwrap() as f64;
        let mean = 5000.0 / 100.0;
        assert!(max < 3.0 * mean, "uniform stream should have no hubs");
    }

    #[test]
    fn mixed_stream_removals_are_valid_replay() {
        let g = base();
        let ops = mixed_stream(&g, 1000, 0.3, 5);
        let removes = ops.iter().filter(|o| matches!(o, EdgeOp::RemoveEdge(..))).count();
        assert!(removes > 100, "expected a healthy removal mix, got {removes}");
        // replay against a copy: every removal must hit an existing edge
        let mut replay = g.clone();
        let mut failed = 0;
        for op in ops {
            match op {
                EdgeOp::AddEdge(u, v) => {
                    let _ = replay.add_edge(u, v); // duplicates allowed to fail
                }
                EdgeOp::RemoveEdge(u, v) => {
                    if replay.remove_edge(u, v).is_err() {
                        failed += 1;
                    }
                }
                _ => {}
            }
        }
        // duplicates in the add-universe can invalidate a later removal of
        // the same pair; tolerate a tiny fraction
        assert!(failed < 20, "too many invalid removals: {failed}");
    }

    #[test]
    fn sliding_window_keeps_at_most_window_edges() {
        let edges: Vec<(u64, u64)> = (0..50).map(|i| (i, i + 100)).collect();
        let ops = sliding_window_stream(&edges, 10);
        let mut g = DynamicGraph::new();
        for op in ops {
            match op {
                EdgeOp::AddEdge(u, v) => g.add_edge(u, v).unwrap(),
                EdgeOp::RemoveEdge(u, v) => g.remove_edge(u, v).unwrap(),
                _ => {}
            }
        }
        assert_eq!(g.num_edges(), 10);
        assert!(g.has_edge(49, 149));
        assert!(!g.has_edge(0, 100));
    }

    #[test]
    fn streams_are_deterministic() {
        let g = base();
        let a = powerlaw_growth_stream(&g, 100, 0.3, 9);
        assert_eq!(a, powerlaw_growth_stream(&g, 100, 0.3, 9));
        assert_eq!(er_stream(50, 100, 9), er_stream(50, 100, 9));
        assert_eq!(mixed_stream(&g, 100, 0.2, 9), mixed_stream(&g, 100, 0.2, 9));
    }
}
