//! Update-stream substrate: event model, pending-update buffer with
//! statistics, stream construction per the paper's evaluation protocol,
//! and a bounded ingestion queue with load-shedding policies.

pub mod backpressure;
pub mod buffer;
pub mod event;
pub mod source;
pub mod synthetic;
pub mod trace;
pub mod window;
