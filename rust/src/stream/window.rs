//! Sliding-window edge retention: expiry as generated `RemoveEdge` ops.
//!
//! A windowed streaming graph keeps only the edges admitted in the last
//! `W` time units. Rather than teaching the graph (or the coalescer, or
//! the recompute planes) about time, the window is a *stage in front of
//! the write pipeline*: it watches admitted ops, remembers when each
//! edge will fall out of the window, and on every tick emits ordinary
//! [`EdgeOp::RemoveEdge`] ops for the expired ones. Those flow through
//! the existing `UpdateBuffer` coalescer like any client write — so
//! expiry is batched, replay-exact, and staleness-accounted for free,
//! and the rest of the system stays timestamp-free.
//!
//! Time is a caller-supplied logical clock in nanoseconds (the server
//! passes wall time since its epoch; tests pass small integers), which
//! keeps the semantics deterministic and property-testable.
//!
//! Re-adds and explicit removes interact through a per-edge
//! `(count, stamp)` state: each admit increments `count` and enqueues an
//! expiry entry stamped with the current `stamp`; an explicit client
//! `RemoveEdge` (or `RemoveVertex` touching the edge) bumps `stamp` and
//! zeroes `count`, instantly orphaning every queued entry for that edge
//! so a stale expiry can never remove a re-added edge. Generations come
//! from one monotone counter, so a recycled map slot can never collide
//! with an old entry's stamp. An expiry fires an actual `RemoveEdge`
//! only on the `count` 1 → 0 transition — the edge's *last* unexpired
//! admit leaving the window.

use std::collections::{HashMap, VecDeque};

use crate::graph::VertexId;
use crate::stream::event::EdgeOp;

/// One queued expiry: the admit that created it falls out of the window
/// at `deadline`.
struct Entry {
    deadline: u64,
    src: VertexId,
    dst: VertexId,
    stamp: u64,
}

/// Live admit-state for one edge.
struct EdgeState {
    /// Unexpired admits since the last explicit remove.
    count: u64,
    /// Stamp queued entries must match to still be live.
    stamp: u64,
}

/// The window stage. Not thread-safe by design — it lives on the engine
/// worker thread, in front of the ingest path.
pub struct SlidingWindow {
    window_nanos: u64,
    /// Expiry queue, in admit order (deadlines are monotone because the
    /// caller's clock is).
    entries: VecDeque<Entry>,
    live: HashMap<(VertexId, VertexId), EdgeState>,
    next_stamp: u64,
}

impl SlidingWindow {
    /// A window retaining edges for `window_nanos` logical nanoseconds.
    pub fn new(window_nanos: u64) -> SlidingWindow {
        assert!(window_nanos > 0, "a zero-width window would expire every edge instantly");
        SlidingWindow {
            window_nanos,
            entries: VecDeque::new(),
            live: HashMap::new(),
            next_stamp: 0,
        }
    }

    /// The configured width.
    pub fn window_nanos(&self) -> u64 {
        self.window_nanos
    }

    /// Observe one client op at logical time `now` (called *before* the
    /// op is handed to the engine). Expiry-generated removes must NOT be
    /// admitted back — they already settled their own bookkeeping.
    pub fn admit(&mut self, op: &EdgeOp, now: u64) {
        match *op {
            EdgeOp::AddEdge(src, dst) => {
                let next_stamp = &mut self.next_stamp;
                let st = self.live.entry((src, dst)).or_insert_with(|| {
                    let stamp = *next_stamp;
                    *next_stamp += 1;
                    EdgeState { count: 0, stamp }
                });
                st.count += 1;
                self.entries.push_back(Entry {
                    deadline: now.saturating_add(self.window_nanos),
                    src,
                    dst,
                    stamp: st.stamp,
                });
            }
            EdgeOp::RemoveEdge(src, dst) => {
                if let Some(st) = self.live.get_mut(&(src, dst)) {
                    st.count = 0;
                    st.stamp = self.next_stamp;
                    self.next_stamp += 1;
                }
            }
            EdgeOp::RemoveVertex(id) => {
                // The graph drops every incident edge; orphan their
                // queued expiries the same way an explicit remove would.
                for (&(src, dst), st) in self.live.iter_mut() {
                    if src == id || dst == id {
                        st.count = 0;
                        st.stamp = self.next_stamp;
                        self.next_stamp += 1;
                    }
                }
            }
            EdgeOp::AddVertex(_) => {}
        }
    }

    /// Pop every admit whose deadline has passed and return the
    /// `RemoveEdge` ops for edges whose last unexpired admit just left
    /// the window. Feed these to the ingest path as a batch.
    pub fn expire_due(&mut self, now: u64) -> Vec<EdgeOp> {
        let mut out = Vec::new();
        loop {
            match self.entries.front() {
                Some(e) if e.deadline <= now => {}
                _ => break,
            }
            let e = self.entries.pop_front().unwrap();
            let key = (e.src, e.dst);
            if let Some(st) = self.live.get_mut(&key) {
                if st.stamp == e.stamp {
                    // Matching queued entries never outnumber `count`.
                    st.count -= 1;
                    if st.count == 0 {
                        self.live.remove(&key);
                        out.push(EdgeOp::remove(e.src, e.dst));
                    }
                } else if st.count == 0 {
                    // Orphaned by an explicit remove and never re-added:
                    // reclaim the slot.
                    self.live.remove(&key);
                }
            }
        }
        out
    }

    /// When the earliest queued admit expires, if any — what a ticker
    /// needs to pace itself.
    pub fn next_deadline(&self) -> Option<u64> {
        self.entries.front().map(|e| e.deadline)
    }

    /// Queued expiry entries (one per unexpired admit).
    pub fn tracked(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is in the window.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Capture the admission state for a checkpoint, relative to the
    /// caller's clock: queued deadlines are stored as *remaining*
    /// nanoseconds so a restore under a fresh epoch (the recovered
    /// server's clock restarts at zero) preserves each admit's
    /// remaining lifetime rather than expiring everything instantly.
    pub fn export_state(&self, now: u64) -> WindowState {
        let mut live: Vec<(VertexId, VertexId, u64, u64)> = self
            .live
            .iter()
            .map(|(&(src, dst), st)| (src, dst, st.count, st.stamp))
            .collect();
        live.sort_unstable(); // deterministic bytes for identical state
        WindowState {
            window_nanos: self.window_nanos,
            next_stamp: self.next_stamp,
            live,
            entries: self
                .entries
                .iter()
                .map(|e| (e.deadline.saturating_sub(now), e.src, e.dst, e.stamp))
                .collect(),
        }
    }

    /// Rebuild a window from checkpointed state under a new clock whose
    /// current reading is `now`. Queue order (and thus deadline
    /// monotonicity) is preserved because remaining times were captured
    /// in queue order from a monotone clock.
    pub fn restore(state: &WindowState, now: u64) -> SlidingWindow {
        let mut w = SlidingWindow::new(state.window_nanos.max(1));
        w.next_stamp = state.next_stamp;
        for &(src, dst, count, stamp) in &state.live {
            w.live.insert((src, dst), EdgeState { count, stamp });
        }
        for &(remaining, src, dst, stamp) in &state.entries {
            w.entries.push_back(Entry {
                deadline: now.saturating_add(remaining),
                src,
                dst,
                stamp,
            });
        }
        w
    }
}

/// Checkpointable snapshot of a [`SlidingWindow`]'s admission state.
/// Deadlines are relative (remaining nanoseconds at capture time); see
/// [`SlidingWindow::export_state`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowState {
    /// Configured width.
    pub window_nanos: u64,
    /// Monotone generation counter.
    pub next_stamp: u64,
    /// `(src, dst, count, stamp)` per live edge, sorted by key.
    pub live: Vec<(VertexId, VertexId, u64, u64)>,
    /// `(remaining_nanos, src, dst, stamp)` per queued admit, in queue
    /// order.
    pub entries: Vec<(u64, VertexId, VertexId, u64)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edges_expire_after_the_window() {
        let mut w = SlidingWindow::new(10);
        w.admit(&EdgeOp::add(1, 2), 0);
        w.admit(&EdgeOp::add(3, 4), 5);
        assert!(w.expire_due(9).is_empty());
        assert_eq!(w.expire_due(10), vec![EdgeOp::remove(1, 2)]);
        assert_eq!(w.expire_due(15), vec![EdgeOp::remove(3, 4)]);
        assert!(w.is_empty());
    }

    #[test]
    fn readd_refreshes_the_deadline() {
        let mut w = SlidingWindow::new(10);
        w.admit(&EdgeOp::add(1, 2), 0);
        w.admit(&EdgeOp::add(1, 2), 8);
        // First admit expires but the edge is still within the window of
        // the second: no remove yet.
        assert!(w.expire_due(10).is_empty());
        assert_eq!(w.expire_due(18), vec![EdgeOp::remove(1, 2)]);
    }

    #[test]
    fn explicit_remove_orphans_queued_expiries() {
        let mut w = SlidingWindow::new(10);
        w.admit(&EdgeOp::add(1, 2), 0);
        w.admit(&EdgeOp::remove(1, 2), 3);
        // Re-added after the remove: the orphaned entry from t=0 must
        // not expire the new incarnation at t=10.
        w.admit(&EdgeOp::add(1, 2), 5);
        assert!(w.expire_due(10).is_empty());
        assert_eq!(w.expire_due(15), vec![EdgeOp::remove(1, 2)]);
        assert!(w.is_empty());
    }

    #[test]
    fn remove_vertex_orphans_incident_edges() {
        let mut w = SlidingWindow::new(10);
        w.admit(&EdgeOp::add(1, 2), 0);
        w.admit(&EdgeOp::add(3, 1), 0);
        w.admit(&EdgeOp::add(4, 5), 0);
        w.admit(&EdgeOp::RemoveVertex(1), 2);
        // Only the untouched edge still expires.
        assert_eq!(w.expire_due(10), vec![EdgeOp::remove(4, 5)]);
    }

    #[test]
    fn export_restore_preserves_remaining_lifetimes_under_a_new_epoch() {
        let mut w = SlidingWindow::new(10);
        w.admit(&EdgeOp::add(1, 2), 0);
        w.admit(&EdgeOp::add(3, 4), 6);
        w.admit(&EdgeOp::remove(3, 4), 7); // orphaned entry rides along
        w.admit(&EdgeOp::add(3, 4), 8);
        // Capture at t=9: (1,2) has 1ns left, (3,4) re-add has 9ns left.
        let state = w.export_state(9);
        // Restore under a clock that reads 100.
        let mut r = SlidingWindow::restore(&state, 100);
        assert_eq!(r.tracked(), w.tracked());
        assert!(r.expire_due(100).is_empty());
        assert_eq!(r.expire_due(101), vec![EdgeOp::remove(1, 2)]);
        assert!(r.expire_due(108).is_empty(), "orphaned entry must not fire");
        assert_eq!(r.expire_due(109), vec![EdgeOp::remove(3, 4)]);
        assert!(r.is_empty());
        // The original window behaves identically on its own clock.
        assert_eq!(w.expire_due(10), vec![EdgeOp::remove(1, 2)]);
        assert_eq!(w.expire_due(18), vec![EdgeOp::remove(3, 4)]);
    }

    #[test]
    fn export_is_deterministic_for_identical_state() {
        let build = || {
            let mut w = SlidingWindow::new(5);
            for i in 0..8u64 {
                w.admit(&EdgeOp::add(i % 3, i % 5 + 10), i);
            }
            w
        };
        assert_eq!(build().export_state(8), build().export_state(8));
    }

    #[test]
    fn reclaimed_slots_do_not_resurrect_old_generations() {
        let mut w = SlidingWindow::new(10);
        w.admit(&EdgeOp::add(1, 2), 0);
        w.admit(&EdgeOp::add(1, 2), 1);
        w.admit(&EdgeOp::remove(1, 2), 2);
        // First orphaned entry reclaims the slot at t=10…
        assert!(w.expire_due(10).is_empty());
        // …and a fresh add gets a fresh generation the second orphaned
        // entry (t=1 admit, due t=11) cannot match.
        w.admit(&EdgeOp::add(1, 2), 10);
        assert!(w.expire_due(11).is_empty());
        assert_eq!(w.expire_due(20), vec![EdgeOp::remove(1, 2)]);
    }
}
