//! Timestamped operation traces: record a production update/query stream
//! and replay it later — the data-pipeline companion to checkpointing
//! (record once, replay against any parameter combination, compare).
//!
//! Text format, one event per line (git-diffable, `#` comments):
//! ```text
//! <t_micros> a <src> <dst>     edge addition
//! <t_micros> r <src> <dst>     edge removal
//! <t_micros> va <id>           vertex addition
//! <t_micros> vr <id>           vertex removal
//! <t_micros> q                 query
//! ```
//! Replay can be as-fast-as-possible (the experiment harness mode) or
//! rate-faithful via [`TraceEvent::delay_from`].

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::stream::event::{EdgeOp, UpdateEvent};

/// One timestamped trace event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Microseconds since trace start.
    pub t_micros: u64,
    /// The event payload.
    pub event: UpdateEventKind,
}

/// Payload without the Stop sentinel (a trace ends at EOF).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UpdateEventKind {
    Op(EdgeOp),
    Query,
}

impl TraceEvent {
    /// Wall-clock delay between a previous event and this one.
    pub fn delay_from(&self, prev: &TraceEvent) -> std::time::Duration {
        std::time::Duration::from_micros(self.t_micros.saturating_sub(prev.t_micros))
    }

    /// Convert to the engine's event type.
    pub fn to_update_event(&self) -> UpdateEvent {
        match self.event {
            UpdateEventKind::Op(op) => UpdateEvent::Op(op),
            UpdateEventKind::Query => UpdateEvent::Query,
        }
    }
}

/// Serialize a trace.
pub fn write_trace<W: Write>(w: W, events: &[TraceEvent]) -> Result<()> {
    let mut w = BufWriter::new(w);
    writeln!(w, "# veilgraph trace v1")?;
    for e in events {
        match e.event {
            UpdateEventKind::Op(EdgeOp::AddEdge(s, d)) => writeln!(w, "{} a {s} {d}", e.t_micros)?,
            UpdateEventKind::Op(EdgeOp::RemoveEdge(s, d)) => {
                writeln!(w, "{} r {s} {d}", e.t_micros)?
            }
            UpdateEventKind::Op(EdgeOp::AddVertex(v)) => writeln!(w, "{} va {v}", e.t_micros)?,
            UpdateEventKind::Op(EdgeOp::RemoveVertex(v)) => writeln!(w, "{} vr {v}", e.t_micros)?,
            UpdateEventKind::Query => writeln!(w, "{} q", e.t_micros)?,
        }
    }
    w.flush()?;
    Ok(())
}

/// Parse a trace; validates monotone timestamps.
pub fn read_trace<R: std::io::Read>(r: R) -> Result<Vec<TraceEvent>> {
    let mut out = Vec::new();
    let mut last_t = 0u64;
    for (lineno, line) in BufReader::new(r).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut parts = t.split_whitespace();
        let err = |msg: &str| Error::Parse(format!("trace line {}: {msg}", lineno + 1));
        let t_micros: u64 = parts
            .next()
            .ok_or_else(|| err("missing timestamp"))?
            .parse()
            .map_err(|_| err("bad timestamp"))?;
        if t_micros < last_t {
            return Err(err("timestamps must be monotone"));
        }
        last_t = t_micros;
        let kind = parts.next().ok_or_else(|| err("missing op"))?;
        let mut num = |p: &mut std::str::SplitWhitespace<'_>| -> Result<u64> {
            p.next().ok_or_else(|| err("missing id"))?.parse().map_err(|_| err("bad id"))
        };
        let event = match kind {
            "a" => UpdateEventKind::Op(EdgeOp::AddEdge(num(&mut parts)?, num(&mut parts)?)),
            "r" => UpdateEventKind::Op(EdgeOp::RemoveEdge(num(&mut parts)?, num(&mut parts)?)),
            "va" => UpdateEventKind::Op(EdgeOp::AddVertex(num(&mut parts)?)),
            "vr" => UpdateEventKind::Op(EdgeOp::RemoveVertex(num(&mut parts)?)),
            "q" => UpdateEventKind::Query,
            other => return Err(err(&format!("unknown op {other:?}"))),
        };
        out.push(TraceEvent { t_micros, event });
    }
    Ok(out)
}

/// Save a trace to a file.
pub fn save_trace(path: impl AsRef<Path>, events: &[TraceEvent]) -> Result<()> {
    write_trace(std::fs::File::create(path)?, events)
}

/// Load a trace from a file.
pub fn load_trace(path: impl AsRef<Path>) -> Result<Vec<TraceEvent>> {
    read_trace(std::fs::File::open(path)?)
}

/// A recorder that stamps events with elapsed wall time as they arrive.
pub struct TraceRecorder {
    started: std::time::Instant,
    events: Vec<TraceEvent>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceRecorder {
    /// Start recording now.
    pub fn new() -> Self {
        Self { started: std::time::Instant::now(), events: Vec::new() }
    }

    /// Record a graph operation.
    pub fn op(&mut self, op: EdgeOp) {
        let t_micros = self.started.elapsed().as_micros() as u64;
        self.events.push(TraceEvent { t_micros, event: UpdateEventKind::Op(op) });
    }

    /// Record a query.
    pub fn query(&mut self) {
        let t_micros = self.started.elapsed().as_micros() as u64;
        self.events.push(TraceEvent { t_micros, event: UpdateEventKind::Query });
    }

    /// Finish and return the trace.
    pub fn finish(self) -> Vec<TraceEvent> {
        self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<TraceEvent> {
        vec![
            TraceEvent { t_micros: 0, event: UpdateEventKind::Op(EdgeOp::add(1, 2)) },
            TraceEvent { t_micros: 120, event: UpdateEventKind::Op(EdgeOp::AddVertex(9)) },
            TraceEvent { t_micros: 150, event: UpdateEventKind::Query },
            TraceEvent { t_micros: 400, event: UpdateEventKind::Op(EdgeOp::remove(1, 2)) },
            TraceEvent { t_micros: 500, event: UpdateEventKind::Op(EdgeOp::RemoveVertex(9)) },
            TraceEvent { t_micros: 501, event: UpdateEventKind::Query },
        ]
    }

    #[test]
    fn roundtrip_preserves_events() {
        let mut buf = Vec::new();
        write_trace(&mut buf, &sample()).unwrap();
        let back = read_trace(&buf[..]).unwrap();
        assert_eq!(back, sample());
    }

    #[test]
    fn rejects_non_monotone_timestamps() {
        let text = "100 q\n50 q\n";
        let e = read_trace(text.as_bytes()).unwrap_err();
        assert!(e.to_string().contains("monotone"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(read_trace("abc q\n".as_bytes()).is_err());
        assert!(read_trace("5 a 1\n".as_bytes()).is_err());
        assert!(read_trace("5 zz 1 2\n".as_bytes()).is_err());
    }

    #[test]
    fn delays_and_conversion() {
        let ev = sample();
        assert_eq!(ev[1].delay_from(&ev[0]).as_micros(), 120);
        assert_eq!(ev[2].to_update_event(), UpdateEvent::Query);
        assert_eq!(ev[0].to_update_event(), UpdateEvent::Op(EdgeOp::add(1, 2)));
    }

    #[test]
    fn recorder_stamps_monotone() {
        let mut rec = TraceRecorder::new();
        rec.op(EdgeOp::add(1, 2));
        std::thread::sleep(std::time::Duration::from_millis(2));
        rec.query();
        let tr = rec.finish();
        assert_eq!(tr.len(), 2);
        assert!(tr[1].t_micros >= tr[0].t_micros);
    }

    #[test]
    fn trace_replays_through_engine() {
        use crate::coordinator::engine::EngineBuilder;
        let mut rec = TraceRecorder::new();
        for i in 0..10u64 {
            rec.op(EdgeOp::add(100 + i, i % 5));
        }
        rec.query();
        let trace = rec.finish();
        let mut buf = Vec::new();
        write_trace(&mut buf, &trace).unwrap();
        let loaded = read_trace(&buf[..]).unwrap();
        let mut engine = EngineBuilder::new()
            .build_from_edges((0..5u64).map(|i| (i, (i + 1) % 5)))
            .unwrap();
        let results = engine
            .run_stream(loaded.iter().map(|e| e.to_update_event()))
            .unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(engine.graph().num_vertices(), 15);
    }
}
