//! The paper's evaluation (§5) as a regenerable experiment suite:
//! dataset stand-ins (Table 1), the replay harness (Q = 50 queries × 18
//! parameter combinations × ground truth), the figure registry
//! (Figs. 3–30) and result persistence.

pub mod datasets;
pub mod figures;
pub mod harness;
pub mod report;
