//! The figure-regeneration harness: the paper's evaluation protocol (§5).
//!
//! For one dataset: split off a stream of |S| edges, chunk into Q = 50
//! queries, run the exact ground truth replay, then replay the *same*
//! stream under every (r, n, Δ) combination, recording per query:
//!
//! * summary vertex ratio  |V(G)| / |V|       (Figs. 3, 7, 11, 15, 19, 23, 27)
//! * summary edge ratio    |E(G)| / |E|       (Figs. 4, 8, 12, 16, 20, 24, 28)
//! * RBO vs. ground truth (top-1000/4000)     (Figs. 5, 9, 13, 17, 21, 25, 29)
//! * speedup = exact time / approx time       (Figs. 6, 10, 14, 18, 22, 26, 30)

use std::sync::Arc;

use crate::coordinator::engine::EngineBuilder;
use crate::coordinator::policies::{AlwaysApproximate, AlwaysExact};
use crate::error::Result;
use crate::metrics::ranking::rbo_depth_for_density;
use crate::metrics::rbo::rbo_ext;
use crate::pagerank::power::PageRankConfig;
use crate::stream::event::UpdateEvent;
use crate::stream::source::{chunked_events, split_stream, update_density};
use crate::summary::params::SummaryParams;
use crate::util::threadpool::{available_parallelism, ThreadPool};

/// Number of queries per experiment (paper: Q = 50).
pub const Q: usize = 50;

/// RBO persistence parameter (not stated in the paper; DESIGN.md §8).
pub const RBO_P: f64 = 0.99;

/// Per-query measurements for one parameter combination.
#[derive(Clone, Debug, Default)]
pub struct SeriesRow {
    pub query: usize,
    pub summary_vertices: usize,
    pub summary_edges: usize,
    pub full_vertices: usize,
    pub full_edges: usize,
    pub rbo: f64,
    pub approx_secs: f64,
    pub exact_secs: f64,
}

impl SeriesRow {
    /// |V(G)|/|V|.
    pub fn vertex_ratio(&self) -> f64 {
        self.summary_vertices as f64 / self.full_vertices.max(1) as f64
    }

    /// |E(G)|/|E|.
    pub fn edge_ratio(&self) -> f64 {
        self.summary_edges as f64 / self.full_edges.max(1) as f64
    }

    /// exact / approx wall time.
    pub fn speedup(&self) -> f64 {
        if self.approx_secs > 0.0 {
            self.exact_secs / self.approx_secs
        } else {
            f64::INFINITY
        }
    }
}

/// One parameter combination's full replay.
#[derive(Clone, Debug)]
pub struct CombinationResult {
    pub params: SummaryParams,
    pub rows: Vec<SeriesRow>,
}

impl CombinationResult {
    /// Average of a metric over the stream (the paper ranks combinations
    /// by these averages to pick best-3/worst-3 per figure).
    pub fn avg(&self, metric: Metric) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().map(|r| metric.value(r)).sum::<f64>() / self.rows.len() as f64
    }

    /// Metric series over queries.
    pub fn series(&self, metric: Metric) -> Vec<f64> {
        self.rows.iter().map(|r| metric.value(r)).collect()
    }
}

/// The four per-figure metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    VertexRatio,
    EdgeRatio,
    Rbo,
    Speedup,
}

impl Metric {
    /// Extract the metric from a row.
    pub fn value(&self, r: &SeriesRow) -> f64 {
        match self {
            Metric::VertexRatio => r.vertex_ratio(),
            Metric::EdgeRatio => r.edge_ratio(),
            Metric::Rbo => r.rbo,
            Metric::Speedup => r.speedup(),
        }
    }

    /// Short name used in CSV headers / figure titles.
    pub fn name(&self) -> &'static str {
        match self {
            Metric::VertexRatio => "vertex_ratio",
            Metric::EdgeRatio => "edge_ratio",
            Metric::Rbo => "rbo",
            Metric::Speedup => "speedup",
        }
    }

    /// Whether larger is better (for best/worst ordering).
    pub fn higher_is_better(&self) -> bool {
        match self {
            // smaller summaries are the goal for ratios
            Metric::VertexRatio | Metric::EdgeRatio => false,
            Metric::Rbo | Metric::Speedup => true,
        }
    }
}

/// A full experiment: ground truth + all combinations over one stream.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub dataset: String,
    pub stream_len: usize,
    pub q: usize,
    pub rbo_depth: usize,
    pub combos: Vec<CombinationResult>,
}

impl ExperimentResult {
    /// Combinations ordered best-first for `metric`.
    pub fn ranked(&self, metric: Metric) -> Vec<&CombinationResult> {
        let mut v: Vec<&CombinationResult> = self.combos.iter().collect();
        v.sort_by(|a, b| {
            let (x, y) = (a.avg(metric), b.avg(metric));
            if metric.higher_is_better() {
                y.partial_cmp(&x).unwrap()
            } else {
                x.partial_cmp(&y).unwrap()
            }
        });
        v
    }

    /// The paper's plots: best 3 and worst 3 combinations by average.
    pub fn best_worst(&self, metric: Metric, each: usize) -> Vec<&CombinationResult> {
        let ranked = self.ranked(metric);
        let n = ranked.len();
        if n <= 2 * each {
            return ranked;
        }
        let mut out: Vec<&CombinationResult> = ranked[..each].to_vec();
        out.extend_from_slice(&ranked[n - each..]);
        out
    }
}

/// Experiment configuration.
#[derive(Clone, Debug)]
pub struct HarnessConfig {
    /// Queries per stream (paper: 50).
    pub q: usize,
    /// PageRank configuration shared by exact and summarized runs.
    pub pagerank: PageRankConfig,
    /// Parameter grid (paper: the 18 combinations).
    pub grid: Vec<SummaryParams>,
    /// Stream sampling/shuffle seed.
    pub seed: u64,
    /// Workers for the combination grid (each replay is independent).
    /// `run_experiment` clamps `workers × pagerank.parallelism` to the
    /// machine's available parallelism (logging the clamp) and shares a
    /// single shard pool across all replays.
    pub workers: usize,
}

impl Default for HarnessConfig {
    fn default() -> Self {
        Self {
            q: Q,
            pagerank: PageRankConfig { epsilon: 1e-8, max_iters: 100, ..Default::default() },
            grid: SummaryParams::paper_grid(),
            seed: 7,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }
}

/// Ground-truth replay: per-query exact wall time, top-k ids, |V|, |E|.
struct GroundTruth {
    exact_secs: Vec<f64>,
    top_ids: Vec<Vec<u64>>,
    full_vertices: Vec<usize>,
    full_edges: Vec<usize>,
}

fn run_ground_truth(
    initial: &[(u64, u64)],
    events: &[UpdateEvent],
    cfg: &HarnessConfig,
    rbo_depth: usize,
    pool: Option<Arc<ThreadPool>>,
) -> Result<GroundTruth> {
    // Paper baseline: a *complete* (cold) PageRank execution per query.
    let gt_cfg = PageRankConfig { warm_start_exact: false, ..cfg.pagerank };
    let mut builder = EngineBuilder::new().udf(Box::new(AlwaysExact)).pagerank(gt_cfg);
    if let Some(pool) = pool {
        builder = builder.shared_pool(pool);
    }
    let mut engine = builder.build_from_edges(initial.iter().copied())?;
    let mut gt = GroundTruth {
        exact_secs: Vec::new(),
        top_ids: Vec::new(),
        full_vertices: Vec::new(),
        full_edges: Vec::new(),
    };
    // Batch path: one `ingest_batch` per op run (the wire shape clients
    // use), coalesced at the apply step before each query.
    engine.run_stream_with(events.iter().cloned(), |eng, r| {
        gt.exact_secs.push(r.exec.elapsed_secs);
        gt.top_ids.push(r.top_ids(rbo_depth));
        gt.full_vertices.push(eng.graph().num_vertices());
        gt.full_edges.push(eng.graph().num_edges());
        Ok(())
    })?;
    Ok(gt)
}

fn run_combination(
    initial: &[(u64, u64)],
    events: &[UpdateEvent],
    cfg: &HarnessConfig,
    params: SummaryParams,
    gt: &GroundTruth,
    rbo_depth: usize,
    pool: Option<Arc<ThreadPool>>,
) -> Result<CombinationResult> {
    let mut builder = EngineBuilder::new()
        .params(params)
        .udf(Box::new(AlwaysApproximate))
        .pagerank(cfg.pagerank);
    if let Some(pool) = pool {
        builder = builder.shared_pool(pool);
    }
    let mut engine = builder.build_from_edges(initial.iter().copied())?;
    let mut rows = Vec::new();
    let mut q = 0usize;
    engine.run_stream_with(events.iter().cloned(), |_, r| {
        let approx_top = r.top_ids(rbo_depth);
        rows.push(SeriesRow {
            query: q + 1,
            summary_vertices: r.exec.summary_vertices,
            summary_edges: r.exec.summary_edges,
            full_vertices: gt.full_vertices[q],
            full_edges: gt.full_edges[q],
            rbo: rbo_ext(&approx_top, &gt.top_ids[q], RBO_P),
            approx_secs: r.exec.elapsed_secs,
            exact_secs: gt.exact_secs[q],
        });
        q += 1;
        Ok(())
    })?;
    Ok(CombinationResult { params, rows })
}

/// Run the full experiment for one dataset edge list.
///
/// `stream_len` edges are held out per the paper's protocol; `shuffled`
/// selects the incidence-order vs shuffled stream scenario.
pub fn run_experiment(
    dataset_name: &str,
    edges: &[(u64, u64)],
    stream_len: usize,
    shuffled: bool,
    cfg: &HarnessConfig,
) -> Result<ExperimentResult> {
    let (initial, stream) = split_stream(&edges.to_vec(), stream_len, shuffled, cfg.seed);
    let events = chunked_events(&stream, cfg.q);
    let density = update_density(stream.len(), cfg.q);
    let rbo_depth = rbo_depth_for_density(density);

    crate::log_info!(
        "experiment {dataset_name}: |V0 edges|={}, |S|={}, Q={}, density={density:.0}, \
         rbo_depth={rbo_depth}",
        initial.len(),
        stream.len(),
        cfg.q
    );

    // Resolve the thread budget. Outer replay workers × inner PageRank
    // shards must not exceed the machine, and engines no longer spawn one
    // pool each: ONE shared inner pool serves the ground truth and every
    // combination replay, so total threads are workers + shards (not
    // their product). Outer workers block while their engine's shards
    // run, and inner workers never re-enter a pool, so the two-pool
    // split cannot deadlock.
    let avail = available_parallelism();
    let req_workers = cfg.workers.max(1);
    let workers = req_workers.min(avail).min(cfg.grid.len().max(1));
    let req_shards = if cfg.pagerank.parallelism == 0 {
        avail
    } else {
        cfg.pagerank.parallelism
    };
    let shards = if workers.saturating_mul(req_shards) > avail {
        (avail / workers).max(1)
    } else {
        req_shards
    };
    if workers != req_workers || shards != req_shards {
        crate::log_info!(
            "harness clamp: workers {req_workers}->{workers}, parallelism \
             {req_shards}->{shards} (available_parallelism={avail})"
        );
    }
    let mut cfg = cfg.clone();
    cfg.workers = workers;
    cfg.pagerank.parallelism = shards;
    let inner: Option<Arc<ThreadPool>> = if shards != 1 {
        Some(Arc::new(ThreadPool::new(shards)))
    } else {
        None
    };

    let gt = run_ground_truth(&initial, &events, &cfg, rbo_depth, inner.clone())?;

    // Each combination's replay is independent — fan out over the outer
    // pool while all engines share the inner one.
    let pool = ThreadPool::new(cfg.workers);
    let shared = Arc::new((initial, events, cfg.clone(), gt));
    let combos: Vec<Result<CombinationResult>> = pool.scope_map(cfg.grid.clone(), {
        let shared = Arc::clone(&shared);
        move |params| {
            let (initial, events, cfg, gt) = &*shared;
            run_combination(initial, events, cfg, params, gt, rbo_depth, inner.clone())
        }
    });
    let mut out = Vec::with_capacity(combos.len());
    for c in combos {
        out.push(c?);
    }
    Ok(ExperimentResult {
        dataset: dataset_name.to_string(),
        stream_len,
        q: cfg.q,
        rbo_depth,
        combos: out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::barabasi_albert;

    fn quick_cfg() -> HarnessConfig {
        HarnessConfig {
            q: 5,
            grid: vec![
                SummaryParams::new(0.1, 1, 0.1),
                SummaryParams::new(0.3, 0, 0.9),
            ],
            seed: 3,
            workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn experiment_produces_full_series() {
        let edges = barabasi_albert(400, 3, 0.5, 21);
        let res = run_experiment("test", &edges, 100, true, &quick_cfg()).unwrap();
        assert_eq!(res.combos.len(), 2);
        for c in &res.combos {
            assert_eq!(c.rows.len(), 5);
            for (i, row) in c.rows.iter().enumerate() {
                assert_eq!(row.query, i + 1);
                assert!(row.vertex_ratio() <= 1.0);
                assert!(row.edge_ratio() <= 1.5, "ratios stay plausible");
                assert!((0.0..=1.0).contains(&row.rbo));
                assert!(row.exact_secs > 0.0 && row.approx_secs > 0.0);
            }
        }
    }

    #[test]
    fn oversubscribed_config_is_clamped_and_still_correct() {
        // workers × parallelism far beyond any machine: the harness must
        // clamp (shared inner pool, capped shard count) and the replay
        // must still produce the full series.
        let edges = barabasi_albert(300, 3, 0.5, 31);
        let mut cfg = quick_cfg();
        cfg.workers = 64;
        cfg.pagerank.parallelism = 64;
        let res = run_experiment("test", &edges, 60, false, &cfg).unwrap();
        assert_eq!(res.combos.len(), 2);
        for c in &res.combos {
            assert_eq!(c.rows.len(), 5);
            for row in &c.rows {
                assert!((0.0..=1.0).contains(&row.rbo));
                assert!(row.exact_secs > 0.0 && row.approx_secs > 0.0);
            }
        }
    }

    #[test]
    fn conservative_params_summarize_more_vertices() {
        let edges = barabasi_albert(400, 3, 0.5, 22);
        let res = run_experiment("test", &edges, 120, false, &quick_cfg()).unwrap();
        // combo 0 = (r=0.1, n=1, Δ=0.1) conservative; combo 1 = (0.3, 0, 0.9)
        let conservative = res.combos[0].avg(Metric::VertexRatio);
        let aggressive = res.combos[1].avg(Metric::VertexRatio);
        assert!(
            conservative >= aggressive,
            "conservative {conservative} vs aggressive {aggressive}"
        );
    }

    #[test]
    fn rbo_stays_high_for_conservative_params() {
        let edges = barabasi_albert(500, 3, 0.5, 23);
        let res = run_experiment("test", &edges, 100, false, &quick_cfg()).unwrap();
        let rbo = res.combos[0].avg(Metric::Rbo);
        assert!(rbo > 0.8, "conservative combo should track ground truth, rbo={rbo}");
    }

    #[test]
    fn ranked_orders_by_metric_direction() {
        let edges = barabasi_albert(300, 3, 0.5, 24);
        let res = run_experiment("test", &edges, 80, false, &quick_cfg()).unwrap();
        let by_rbo = res.ranked(Metric::Rbo);
        assert!(by_rbo[0].avg(Metric::Rbo) >= by_rbo[1].avg(Metric::Rbo));
        let by_vr = res.ranked(Metric::VertexRatio);
        assert!(by_vr[0].avg(Metric::VertexRatio) <= by_vr[1].avg(Metric::VertexRatio));
        // best_worst with small grids returns everything
        assert_eq!(res.best_worst(Metric::Rbo, 3).len(), 2);
    }
}
