//! Result persistence: writes figure CSVs, ASCII plots and the
//! EXPERIMENTS.md summary block for a set of experiment runs.

use std::path::Path;

use crate::error::Result;
use crate::experiments::figures::{figure_csv, figure_summary, figures_for_dataset, render_figure};
use crate::experiments::harness::{ExperimentResult, Metric};

/// Write everything for one experiment under `out_dir`:
/// `fig{N}_{dataset}_{metric}.csv` + a combined `{dataset}.txt` quicklook.
/// Returns the file names written.
pub fn write_experiment(
    out_dir: impl AsRef<Path>,
    result: &ExperimentResult,
) -> Result<Vec<String>> {
    let out_dir = out_dir.as_ref();
    std::fs::create_dir_all(out_dir)?;
    let mut written = Vec::new();
    let mut quicklook = String::new();
    for fig in figures_for_dataset(&result.dataset) {
        let csv_name = format!("fig{:02}_{}_{}.csv", fig.number, fig.dataset, fig.metric.name());
        std::fs::write(out_dir.join(&csv_name), figure_csv(&fig, result))?;
        written.push(csv_name);
        quicklook.push_str(&render_figure(&fig, result));
        quicklook.push('\n');
        quicklook.push_str(&figure_summary(&fig, result));
        quicklook.push_str("\n\n");
    }
    let txt_name = format!("{}.txt", result.dataset);
    std::fs::write(out_dir.join(&txt_name), quicklook)?;
    written.push(txt_name);
    Ok(written)
}

/// Markdown table row per figure for EXPERIMENTS.md.
pub fn markdown_rows(result: &ExperimentResult) -> String {
    let mut out = String::new();
    for fig in figures_for_dataset(&result.dataset) {
        let ranked = result.ranked(fig.metric);
        let best = ranked.first().map(|c| (c.params.label(), c.avg(fig.metric)));
        let worst = ranked.last().map(|c| (c.params.label(), c.avg(fig.metric)));
        if let (Some((bl, bv)), Some((wl, wv))) = (best, worst) {
            out.push_str(&format!(
                "| Fig. {} | {} | {} | {bv:.4} ({bl}) | {wv:.4} ({wl}) |\n",
                fig.number,
                result.dataset,
                fig.metric.name(),
            ));
        }
    }
    out
}

/// Aggregate headline: average speedup and RBO of the combination with
/// the best speedup (for the paper's “>50 % time reduction at >95 %
/// accuracy” claim).
pub fn headline(result: &ExperimentResult) -> (f64, f64) {
    let by_speedup = result.ranked(Metric::Speedup);
    match by_speedup.first() {
        Some(best) => (best.avg(Metric::Speedup), best.avg(Metric::Rbo)),
        None => (0.0, 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::harness::{run_experiment, HarnessConfig};
    use crate::graph::generate::barabasi_albert;
    use crate::summary::params::SummaryParams;

    fn tiny() -> ExperimentResult {
        let edges = barabasi_albert(250, 3, 0.5, 77);
        let cfg = HarnessConfig {
            q: 3,
            grid: vec![SummaryParams::new(0.1, 0, 0.1), SummaryParams::new(0.3, 0, 0.9)],
            seed: 5,
            workers: 2,
            ..Default::default()
        };
        run_experiment("web-cnr", &edges, 60, true, &cfg).unwrap()
    }

    #[test]
    fn write_experiment_emits_4_csvs_and_quicklook() {
        let res = tiny();
        let dir = std::env::temp_dir().join(format!("vg-report-{}", std::process::id()));
        let files = write_experiment(&dir, &res).unwrap();
        assert_eq!(files.len(), 5);
        assert!(files.iter().any(|f| f.contains("fig03") && f.contains("vertex_ratio")));
        assert!(files.iter().any(|f| f.ends_with("web-cnr.txt")));
        for f in &files {
            assert!(dir.join(f).is_file());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn markdown_and_headline() {
        let res = tiny();
        let md = markdown_rows(&res);
        assert_eq!(md.lines().count(), 4);
        assert!(md.contains("| Fig. 3 |"));
        let (speedup, rbo) = headline(&res);
        assert!(speedup > 0.0);
        assert!((0.0..=1.0).contains(&rbo));
    }
}
