//! Figure registry: maps every figure of the paper's evaluation (Figs.
//! 3–30) to a (dataset, metric) pair and renders/persists it.
//!
//! Layout of the paper's §5.3: per dataset, four figures in fixed order —
//! vertex ratio, edge ratio, RBO, speedup — each plotting the best-3 and
//! worst-3 parameter combinations by metric average over Q = 50 queries.
//! eu-2005 (Figs. 7–10) plots an r = 0.10 subset instead of best/worst
//! (§5.3: “For this dataset we focus on parameter combinations for a
//! fixed r = 0.10”).

use crate::experiments::harness::{CombinationResult, ExperimentResult, Metric};
use crate::util::ascii_plot::{render, Series};

/// One figure's identity.
#[derive(Clone, Debug)]
pub struct FigureSpec {
    /// Paper figure number (3–30).
    pub number: u32,
    /// Stand-in dataset name.
    pub dataset: &'static str,
    /// Which metric it plots.
    pub metric: Metric,
    /// Whether the paper plots the fixed-r subset instead of best/worst.
    pub fixed_r_subset: bool,
}

/// All 28 evaluation figures in paper order.
pub fn all_figures() -> Vec<FigureSpec> {
    let order: [(&'static str, bool); 7] = [
        ("web-cnr", false),       // Figs. 3–6
        ("web-eu", true),         // Figs. 7–10 (r = 0.10 subset)
        ("social-enron", false),  // Figs. 11–14
        ("cit-hepph", false),     // Figs. 15–18
        ("social-dblp", false),   // Figs. 19–22
        ("social-amazon", false), // Figs. 23–26
        ("fb-ego", false),        // Figs. 27–30
    ];
    let metrics = [Metric::VertexRatio, Metric::EdgeRatio, Metric::Rbo, Metric::Speedup];
    let mut out = Vec::with_capacity(28);
    let mut number = 3;
    for (dataset, fixed_r_subset) in order {
        for metric in metrics {
            out.push(FigureSpec { number, dataset, metric, fixed_r_subset });
            number += 1;
        }
    }
    out
}

/// Figures belonging to a dataset.
pub fn figures_for_dataset(dataset: &str) -> Vec<FigureSpec> {
    all_figures().into_iter().filter(|f| f.dataset == dataset).collect()
}

/// Figure spec by number.
pub fn figure_by_number(number: u32) -> Option<FigureSpec> {
    all_figures().into_iter().find(|f| f.number == number)
}

/// Select the combinations a figure plots.
pub fn select_combos<'a>(
    fig: &FigureSpec,
    result: &'a ExperimentResult,
) -> Vec<&'a CombinationResult> {
    if fig.fixed_r_subset {
        // eu-2005: all combinations with r = 0.10 (6 of 18).
        result.combos.iter().filter(|c| (c.params.r - 0.10).abs() < 1e-9).collect()
    } else {
        result.best_worst(fig.metric, 3)
    }
}

/// Render one figure as an ASCII chart (quick look; CSV is the durable
/// output).
pub fn render_figure(fig: &FigureSpec, result: &ExperimentResult) -> String {
    let combos = select_combos(fig, result);
    let series: Vec<Series> = combos
        .iter()
        .map(|c| {
            let label = format!("{} (avg {:.4})", c.params.label(), c.avg(fig.metric));
            Series::new(label, c.series(fig.metric))
        })
        .collect();
    let title = format!(
        "Figure {} — {} {} (|S|={}, Q={})",
        fig.number,
        result.dataset,
        fig.metric.name(),
        result.stream_len,
        result.q
    );
    render(&title, &series, 70, 16)
}

/// CSV for one figure: `query,<combo1>,<combo2>,…` (one column per
/// plotted combination).
pub fn figure_csv(fig: &FigureSpec, result: &ExperimentResult) -> String {
    let combos = select_combos(fig, result);
    let mut out = String::from("query");
    for c in &combos {
        out.push(',');
        out.push_str(&c.params.label());
    }
    out.push('\n');
    let q = combos.iter().map(|c| c.rows.len()).max().unwrap_or(0);
    for i in 0..q {
        out.push_str(&(i + 1).to_string());
        for c in &combos {
            out.push(',');
            if let Some(row) = c.rows.get(i) {
                out.push_str(&format!("{:.6}", fig.metric.value(row)));
            }
        }
        out.push('\n');
    }
    out
}

/// One-line summary used in EXPERIMENTS.md tables: best avg, worst avg.
pub fn figure_summary(fig: &FigureSpec, result: &ExperimentResult) -> String {
    let ranked = result.ranked(fig.metric);
    let best = ranked.first().map(|c| c.avg(fig.metric)).unwrap_or(0.0);
    let worst = ranked.last().map(|c| c.avg(fig.metric)).unwrap_or(0.0);
    format!(
        "fig {:>2}  {:<14} {:<12} best-avg {:>9.4}  worst-avg {:>9.4}",
        fig.number,
        fig.dataset,
        fig.metric.name(),
        best,
        worst
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::harness::{run_experiment, HarnessConfig};
    use crate::graph::generate::barabasi_albert;
    use crate::summary::params::SummaryParams;

    #[test]
    fn registry_covers_figs_3_to_30() {
        let figs = all_figures();
        assert_eq!(figs.len(), 28);
        assert_eq!(figs.first().unwrap().number, 3);
        assert_eq!(figs.last().unwrap().number, 30);
        // every dataset has exactly the four metrics in paper order
        for ds in ["web-cnr", "web-eu", "fb-ego"] {
            let f = figures_for_dataset(ds);
            assert_eq!(f.len(), 4);
            assert_eq!(f[0].metric, Metric::VertexRatio);
            assert_eq!(f[3].metric, Metric::Speedup);
        }
        // eu-2005 figures use the fixed-r subset
        assert!(figure_by_number(7).unwrap().fixed_r_subset);
        assert!(!figure_by_number(3).unwrap().fixed_r_subset);
    }

    fn tiny_result() -> ExperimentResult {
        let edges = barabasi_albert(300, 3, 0.5, 31);
        let cfg = HarnessConfig {
            q: 4,
            grid: vec![
                SummaryParams::new(0.10, 0, 0.1),
                SummaryParams::new(0.10, 1, 0.9),
                SummaryParams::new(0.30, 0, 0.9),
            ],
            seed: 5,
            workers: 2,
            ..Default::default()
        };
        run_experiment("web-eu", &edges, 80, false, &cfg).unwrap()
    }

    #[test]
    fn fixed_r_subset_filters_to_r010() {
        let res = tiny_result();
        let fig = figure_by_number(9).unwrap(); // eu-2005 RBO
        let combos = select_combos(&fig, &res);
        assert_eq!(combos.len(), 2);
        assert!(combos.iter().all(|c| (c.params.r - 0.10).abs() < 1e-9));
    }

    #[test]
    fn csv_has_header_and_q_rows() {
        let res = tiny_result();
        let fig = figure_by_number(10).unwrap();
        let csv = figure_csv(&fig, &res);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + 4);
        assert!(lines[0].starts_with("query,"));
        assert!(lines[1].starts_with("1,"));
    }

    #[test]
    fn render_and_summary_do_not_panic() {
        let res = tiny_result();
        for n in [7, 8, 9, 10] {
            let fig = figure_by_number(n).unwrap();
            let txt = render_figure(&fig, &res);
            assert!(txt.contains(&format!("Figure {n}")));
            let s = figure_summary(&fig, &res);
            assert!(s.contains("best-avg"));
        }
    }
}
