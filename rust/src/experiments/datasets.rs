//! The evaluation datasets (paper Table 1) as deterministic synthetic
//! stand-ins (DESIGN.md §Substitutions: the LAW/SNAP originals are not
//! redistributable offline; generators reproduce each topology class at
//! ~10× reduced scale, except Cit-HepPh which is generated at 1:1).

use crate::graph::generate::{
    barabasi_albert, citation_dag, copying_web, ego_network, EdgeList,
};

/// Topology class of a dataset (drives the generator).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Copying-model web graph (power-law in-degree).
    Web,
    /// Preferential-attachment social network.
    Social,
    /// Time-layered citation DAG.
    Citation,
    /// Dense-core ego network.
    Ego,
}

/// A dataset specification: paper identity + stand-in generator params.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Stand-in name used in file names and figures.
    pub name: &'static str,
    /// The paper's original dataset this stands in for.
    pub paper_name: &'static str,
    /// Paper's |V| / |E| (documentation).
    pub paper_v: u64,
    pub paper_e: u64,
    /// Topology class.
    pub topology: Topology,
    /// Stand-in vertex count at scale 1.0.
    pub n: usize,
    /// Generator fan-out parameter (out-links / attachments / citations).
    pub d: usize,
    /// Stream size |S| (paper Table 1).
    pub stream_len: usize,
    /// Whether the paper evaluates this dataset with a shuffled stream
    /// (§5: cnr-2000 is the entropy-intensive shuffled scenario).
    pub shuffled: bool,
    /// Generator seed (fixed ⇒ reproducible).
    pub seed: u64,
}

impl DatasetSpec {
    /// Generate the stand-in edge list at `scale` (1.0 = DESIGN.md Table
    /// 1b sizes; smaller for quick CI runs). Vertex counts scale
    /// linearly, fan-out stays fixed so density is preserved.
    pub fn generate(&self, scale: f64) -> EdgeList {
        let n = ((self.n as f64 * scale) as usize).max(self.d * 4 + 8);
        match self.topology {
            Topology::Web => copying_web(n, self.d, 0.7, self.seed),
            Topology::Social => barabasi_albert(n, self.d, 0.7, self.seed),
            Topology::Citation => citation_dag(n, self.d, self.seed),
            Topology::Ego => {
                let core = (n / 72).max(8);
                ego_network(n, core, 0.5, self.d, self.seed)
            }
        }
    }

    /// Stream size scaled together with the graph (keeps |S|/|E| roughly
    /// constant so summary ratios stay in the paper's regime).
    pub fn stream_len_at(&self, scale: f64) -> usize {
        ((self.stream_len as f64 * scale) as usize).max(50)
    }
}

/// All seven datasets (paper Table 1 order).
pub fn all_datasets() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "web-cnr",
            paper_name: "cnr-2000",
            paper_v: 325_557,
            paper_e: 3_216_152,
            topology: Topology::Web,
            n: 32_000,
            d: 10,
            stream_len: 40_000,
            shuffled: true, // the paper's entropy-intensive scenario
            seed: 0xC0FFEE01,
        },
        DatasetSpec {
            name: "web-eu",
            paper_name: "eu-2005",
            paper_v: 862_664,
            paper_e: 19_235_140,
            topology: Topology::Web,
            n: 86_000,
            d: 22,
            stream_len: 20_000,
            shuffled: false,
            seed: 0xC0FFEE02,
        },
        DatasetSpec {
            name: "cit-hepph",
            paper_name: "Cit-HepPh",
            paper_v: 34_546,
            paper_e: 421_576,
            topology: Topology::Citation,
            n: 34_546, // kept at original scale — already small
            d: 12,
            stream_len: 40_000,
            shuffled: false,
            seed: 0xC0FFEE03,
        },
        DatasetSpec {
            name: "social-enron",
            paper_name: "enron",
            paper_v: 69_244,
            paper_e: 276_143,
            topology: Topology::Social,
            n: 17_000,
            d: 8,
            stream_len: 40_000,
            shuffled: false,
            seed: 0xC0FFEE04,
        },
        DatasetSpec {
            name: "social-dblp",
            paper_name: "dblp-2010",
            paper_v: 326_186,
            paper_e: 1_615_400,
            topology: Topology::Social,
            n: 33_000,
            d: 3,
            stream_len: 40_000,
            shuffled: false,
            seed: 0xC0FFEE05,
        },
        DatasetSpec {
            name: "social-amazon",
            paper_name: "amazon-2008",
            paper_v: 735_323,
            paper_e: 5_158_388,
            topology: Topology::Social,
            n: 74_000,
            d: 4,
            stream_len: 20_000,
            shuffled: false,
            seed: 0xC0FFEE06,
        },
        DatasetSpec {
            name: "fb-ego",
            paper_name: "Facebook-ego",
            paper_v: 63_731,
            paper_e: 1_545_686,
            topology: Topology::Ego,
            n: 16_000,
            d: 15,
            stream_len: 40_000,
            shuffled: false,
            seed: 0xC0FFEE07,
        },
    ]
}

/// Find a dataset spec by stand-in name.
pub fn dataset_by_name(name: &str) -> Option<DatasetSpec> {
    all_datasets().into_iter().find(|d| d.name == name)
}

/// Render Table 1 (paper) side by side with the stand-ins at `scale`.
pub fn table1(scale: f64) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14} {:<13} {:>9} {:>11} | {:>9} {:>11} {:>8} {:>8}\n",
        "stand-in", "paper", "paper|V|", "paper|E|", "gen|V|", "gen|E|", "|S|", "shuffled"
    ));
    for spec in all_datasets() {
        let edges = spec.generate(scale);
        let v = edges
            .iter()
            .flat_map(|&(u, w)| [u, w])
            .collect::<std::collections::HashSet<_>>()
            .len();
        out.push_str(&format!(
            "{:<14} {:<13} {:>9} {:>11} | {:>9} {:>11} {:>8} {:>8}\n",
            spec.name,
            spec.paper_name,
            spec.paper_v,
            spec.paper_e,
            v,
            edges.len(),
            spec.stream_len_at(scale),
            spec.shuffled,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_datasets_matching_paper_table() {
        let ds = all_datasets();
        assert_eq!(ds.len(), 7);
        let names: Vec<_> = ds.iter().map(|d| d.paper_name).collect();
        assert_eq!(
            names,
            vec![
                "cnr-2000", "eu-2005", "Cit-HepPh", "enron", "dblp-2010", "amazon-2008",
                "Facebook-ego"
            ]
        );
        // paper's stream sizes
        assert!(ds.iter().all(|d| d.stream_len == 20_000 || d.stream_len == 40_000));
        // only cnr-2000 is shuffled
        assert_eq!(ds.iter().filter(|d| d.shuffled).count(), 1);
    }

    #[test]
    fn generation_is_deterministic_and_scaled() {
        let spec = dataset_by_name("social-enron").unwrap();
        let a = spec.generate(0.05);
        let b = spec.generate(0.05);
        assert_eq!(a, b);
        let big = spec.generate(0.1);
        assert!(big.len() > a.len());
    }

    #[test]
    fn edge_counts_land_near_targets_at_small_scale() {
        // At scale 0.05, |E| should be ≈ 0.05 × the Table-1b target
        // (±50 % — generators are stochastic).
        for spec in all_datasets() {
            if spec.name == "web-eu" || spec.name == "social-amazon" {
                continue; // larger; covered by the figure harness itself
            }
            let e = spec.generate(0.05).len() as f64;
            let v = spec.n as f64 * 0.05;
            let density = e / v;
            assert!(
                density > 1.0 && density < 60.0,
                "{}: density {density} out of plausible range",
                spec.name
            );
        }
    }

    #[test]
    fn table1_renders_all_rows() {
        let t = table1(0.02);
        assert_eq!(t.lines().count(), 8);
        assert!(t.contains("cnr-2000") && t.contains("fb-ego"));
    }
}
