//! Adaptive serving policy — the paper's §7 research direction:
//! “approximation strategies based on the statistical records, from a
//! set of manually implemented policies to automations based on machine
//! learning.”
//!
//! [`AdaptivePolicy`] learns online from the engine's own statistical
//! records, with no labels required:
//!
//! * It tracks an **error budget**: a proxy for accumulated approximation
//!   error, grown every approximate/repeated query proportionally to the
//!   touched-vertex ratio (update magnitude) and reset by exact queries.
//!   When the budget crosses `error_budget`, it forces an exact refresh —
//!   an automated version of “performing an exact computation if too
//!   much entropy has accumulated” (§7).
//! * It adapts the **repeat threshold** by stochastic approximation
//!   (Robbins–Monro): the threshold moves to steer the observed fraction
//!   of repeat-served queries toward `target_repeat_rate`, so the knob
//!   self-tunes to the stream instead of needing per-dataset hand
//!   calibration.

use crate::coordinator::udf::{Action, ExecStats, QueryContext, UdfSuite};

/// Online self-tuning policy. See module docs.
#[derive(Clone, Debug)]
pub struct AdaptivePolicy {
    /// Error-proxy ceiling before an exact refresh is forced.
    pub error_budget: f64,
    /// Desired fraction of queries served from cache.
    pub target_repeat_rate: f64,
    /// Robbins–Monro step size for the repeat threshold.
    pub learning_rate: f64,
    // --- state ---
    accumulated_error: f64,
    repeat_threshold: f64,
    queries: u64,
    repeats: u64,
    exacts_forced: u64,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        Self::new(0.5, 0.2)
    }
}

impl AdaptivePolicy {
    /// `error_budget`: sum of touched ratios tolerated before an exact
    /// refresh; `target_repeat_rate`: fraction of queries to serve from
    /// cache.
    pub fn new(error_budget: f64, target_repeat_rate: f64) -> Self {
        assert!(error_budget > 0.0);
        assert!((0.0..1.0).contains(&target_repeat_rate));
        Self {
            error_budget,
            target_repeat_rate,
            learning_rate: 0.05,
            accumulated_error: 0.0,
            repeat_threshold: 0.001,
            queries: 0,
            repeats: 0,
            exacts_forced: 0,
        }
    }

    /// Observed repeat rate so far.
    pub fn repeat_rate(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.repeats as f64 / self.queries as f64
        }
    }

    /// Current (learned) repeat threshold on the touched ratio.
    pub fn repeat_threshold(&self) -> f64 {
        self.repeat_threshold
    }

    /// Exact refreshes the error budget has forced.
    pub fn exacts_forced(&self) -> u64 {
        self.exacts_forced
    }

    /// Current error proxy.
    pub fn accumulated_error(&self) -> f64 {
        self.accumulated_error
    }
}

impl UdfSuite for AdaptivePolicy {
    fn on_query(&mut self, ctx: &QueryContext) -> Action {
        self.queries += 1;
        let magnitude = ctx.stats.touched_ratio();
        // 1) budget check: too much approximation debt → exact refresh
        if self.accumulated_error + magnitude > self.error_budget {
            self.exacts_forced += 1;
            return Action::ComputeExact;
        }
        // 2) threshold check with online adaptation
        let action = if magnitude < self.repeat_threshold {
            self.repeats += 1;
            Action::RepeatLast
        } else {
            Action::ComputeApproximate
        };
        // Robbins–Monro: move the threshold toward the target repeat rate.
        let signal = if action == Action::RepeatLast { 1.0 } else { 0.0 };
        self.repeat_threshold += self.learning_rate
            * (self.target_repeat_rate - signal)
            * self.repeat_threshold.max(1e-6);
        self.repeat_threshold = self.repeat_threshold.clamp(0.0, 0.5);
        action
    }

    fn on_query_result(&mut self, ctx: &QueryContext, action: Action, _stats: &ExecStats) {
        // Update the error proxy from what actually happened.
        match action {
            Action::ComputeExact => self.accumulated_error = 0.0,
            Action::ComputeApproximate => {
                // approximation leaves residual error ∝ what it skipped
                self.accumulated_error += 0.1 * ctx.stats.touched_ratio();
            }
            Action::RepeatLast => {
                // serving stale results accrues the full update magnitude
                self.accumulated_error += ctx.stats.touched_ratio();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::buffer::UpdateStatistics;

    fn ctx(touched: usize, total: usize) -> QueryContext {
        QueryContext {
            query_id: 1,
            stats: UpdateStatistics {
                touched_vertices: touched,
                total_vertices: total,
                ..Default::default()
            },
            num_vertices: total,
            num_edges: total * 3,
            queries_since_exact: 0,
            snapshot_age_queries: 0,
            snapshot_age_secs: 0.0,
            updates_since_refresh: 0,
        }
    }

    fn drive(p: &mut AdaptivePolicy, touched: usize, total: usize) -> Action {
        let c = ctx(touched, total);
        let a = p.on_query(&c);
        let stats = ExecStats {
            elapsed_secs: 0.001,
            backend: None,
            summary_vertices: 0,
            summary_edges: 0,
            iterations: 0,
        };
        p.on_query_result(&c, a, &stats);
        a
    }

    #[test]
    fn budget_forces_exact_refresh() {
        let mut p = AdaptivePolicy::new(0.3, 0.1);
        let mut saw_exact = false;
        for _ in 0..60 {
            if drive(&mut p, 100, 1000) == Action::ComputeExact {
                saw_exact = true;
                assert_eq!(p.accumulated_error(), 0.0, "exact resets the budget");
                break;
            }
        }
        assert!(saw_exact, "10% updates must exhaust a 0.3 budget within 60 queries");
        assert!(p.exacts_forced() >= 1);
    }

    #[test]
    fn threshold_adapts_toward_target_repeat_rate() {
        let mut p = AdaptivePolicy::new(1e18, 0.5); // budget effectively off
        // constant small updates (ratio 0.002)
        for _ in 0..400 {
            drive(&mut p, 2, 1000);
        }
        let rate = p.repeat_rate();
        assert!(
            (rate - 0.5).abs() < 0.2,
            "repeat rate should approach target 0.5, got {rate} (threshold {})",
            p.repeat_threshold()
        );
    }

    #[test]
    fn tiny_updates_get_repeated_big_ones_do_not() {
        let mut p = AdaptivePolicy::new(1e18, 0.2);
        assert_eq!(drive(&mut p, 0, 1000), Action::RepeatLast);
        assert_eq!(drive(&mut p, 500, 1000), Action::ComputeApproximate);
    }

    #[test]
    fn threshold_stays_in_bounds() {
        let mut p = AdaptivePolicy::new(1e18, 0.9);
        for _ in 0..2000 {
            drive(&mut p, 1, 10_000);
        }
        assert!(p.repeat_threshold() <= 0.5);
        assert!(p.repeat_threshold() >= 0.0);
    }
}
