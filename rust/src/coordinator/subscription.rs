//! Standing queries: the push plane.
//!
//! Everything before this module is request/response — a client polls
//! and the server answers from the latest published [`RankSnapshot`].
//! A *standing* query inverts that: the client registers interest once
//! ("notify me when the top-K set changes", "when vertex v's rank
//! crosses τ", "when v enters or leaves the hot set", "when v changes
//! community") and the server pushes a notification whenever the
//! condition fires.
//!
//! Evaluation rides the existing publish path: every time the engine
//! publishes a new snapshot, [`SubscriptionRegistry::notify_publish`]
//! diffs it against the previous one, per subscription. The diff is
//! cheap by construction — top-K membership comes from the snapshot's
//! precomputed deterministic top-K index (O(K log n)), rank lookups are
//! O(log n) binary searches, and hot-set membership is a binary search
//! over the sorted hot-vertex list the engine now attaches at publish
//! time. Community-change subscriptions are driven separately by the
//! server's streaming label-propagation workload via
//! [`SubscriptionRegistry::notify_community`].
//!
//! Delivery is decoupled from evaluation: each wire connection owns a
//! bounded [`Mailbox`]; the registry holds only a [`Weak`] reference to
//! it, so a vanished connection never blocks the publish path and is
//! pruned on the next notify sweep. The readiness loop drains mailboxes
//! into per-connection out-buffers and writes lines tagged
//! `{"v":2,"sub":<id>,"notify":{...}}` — push frames exist only in wire
//! protocol v2, where responses already carry ids and may interleave.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::coordinator::serving::RankSnapshot;
use crate::graph::VertexId;
use crate::util::json::Json;

/// Per-connection notification queue depth. A subscriber that stops
/// reading keeps only the newest `MAX_MAILBOX_DEPTH` notifications —
/// old ones are dropped (counted) rather than growing without bound or
/// back-pressuring the publish path.
pub const MAX_MAILBOX_DEPTH: usize = 1024;

/// A bounded, drop-oldest queue of rendered notification lines, shared
/// between the publish path (producer) and one wire connection's
/// readiness loop (consumer).
pub struct Mailbox {
    inner: Mutex<MailboxInner>,
}

struct MailboxInner {
    queue: VecDeque<Json>,
    dropped: u64,
}

impl Mailbox {
    /// A fresh mailbox. Returns an `Arc` because the registry keeps a
    /// `Weak` handle to the same allocation.
    #[allow(clippy::new_without_default)]
    pub fn new() -> Arc<Mailbox> {
        Arc::new(Mailbox {
            inner: Mutex::new(MailboxInner { queue: VecDeque::new(), dropped: 0 }),
        })
    }

    /// Enqueue a rendered notification; returns `true` if an old entry
    /// was evicted to make room.
    fn push(&self, line: Json) -> bool {
        let mut g = self.inner.lock().unwrap();
        let mut evicted = false;
        if g.queue.len() >= MAX_MAILBOX_DEPTH {
            g.queue.pop_front();
            g.dropped += 1;
            evicted = true;
        }
        g.queue.push_back(line);
        evicted
    }

    /// Take every queued notification, oldest first.
    pub fn drain(&self) -> Vec<Json> {
        let mut g = self.inner.lock().unwrap();
        g.queue.drain(..).collect()
    }

    /// Queued (undelivered) notifications.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Notifications evicted because the consumer fell behind.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }
}

/// What a standing query watches. Parsed from the wire `subscribe` op.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Subscription {
    /// Fire when the top-`k` vertex *set* changes between consecutive
    /// published snapshots (entries/exits, not internal reordering).
    TopK { k: usize },
    /// Fire when `id`'s rank crosses `tau` in either direction.
    RankThreshold { id: VertexId, tau: f64 },
    /// Fire when `id` enters or leaves the engine's hot set |K|.
    HotSet { id: VertexId },
    /// Fire when `id`'s community label changes (streaming label
    /// propagation; requires the server's `--communities` workload).
    Community { id: VertexId },
}

impl Subscription {
    /// Parse the wire shape: `{"op":"subscribe","what":"topk","k":10}`,
    /// `{"what":"rank","id":7,"tau":0.002}`, `{"what":"hotset","id":7}`
    /// or `{"what":"community","id":7}`.
    pub fn parse(req: &Json) -> Result<Subscription, String> {
        let what = req.get("what").and_then(Json::as_str).unwrap_or("");
        match what {
            "topk" => {
                let k = req.get("k").and_then(Json::as_u64).unwrap_or(10) as usize;
                if k == 0 {
                    return Err("subscribe topk needs k >= 1".into());
                }
                Ok(Subscription::TopK { k })
            }
            "rank" => {
                let id = req
                    .get("id")
                    .and_then(Json::as_u64)
                    .ok_or("subscribe rank needs a numeric id")?;
                let tau = req
                    .get("tau")
                    .and_then(Json::as_f64)
                    .ok_or("subscribe rank needs a numeric tau")?;
                Ok(Subscription::RankThreshold { id, tau })
            }
            "hotset" => {
                let id = req
                    .get("id")
                    .and_then(Json::as_u64)
                    .ok_or("subscribe hotset needs a numeric id")?;
                Ok(Subscription::HotSet { id })
            }
            "community" => {
                let id = req
                    .get("id")
                    .and_then(Json::as_u64)
                    .ok_or("subscribe community needs a numeric id")?;
                Ok(Subscription::Community { id })
            }
            other => Err(format!(
                "unknown subscription {other:?} (expected topk, rank, hotset or community)"
            )),
        }
    }
}

/// A fired standing query, ready to render as a push frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Notification {
    /// The top-`k` set changed: `entered` in new-rank order, `left` in
    /// old-rank order.
    TopK { k: usize, version: u64, entered: Vec<VertexId>, left: Vec<VertexId> },
    /// `id`'s rank crossed `tau`; `up` is the crossing direction and
    /// `rank` the new value.
    RankThreshold { id: VertexId, tau: f64, rank: f64, up: bool, version: u64 },
    /// `id` entered (`entered == true`) or left the hot set.
    HotSet { id: VertexId, entered: bool, version: u64 },
    /// `id` moved to community `label`.
    Community { id: VertexId, label: u32, version: u64 },
}

impl Notification {
    /// The published-snapshot (or community query) version the event
    /// was observed at.
    pub fn version(&self) -> u64 {
        match self {
            Notification::TopK { version, .. }
            | Notification::RankThreshold { version, .. }
            | Notification::HotSet { version, .. }
            | Notification::Community { version, .. } => *version,
        }
    }

    /// Render the v2 push frame `{"v":2,"sub":N,"notify":{...}}`.
    pub fn to_json(&self, sub: u64) -> Json {
        let ids = |xs: &[VertexId]| Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect());
        let body = match self {
            Notification::TopK { k, version, entered, left } => Json::obj(vec![
                ("kind", Json::Str("topk".into())),
                ("k", Json::Num(*k as f64)),
                ("version", Json::Num(*version as f64)),
                ("entered", ids(entered)),
                ("left", ids(left)),
            ]),
            Notification::RankThreshold { id, tau, rank, up, version } => Json::obj(vec![
                ("kind", Json::Str("rank".into())),
                ("id", Json::Num(*id as f64)),
                ("tau", Json::Num(*tau)),
                ("rank", Json::Num(*rank)),
                ("direction", Json::Str(if *up { "up" } else { "down" }.into())),
                ("version", Json::Num(*version as f64)),
            ]),
            Notification::HotSet { id, entered, version } => Json::obj(vec![
                ("kind", Json::Str("hotset".into())),
                ("id", Json::Num(*id as f64)),
                ("event", Json::Str(if *entered { "entered" } else { "left" }.into())),
                ("version", Json::Num(*version as f64)),
            ]),
            Notification::Community { id, label, version } => Json::obj(vec![
                ("kind", Json::Str("community".into())),
                ("id", Json::Num(*id as f64)),
                ("label", Json::Num(*label as f64)),
                ("version", Json::Num(*version as f64)),
            ]),
        };
        Json::obj(vec![
            ("v", Json::Num(2.0)),
            ("sub", Json::Num(sub as f64)),
            ("notify", body),
        ])
    }
}

/// Diff one snapshot-driven subscription between two consecutive
/// published snapshots. Pure — the property tests compare this against
/// brute-force recomputation. `Community` subscriptions are not
/// snapshot-driven and never fire here.
pub fn diff(spec: &Subscription, prev: &RankSnapshot, next: &RankSnapshot) -> Option<Notification> {
    match *spec {
        Subscription::TopK { k } => {
            let before = prev.top_ids(k);
            let after = next.top_ids(k);
            let entered: Vec<VertexId> =
                after.iter().copied().filter(|v| !before.contains(v)).collect();
            let left: Vec<VertexId> =
                before.iter().copied().filter(|v| !after.contains(v)).collect();
            if entered.is_empty() && left.is_empty() {
                None
            } else {
                Some(Notification::TopK { k, version: next.version, entered, left })
            }
        }
        Subscription::RankThreshold { id, tau } => {
            let was_above = prev.rank_of(id).unwrap_or(0.0) > tau;
            let rank = next.rank_of(id).unwrap_or(0.0);
            let is_above = rank > tau;
            if was_above == is_above {
                None
            } else {
                Some(Notification::RankThreshold {
                    id,
                    tau,
                    rank,
                    up: is_above,
                    version: next.version,
                })
            }
        }
        Subscription::HotSet { id } => {
            let was_hot = prev.is_hot(id);
            let is_hot = next.is_hot(id);
            if was_hot == is_hot {
                None
            } else {
                Some(Notification::HotSet { id, entered: is_hot, version: next.version })
            }
        }
        Subscription::Community { .. } => None,
    }
}

struct ActiveSub {
    id: u64,
    spec: Subscription,
    mailbox: Weak<Mailbox>,
}

/// All live standing queries, shared between the publish path (which
/// evaluates them) and the wire server (which registers them and drains
/// the mailboxes). One registry per engine, owned by the serving
/// `Shared` state so every `SnapshotReader` clone sees the same one.
#[derive(Default)]
pub struct SubscriptionRegistry {
    subs: Mutex<Vec<ActiveSub>>,
    next_id: AtomicU64,
    /// Live count mirrored outside the lock so the publish fast path
    /// (no subscribers — the overwhelmingly common case) is one load.
    live: AtomicUsize,
    sent: AtomicU64,
    dropped: AtomicU64,
}

impl SubscriptionRegistry {
    /// Register a standing query delivering into `mailbox`; returns the
    /// subscription id echoed in every push frame.
    pub fn subscribe(&self, spec: Subscription, mailbox: &Arc<Mailbox>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        let mut g = self.subs.lock().unwrap();
        g.push(ActiveSub { id, spec, mailbox: Arc::downgrade(mailbox) });
        self.live.store(g.len(), Ordering::SeqCst);
        id
    }

    /// Drop a subscription; `false` if the id was unknown.
    pub fn unsubscribe(&self, id: u64) -> bool {
        let mut g = self.subs.lock().unwrap();
        let before = g.len();
        g.retain(|s| s.id != id);
        let removed = g.len() != before;
        self.live.store(g.len(), Ordering::SeqCst);
        removed
    }

    /// Live subscriptions (including ones whose connection has vanished
    /// but has not been pruned yet).
    pub fn len(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// True when nothing is subscribed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total notifications enqueued since startup.
    pub fn notifications_sent(&self) -> u64 {
        self.sent.load(Ordering::SeqCst)
    }

    /// Notifications evicted from full mailboxes (slow consumers).
    pub fn notifications_dropped(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }

    /// Whether any community-change subscription is live — the server
    /// skips the label-propagation refresh entirely when none is.
    pub fn has_community_subs(&self) -> bool {
        if self.is_empty() {
            return false;
        }
        let g = self.subs.lock().unwrap();
        g.iter().any(|s| matches!(s.spec, Subscription::Community { .. }))
    }

    /// Evaluate every snapshot-driven subscription against a publish
    /// transition. Runs on the engine thread right after the new
    /// snapshot is swapped in; cost is O(subs · K log n), zero when no
    /// one is subscribed.
    pub fn notify_publish(&self, prev: &RankSnapshot, next: &RankSnapshot) {
        if self.live.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut g = self.subs.lock().unwrap();
        g.retain(|s| {
            let Some(mb) = s.mailbox.upgrade() else { return false };
            if let Some(event) = diff(&s.spec, prev, next) {
                if mb.push(event.to_json(s.id)) {
                    self.dropped.fetch_add(1, Ordering::SeqCst);
                }
                self.sent.fetch_add(1, Ordering::SeqCst);
            }
            true
        });
        self.live.store(g.len(), Ordering::SeqCst);
    }

    /// Evaluate community-change subscriptions after a label-propagation
    /// refresh. `labels(id)` returns the (previous, current) label of a
    /// vertex; an event fires when both exist and differ, or when the
    /// vertex gained its first label.
    pub fn notify_community(
        &self,
        version: u64,
        labels: impl Fn(VertexId) -> (Option<u32>, Option<u32>),
    ) {
        if self.live.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut g = self.subs.lock().unwrap();
        g.retain(|s| {
            let Some(mb) = s.mailbox.upgrade() else { return false };
            if let Subscription::Community { id } = s.spec {
                let (before, now) = labels(id);
                if let Some(label) = now {
                    if before != now {
                        let event = Notification::Community { id, label, version };
                        if mb.push(event.to_json(s.id)) {
                            self.dropped.fetch_add(1, Ordering::SeqCst);
                        }
                        self.sent.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }
            true
        });
        self.live.store(g.len(), Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::udf::{Action, ExecStats};

    fn snap(version: u64, ids: Vec<u64>, ranks: Vec<f64>, hot: Vec<u64>) -> RankSnapshot {
        let mut s = RankSnapshot::new(
            version,
            version,
            version,
            Action::ComputeExact,
            ExecStats::default(),
            ids,
            ranks,
            8,
            Json::Null,
        );
        s.set_hot_set(hot);
        s
    }

    #[test]
    fn topk_diff_reports_entries_and_exits() {
        let a = snap(1, vec![0, 1, 2, 3], vec![0.4, 0.3, 0.2, 0.1], vec![]);
        let b = snap(2, vec![0, 1, 2, 3], vec![0.1, 0.3, 0.2, 0.4], vec![]);
        let got = diff(&Subscription::TopK { k: 2 }, &a, &b).unwrap();
        assert_eq!(
            got,
            Notification::TopK { k: 2, version: 2, entered: vec![3], left: vec![0] }
        );
        assert!(diff(&Subscription::TopK { k: 4 }, &a, &b).is_none());
    }

    #[test]
    fn threshold_fires_on_crossings_only() {
        let a = snap(1, vec![0, 1], vec![0.1, 0.9], vec![]);
        let b = snap(2, vec![0, 1], vec![0.6, 0.9], vec![]);
        let spec = Subscription::RankThreshold { id: 0, tau: 0.5 };
        let got = diff(&spec, &a, &b).unwrap();
        assert_eq!(
            got,
            Notification::RankThreshold { id: 0, tau: 0.5, rank: 0.6, up: true, version: 2 }
        );
        // No crossing: both sides above.
        assert!(diff(&spec, &b, &b).is_none());
        // Unknown vertex counts as rank 0 (below any positive tau).
        assert!(diff(&Subscription::RankThreshold { id: 9, tau: 0.5 }, &a, &b).is_none());
    }

    #[test]
    fn hot_set_diff_uses_published_membership() {
        let a = snap(1, vec![0, 1], vec![0.5, 0.5], vec![1]);
        let b = snap(2, vec![0, 1], vec![0.5, 0.5], vec![0]);
        assert_eq!(
            diff(&Subscription::HotSet { id: 0 }, &a, &b).unwrap(),
            Notification::HotSet { id: 0, entered: true, version: 2 }
        );
        assert_eq!(
            diff(&Subscription::HotSet { id: 1 }, &a, &b).unwrap(),
            Notification::HotSet { id: 1, entered: false, version: 2 }
        );
    }

    #[test]
    fn registry_routes_to_mailboxes_and_prunes_dead_ones() {
        let reg = SubscriptionRegistry::default();
        let mb = Mailbox::new();
        let sub = reg.subscribe(Subscription::TopK { k: 1 }, &mb);
        let gone = Mailbox::new();
        reg.subscribe(Subscription::TopK { k: 1 }, &gone);
        drop(gone);
        assert_eq!(reg.len(), 2);

        let a = snap(1, vec![0, 1], vec![0.9, 0.1], vec![]);
        let b = snap(2, vec![0, 1], vec![0.1, 0.9], vec![]);
        reg.notify_publish(&a, &b);
        // Dead mailbox pruned, live one got exactly one frame.
        assert_eq!(reg.len(), 1);
        let lines = mb.drain();
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].get("sub").and_then(Json::as_u64), Some(sub));
        assert_eq!(lines[0].get("v").and_then(Json::as_u64), Some(2));
        let body = lines[0].get("notify").unwrap();
        assert_eq!(body.get("kind").and_then(Json::as_str), Some("topk"));
        assert_eq!(reg.notifications_sent(), 1);

        assert!(reg.unsubscribe(sub));
        assert!(!reg.unsubscribe(sub));
        assert!(reg.is_empty());
    }

    #[test]
    fn mailbox_drops_oldest_beyond_depth() {
        let mb = Mailbox::new();
        for i in 0..(MAX_MAILBOX_DEPTH + 3) {
            mb.push(Json::Num(i as f64));
        }
        assert_eq!(mb.len(), MAX_MAILBOX_DEPTH);
        assert_eq!(mb.dropped(), 3);
        let lines = mb.drain();
        assert_eq!(lines[0], Json::Num(3.0));
        assert!(mb.is_empty());
    }

    #[test]
    fn community_notifications_fire_on_label_changes() {
        let reg = SubscriptionRegistry::default();
        let mb = Mailbox::new();
        let sub = reg.subscribe(Subscription::Community { id: 4 }, &mb);
        assert!(reg.has_community_subs());
        reg.notify_community(7, |id| if id == 4 { (Some(1), Some(2)) } else { (None, None) });
        reg.notify_community(8, |_| (Some(2), Some(2)));
        let lines = mb.drain();
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].get("sub").and_then(Json::as_u64), Some(sub));
        let body = lines[0].get("notify").unwrap();
        assert_eq!(body.get("kind").and_then(Json::as_str), Some("community"));
        assert_eq!(body.get("label").and_then(Json::as_u64), Some(2));
        assert_eq!(body.get("version").and_then(Json::as_u64), Some(7));
    }

    #[test]
    fn parse_covers_every_subscription_shape() {
        let p = |s: &str| Subscription::parse(&Json::parse(s).unwrap());
        assert_eq!(p(r#"{"what":"topk","k":3}"#), Ok(Subscription::TopK { k: 3 }));
        assert_eq!(p(r#"{"what":"topk"}"#), Ok(Subscription::TopK { k: 10 }));
        assert_eq!(
            p(r#"{"what":"rank","id":7,"tau":0.25}"#),
            Ok(Subscription::RankThreshold { id: 7, tau: 0.25 })
        );
        assert_eq!(p(r#"{"what":"hotset","id":7}"#), Ok(Subscription::HotSet { id: 7 }));
        assert_eq!(p(r#"{"what":"community","id":7}"#), Ok(Subscription::Community { id: 7 }));
        assert!(p(r#"{"what":"rank","id":7}"#).is_err());
        assert!(p(r#"{"what":"nope"}"#).is_err());
        assert!(p(r#"{"op":"subscribe"}"#).is_err());
    }
}
