//! Standing queries: the push plane.
//!
//! Everything before this module is request/response — a client polls
//! and the server answers from the latest published [`RankSnapshot`].
//! A *standing* query inverts that: the client registers interest once
//! ("notify me when the top-K set changes", "when vertex v's rank
//! crosses τ", "when v enters or leaves the hot set", "when v changes
//! community") and the server pushes a notification whenever the
//! condition fires.
//!
//! Evaluation rides the existing publish path: every time the engine
//! publishes a new snapshot, [`SubscriptionRegistry::notify_publish`]
//! diffs it against the previous one, per subscription. The diff is
//! cheap by construction — top-K membership comes from the snapshot's
//! precomputed deterministic top-K index (O(K log n)), rank lookups are
//! O(log n) binary searches, and hot-set membership is a binary search
//! over the sorted hot-vertex list the engine now attaches at publish
//! time. Community-change subscriptions are driven separately by the
//! server's streaming label-propagation workload via
//! [`SubscriptionRegistry::notify_community`].
//!
//! Delivery is decoupled from evaluation: each wire connection owns a
//! bounded [`Mailbox`]; the registry holds only a [`Weak`] reference to
//! it, so a vanished connection never blocks the publish path and is
//! pruned on the next notify sweep. The readiness loop drains mailboxes
//! into per-connection out-buffers and writes lines tagged
//! `{"v":2,"sub":<id>,"notify":{...}}` — push frames exist only in wire
//! protocol v2, where responses already carry ids and may interleave.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::coordinator::serving::RankSnapshot;
use crate::graph::VertexId;
use crate::util::json::Json;

/// Per-connection notification queue depth. A subscriber that stops
/// reading keeps only `MAX_MAILBOX_DEPTH` queued frames: an overflowing
/// push first tries to *merge* with the newest queued frame of the same
/// subscription (composing the diffs so no transition is silently
/// lost — see [`Mailbox::push_frame`]) and only evicts the oldest frame
/// when no merge is possible. Never grows without bound, never
/// back-pressures the publish path.
pub const MAX_MAILBOX_DEPTH: usize = 1024;

/// One queued notification: which subscription fired and what it saw.
/// Frames stay structured in the queue (rendered to JSON only at drain
/// time) so an overflowing mailbox can merge them semantically.
struct Frame {
    sub: u64,
    note: Notification,
}

/// What happened to a pushed frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// Queued normally (mailbox had room).
    Queued,
    /// Mailbox was full; the frame was composed into (or cancelled
    /// against) the newest queued frame of the same subscription.
    Merged,
    /// Mailbox was full and no same-subscription frame could absorb it;
    /// the oldest queued frame was evicted.
    Dropped,
}

/// A bounded queue of notification frames, shared between the publish
/// path (producer) and one wire connection's readiness loop (consumer).
pub struct Mailbox {
    inner: Mutex<MailboxInner>,
}

struct MailboxInner {
    queue: VecDeque<Frame>,
    dropped: u64,
    merged: u64,
}

impl Mailbox {
    /// A fresh mailbox. Returns an `Arc` because the registry keeps a
    /// `Weak` handle to the same allocation.
    #[allow(clippy::new_without_default)]
    pub fn new() -> Arc<Mailbox> {
        Arc::new(Mailbox {
            inner: Mutex::new(MailboxInner { queue: VecDeque::new(), dropped: 0, merged: 0 }),
        })
    }

    /// Enqueue one notification frame. Below the depth cap this just
    /// queues. At the cap, the newest queued frame of the same
    /// subscription absorbs it — top-K diffs compose set-algebraically,
    /// an up-crossing cancels a queued down-crossing, and so on — so a
    /// slow reader sees one *net* transition instead of losing an
    /// arbitrary prefix. Only when no same-subscription frame exists is
    /// the oldest frame evicted.
    pub fn push_frame(&self, sub: u64, note: Notification) -> PushOutcome {
        let mut g = self.inner.lock().unwrap();
        if g.queue.len() >= MAX_MAILBOX_DEPTH {
            if let Some(pos) = g.queue.iter().rposition(|f| f.sub == sub) {
                match compose(&g.queue[pos].note, &note) {
                    Compose::Merged(m) => {
                        g.queue[pos].note = m;
                        g.merged += 1;
                        return PushOutcome::Merged;
                    }
                    Compose::Cancelled => {
                        // The two transitions undo each other: the
                        // reader should see nothing at all.
                        g.queue.remove(pos);
                        g.merged += 1;
                        return PushOutcome::Merged;
                    }
                    Compose::Incompatible => {}
                }
            }
            g.queue.pop_front();
            g.dropped += 1;
            g.queue.push_back(Frame { sub, note });
            return PushOutcome::Dropped;
        }
        g.queue.push_back(Frame { sub, note });
        PushOutcome::Queued
    }

    /// Take every queued notification as rendered push frames, oldest
    /// first.
    pub fn drain(&self) -> Vec<Json> {
        let mut g = self.inner.lock().unwrap();
        g.queue.drain(..).map(|f| f.note.to_json(f.sub)).collect()
    }

    /// Queued (undelivered) notifications.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Notifications evicted because the consumer fell behind and no
    /// merge was possible.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Overflow pushes absorbed by merging instead of dropping.
    pub fn merged(&self) -> u64 {
        self.inner.lock().unwrap().merged
    }
}

/// Result of composing two notifications of the same subscription.
enum Compose {
    /// Different kinds/parameters — cannot be combined.
    Incompatible,
    /// The newer transition exactly undoes the queued one.
    Cancelled,
    /// One notification carrying the net effect of both.
    Merged(Notification),
}

/// Compose `older` (already queued) with `newer` (arriving) into the
/// net transition a reader catching up now should observe. For top-K,
/// with sets S0 → S1 → S2 and diffs (e1,l1), (e2,l2):
/// net-entered = (e1 \ l2) ∪ (e2 \ l1) and net-left = (l1 \ e2) ∪
/// (l2 \ e1); both empty means the set returned to where it started.
fn compose(older: &Notification, newer: &Notification) -> Compose {
    match (older, newer) {
        (
            Notification::TopK { k: k1, entered: e1, left: l1, .. },
            Notification::TopK { k: k2, version, entered: e2, left: l2 },
        ) if k1 == k2 => {
            let mut entered: Vec<VertexId> =
                e1.iter().copied().filter(|v| !l2.contains(v)).collect();
            entered.extend(e2.iter().copied().filter(|v| !l1.contains(v) && !entered.contains(v)));
            let mut left: Vec<VertexId> = l1.iter().copied().filter(|v| !e2.contains(v)).collect();
            left.extend(l2.iter().copied().filter(|v| !e1.contains(v) && !left.contains(v)));
            if entered.is_empty() && left.is_empty() {
                Compose::Cancelled
            } else {
                Compose::Merged(Notification::TopK {
                    k: *k1,
                    version: *version,
                    entered,
                    left,
                })
            }
        }
        (
            Notification::RankThreshold { id: i1, tau: t1, up: u1, .. },
            Notification::RankThreshold { id: i2, tau: t2, up: u2, .. },
        ) if i1 == i2 && t1 == t2 => {
            if u1 != u2 {
                Compose::Cancelled // crossed and crossed back
            } else {
                Compose::Merged(newer.clone())
            }
        }
        (
            Notification::HotSet { id: i1, entered: in1, .. },
            Notification::HotSet { id: i2, entered: in2, .. },
        ) if i1 == i2 => {
            if in1 != in2 {
                Compose::Cancelled // entered then left (or vice versa)
            } else {
                Compose::Merged(newer.clone())
            }
        }
        (Notification::Community { id: i1, .. }, Notification::Community { id: i2, .. })
            if i1 == i2 =>
        {
            // Labels supersede: only the newest assignment matters.
            Compose::Merged(newer.clone())
        }
        _ => Compose::Incompatible,
    }
}

/// What a standing query watches. Parsed from the wire `subscribe` op.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Subscription {
    /// Fire when the top-`k` vertex *set* changes between consecutive
    /// published snapshots (entries/exits, not internal reordering).
    TopK { k: usize },
    /// Fire when `id`'s rank crosses `tau` in either direction.
    RankThreshold { id: VertexId, tau: f64 },
    /// Fire when `id` enters or leaves the engine's hot set |K|.
    HotSet { id: VertexId },
    /// Fire when `id`'s community label changes (streaming label
    /// propagation; requires the server's `--communities` workload).
    Community { id: VertexId },
}

impl Subscription {
    /// Parse the wire shape: `{"op":"subscribe","what":"topk","k":10}`,
    /// `{"what":"rank","id":7,"tau":0.002}`, `{"what":"hotset","id":7}`
    /// or `{"what":"community","id":7}`.
    pub fn parse(req: &Json) -> Result<Subscription, String> {
        let what = req.get("what").and_then(Json::as_str).unwrap_or("");
        match what {
            "topk" => {
                let k = req.get("k").and_then(Json::as_u64).unwrap_or(10) as usize;
                if k == 0 {
                    return Err("subscribe topk needs k >= 1".into());
                }
                Ok(Subscription::TopK { k })
            }
            "rank" => {
                let id = req
                    .get("id")
                    .and_then(Json::as_u64)
                    .ok_or("subscribe rank needs a numeric id")?;
                let tau = req
                    .get("tau")
                    .and_then(Json::as_f64)
                    .ok_or("subscribe rank needs a numeric tau")?;
                Ok(Subscription::RankThreshold { id, tau })
            }
            "hotset" => {
                let id = req
                    .get("id")
                    .and_then(Json::as_u64)
                    .ok_or("subscribe hotset needs a numeric id")?;
                Ok(Subscription::HotSet { id })
            }
            "community" => {
                let id = req
                    .get("id")
                    .and_then(Json::as_u64)
                    .ok_or("subscribe community needs a numeric id")?;
                Ok(Subscription::Community { id })
            }
            other => Err(format!(
                "unknown subscription {other:?} (expected topk, rank, hotset or community)"
            )),
        }
    }
}

/// A fired standing query, ready to render as a push frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Notification {
    /// The top-`k` set changed: `entered` in new-rank order, `left` in
    /// old-rank order.
    TopK { k: usize, version: u64, entered: Vec<VertexId>, left: Vec<VertexId> },
    /// `id`'s rank crossed `tau`; `up` is the crossing direction and
    /// `rank` the new value.
    RankThreshold { id: VertexId, tau: f64, rank: f64, up: bool, version: u64 },
    /// `id` entered (`entered == true`) or left the hot set.
    HotSet { id: VertexId, entered: bool, version: u64 },
    /// `id` moved to community `label`.
    Community { id: VertexId, label: u32, version: u64 },
}

impl Notification {
    /// The published-snapshot (or community query) version the event
    /// was observed at.
    pub fn version(&self) -> u64 {
        match self {
            Notification::TopK { version, .. }
            | Notification::RankThreshold { version, .. }
            | Notification::HotSet { version, .. }
            | Notification::Community { version, .. } => *version,
        }
    }

    /// Render the v2 push frame `{"v":2,"sub":N,"notify":{...}}`.
    pub fn to_json(&self, sub: u64) -> Json {
        let ids = |xs: &[VertexId]| Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect());
        let body = match self {
            Notification::TopK { k, version, entered, left } => Json::obj(vec![
                ("kind", Json::Str("topk".into())),
                ("k", Json::Num(*k as f64)),
                ("version", Json::Num(*version as f64)),
                ("entered", ids(entered)),
                ("left", ids(left)),
            ]),
            Notification::RankThreshold { id, tau, rank, up, version } => Json::obj(vec![
                ("kind", Json::Str("rank".into())),
                ("id", Json::Num(*id as f64)),
                ("tau", Json::Num(*tau)),
                ("rank", Json::Num(*rank)),
                ("direction", Json::Str(if *up { "up" } else { "down" }.into())),
                ("version", Json::Num(*version as f64)),
            ]),
            Notification::HotSet { id, entered, version } => Json::obj(vec![
                ("kind", Json::Str("hotset".into())),
                ("id", Json::Num(*id as f64)),
                ("event", Json::Str(if *entered { "entered" } else { "left" }.into())),
                ("version", Json::Num(*version as f64)),
            ]),
            Notification::Community { id, label, version } => Json::obj(vec![
                ("kind", Json::Str("community".into())),
                ("id", Json::Num(*id as f64)),
                ("label", Json::Num(*label as f64)),
                ("version", Json::Num(*version as f64)),
            ]),
        };
        Json::obj(vec![
            ("v", Json::Num(2.0)),
            ("sub", Json::Num(sub as f64)),
            ("notify", body),
        ])
    }
}

/// Diff one snapshot-driven subscription between two consecutive
/// published snapshots. Pure — the property tests compare this against
/// brute-force recomputation. `Community` subscriptions are not
/// snapshot-driven and never fire here.
pub fn diff(spec: &Subscription, prev: &RankSnapshot, next: &RankSnapshot) -> Option<Notification> {
    match *spec {
        Subscription::TopK { k } => {
            let before = prev.top_ids(k);
            let after = next.top_ids(k);
            let entered: Vec<VertexId> =
                after.iter().copied().filter(|v| !before.contains(v)).collect();
            let left: Vec<VertexId> =
                before.iter().copied().filter(|v| !after.contains(v)).collect();
            if entered.is_empty() && left.is_empty() {
                None
            } else {
                Some(Notification::TopK { k, version: next.version, entered, left })
            }
        }
        Subscription::RankThreshold { id, tau } => {
            let was_above = prev.rank_of(id).unwrap_or(0.0) > tau;
            let rank = next.rank_of(id).unwrap_or(0.0);
            let is_above = rank > tau;
            if was_above == is_above {
                None
            } else {
                Some(Notification::RankThreshold {
                    id,
                    tau,
                    rank,
                    up: is_above,
                    version: next.version,
                })
            }
        }
        Subscription::HotSet { id } => {
            let was_hot = prev.is_hot(id);
            let is_hot = next.is_hot(id);
            if was_hot == is_hot {
                None
            } else {
                Some(Notification::HotSet { id, entered: is_hot, version: next.version })
            }
        }
        Subscription::Community { .. } => None,
    }
}

/// The observed condition of one subscription at a known version — the
/// piece of state that must survive a restart for a reconnecting
/// client to receive the diff it missed instead of starting blind.
#[derive(Clone, Debug, PartialEq)]
pub enum SubState {
    /// The top-K member set as last notified.
    TopK(Vec<VertexId>),
    /// Whether the watched rank was above τ.
    Above(bool),
    /// Whether the watched vertex was hot.
    Hot(bool),
    /// The watched vertex's last known community label (None until the
    /// first label event — community state is event-driven, so replay
    /// across restarts is best-effort).
    Label(Option<u32>),
}

/// One durable subscription: `(client token, spec, observed state,
/// last notified version)`. These are checkpointed and restored, so a
/// v2 client that reconnects after a server restart and re-subscribes
/// with the same token picks up exactly where it left off.
#[derive(Clone, Debug, PartialEq)]
pub struct DurableSubRecord {
    /// Client-chosen identity, stable across connections.
    pub token: String,
    /// What the subscription watches.
    pub spec: Subscription,
    /// The condition as of the last notification (or registration).
    pub state: SubState,
    /// Snapshot version the state was observed at.
    pub last_version: u64,
}

/// Observe a subscription's current condition against a snapshot (the
/// state a fresh durable record starts from).
pub fn observe(spec: &Subscription, snap: &RankSnapshot) -> SubState {
    match *spec {
        Subscription::TopK { k } => SubState::TopK(snap.top_ids(k)),
        Subscription::RankThreshold { id, tau } => {
            SubState::Above(snap.rank_of(id).unwrap_or(0.0) > tau)
        }
        Subscription::HotSet { id } => SubState::Hot(snap.is_hot(id)),
        Subscription::Community { .. } => SubState::Label(None),
    }
}

/// Diff a checkpointed [`SubState`] against the current snapshot: the
/// notification a re-subscribing client *missed* while away, or `None`
/// if the condition is unchanged. The same transition rules as
/// [`diff`], but anchored at recorded state instead of the previous
/// snapshot.
pub fn diff_from_state(
    state: &SubState,
    spec: &Subscription,
    snap: &RankSnapshot,
) -> Option<Notification> {
    match (state, *spec) {
        (SubState::TopK(before), Subscription::TopK { k }) => {
            let after = snap.top_ids(k);
            let entered: Vec<VertexId> =
                after.iter().copied().filter(|v| !before.contains(v)).collect();
            let left: Vec<VertexId> =
                before.iter().copied().filter(|v| !after.contains(v)).collect();
            if entered.is_empty() && left.is_empty() {
                None
            } else {
                Some(Notification::TopK { k, version: snap.version, entered, left })
            }
        }
        (SubState::Above(was), Subscription::RankThreshold { id, tau }) => {
            let rank = snap.rank_of(id).unwrap_or(0.0);
            let is_above = rank > tau;
            if is_above == *was {
                None
            } else {
                Some(Notification::RankThreshold {
                    id,
                    tau,
                    rank,
                    up: is_above,
                    version: snap.version,
                })
            }
        }
        (SubState::Hot(was), Subscription::HotSet { id }) => {
            let is_hot = snap.is_hot(id);
            if is_hot == *was {
                None
            } else {
                Some(Notification::HotSet { id, entered: is_hot, version: snap.version })
            }
        }
        // Community labels are event-driven (no snapshot to compare
        // against); a reconnecting client hears the next relabel.
        _ => None,
    }
}

struct ActiveSub {
    id: u64,
    spec: Subscription,
    mailbox: Weak<Mailbox>,
    /// Present when the subscription is durable: the key into the
    /// durable-record map kept in step with every fired notification.
    token: Option<String>,
}

/// All live standing queries, shared between the publish path (which
/// evaluates them) and the wire server (which registers them and drains
/// the mailboxes). One registry per engine, owned by the serving
/// `Shared` state so every `SnapshotReader` clone sees the same one.
#[derive(Default)]
pub struct SubscriptionRegistry {
    subs: Mutex<Vec<ActiveSub>>,
    next_id: AtomicU64,
    /// Live count mirrored outside the lock so the publish fast path
    /// (no subscribers — the overwhelmingly common case) is one load.
    live: AtomicUsize,
    sent: AtomicU64,
    dropped: AtomicU64,
    merged: AtomicU64,
    /// Durable records by client token — checkpointed, restored on
    /// recovery, kept in step with every fired notification. Locked
    /// strictly *after* (never inside) `subs`.
    durable: Mutex<HashMap<String, DurableSubRecord>>,
    /// Per-subscription `(dropped, merged)` overflow counters, exposed
    /// over the wire `stats` so a slow consumer can see which of its
    /// subscriptions are losing or coalescing frames.
    delivery: Mutex<HashMap<u64, (u64, u64)>>,
}

impl SubscriptionRegistry {
    /// Register a standing query delivering into `mailbox`; returns the
    /// subscription id echoed in every push frame.
    pub fn subscribe(&self, spec: Subscription, mailbox: &Arc<Mailbox>) -> u64 {
        self.register(spec, mailbox, None)
    }

    /// Register a *durable* standing query identified by a
    /// client-chosen token. If a checkpointed/previous record exists
    /// for the token with the same spec, the notification the client
    /// missed while disconnected (recorded state vs. `snap`) is pushed
    /// into the mailbox immediately. Returns `(sub id, replayed)`.
    pub fn subscribe_durable(
        &self,
        spec: Subscription,
        mailbox: &Arc<Mailbox>,
        token: &str,
        snap: &RankSnapshot,
    ) -> (u64, bool) {
        let missed = {
            let mut durable = self.durable.lock().unwrap();
            let missed = match durable.get(token) {
                Some(rec) if rec.spec == spec => diff_from_state(&rec.state, &spec, snap),
                _ => None,
            };
            durable.insert(
                token.to_string(),
                DurableSubRecord {
                    token: token.to_string(),
                    spec,
                    state: observe(&spec, snap),
                    last_version: snap.version,
                },
            );
            missed
        };
        let id = self.register(spec, mailbox, Some(token.to_string()));
        let replayed = missed.is_some();
        if let Some(event) = missed {
            self.deliver(mailbox, id, event);
        }
        (id, replayed)
    }

    fn register(&self, spec: Subscription, mailbox: &Arc<Mailbox>, token: Option<String>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst) + 1;
        let mut g = self.subs.lock().unwrap();
        g.push(ActiveSub { id, spec, mailbox: Arc::downgrade(mailbox), token });
        self.live.store(g.len(), Ordering::SeqCst);
        id
    }

    /// Push one frame and account for the outcome (global + per-sub).
    fn deliver(&self, mailbox: &Mailbox, id: u64, event: Notification) {
        match mailbox.push_frame(id, event) {
            PushOutcome::Queued => {}
            PushOutcome::Merged => {
                self.merged.fetch_add(1, Ordering::SeqCst);
                self.delivery.lock().unwrap().entry(id).or_insert((0, 0)).1 += 1;
            }
            PushOutcome::Dropped => {
                self.dropped.fetch_add(1, Ordering::SeqCst);
                self.delivery.lock().unwrap().entry(id).or_insert((0, 0)).0 += 1;
            }
        }
        self.sent.fetch_add(1, Ordering::SeqCst);
    }

    /// Drop a subscription; `false` if the id was unknown. Explicitly
    /// unsubscribing a durable subscription also forgets its record —
    /// the client said it is no longer interested (a *disconnect*, by
    /// contrast, keeps the record for later re-subscribe).
    pub fn unsubscribe(&self, id: u64) -> bool {
        let (removed, token) = {
            let mut g = self.subs.lock().unwrap();
            let before = g.len();
            let token = g.iter().find(|s| s.id == id).and_then(|s| s.token.clone());
            g.retain(|s| s.id != id);
            let removed = g.len() != before;
            self.live.store(g.len(), Ordering::SeqCst);
            (removed, token)
        };
        if let Some(token) = token {
            self.durable.lock().unwrap().remove(&token);
        }
        if removed {
            self.delivery.lock().unwrap().remove(&id);
        }
        removed
    }

    /// Detach a subscription whose connection closed. Unlike
    /// [`Self::unsubscribe`], a durable subscription's record survives:
    /// the client can re-subscribe under its token and replay what it
    /// missed.
    pub fn disconnect(&self, id: u64) -> bool {
        let removed = {
            let mut g = self.subs.lock().unwrap();
            let before = g.len();
            g.retain(|s| s.id != id);
            let removed = g.len() != before;
            self.live.store(g.len(), Ordering::SeqCst);
            removed
        };
        if removed {
            self.delivery.lock().unwrap().remove(&id);
        }
        removed
    }

    /// Snapshot every durable record (for checkpointing), sorted by
    /// token for deterministic bytes.
    pub fn durable_records(&self) -> Vec<DurableSubRecord> {
        let g = self.durable.lock().unwrap();
        let mut out: Vec<DurableSubRecord> = g.values().cloned().collect();
        out.sort_by(|a, b| a.token.cmp(&b.token));
        out
    }

    /// Restore checkpointed durable records (recovery path; runs before
    /// any client connects).
    pub fn restore_durable(&self, records: Vec<DurableSubRecord>) {
        let mut g = self.durable.lock().unwrap();
        for rec in records {
            g.insert(rec.token.clone(), rec);
        }
    }

    /// Durable records currently held (live or awaiting re-subscribe).
    pub fn durable_len(&self) -> usize {
        self.durable.lock().unwrap().len()
    }

    /// Live subscriptions (including ones whose connection has vanished
    /// but has not been pruned yet).
    pub fn len(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// True when nothing is subscribed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total notifications enqueued since startup.
    pub fn notifications_sent(&self) -> u64 {
        self.sent.load(Ordering::SeqCst)
    }

    /// Notifications evicted from full mailboxes (slow consumers).
    pub fn notifications_dropped(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }

    /// Overflow notifications absorbed by merging frames.
    pub fn notifications_merged(&self) -> u64 {
        self.merged.load(Ordering::SeqCst)
    }

    /// Per-subscription overflow counters as a wire `stats` object:
    /// `{"<sub id>": {"dropped": d, "merged": m}, ...}` (only
    /// subscriptions that overflowed at least once appear).
    pub fn delivery_counters_json(&self) -> Json {
        let g = self.delivery.lock().unwrap();
        let mut map = std::collections::BTreeMap::new();
        for (&id, &(dropped, merged)) in g.iter() {
            map.insert(
                id.to_string(),
                Json::obj(vec![
                    ("dropped", Json::Num(dropped as f64)),
                    ("merged", Json::Num(merged as f64)),
                ]),
            );
        }
        Json::Obj(map)
    }

    /// Whether any community-change subscription is live — the server
    /// skips the label-propagation refresh entirely when none is.
    pub fn has_community_subs(&self) -> bool {
        if self.is_empty() {
            return false;
        }
        let g = self.subs.lock().unwrap();
        g.iter().any(|s| matches!(s.spec, Subscription::Community { .. }))
    }

    /// Evaluate every snapshot-driven subscription against a publish
    /// transition. Runs on the engine thread right after the new
    /// snapshot is swapped in; cost is O(subs · K log n), zero when no
    /// one is subscribed.
    pub fn notify_publish(&self, prev: &RankSnapshot, next: &RankSnapshot) {
        if self.live.load(Ordering::SeqCst) == 0 {
            return;
        }
        // (token, new state) pairs for durable records, applied after
        // the subs lock drops (lock order: subs, then durable).
        let mut durable_updates: Vec<(String, SubState)> = Vec::new();
        {
            let mut g = self.subs.lock().unwrap();
            g.retain(|s| {
                let Some(mb) = s.mailbox.upgrade() else { return false };
                if let Some(event) = diff(&s.spec, prev, next) {
                    if let Some(token) = &s.token {
                        durable_updates.push((token.clone(), observe(&s.spec, next)));
                    }
                    self.deliver(&mb, s.id, event);
                }
                true
            });
            self.live.store(g.len(), Ordering::SeqCst);
        }
        if !durable_updates.is_empty() {
            let mut durable = self.durable.lock().unwrap();
            for (token, state) in durable_updates {
                if let Some(rec) = durable.get_mut(&token) {
                    rec.state = state;
                    rec.last_version = next.version;
                }
            }
        }
    }

    /// Evaluate community-change subscriptions after a label-propagation
    /// refresh. `labels(id)` returns the (previous, current) label of a
    /// vertex; an event fires when both exist and differ, or when the
    /// vertex gained its first label.
    pub fn notify_community(
        &self,
        version: u64,
        labels: impl Fn(VertexId) -> (Option<u32>, Option<u32>),
    ) {
        if self.live.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut durable_updates: Vec<(String, SubState)> = Vec::new();
        {
            let mut g = self.subs.lock().unwrap();
            g.retain(|s| {
                let Some(mb) = s.mailbox.upgrade() else { return false };
                if let Subscription::Community { id } = s.spec {
                    let (before, now) = labels(id);
                    if let Some(label) = now {
                        if before != now {
                            if let Some(token) = &s.token {
                                durable_updates
                                    .push((token.clone(), SubState::Label(Some(label))));
                            }
                            self.deliver(&mb, s.id, Notification::Community { id, label, version });
                        }
                    }
                }
                true
            });
            self.live.store(g.len(), Ordering::SeqCst);
        }
        if !durable_updates.is_empty() {
            let mut durable = self.durable.lock().unwrap();
            for (token, state) in durable_updates {
                if let Some(rec) = durable.get_mut(&token) {
                    rec.state = state;
                    rec.last_version = version;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::udf::{Action, ExecStats};

    fn snap(version: u64, ids: Vec<u64>, ranks: Vec<f64>, hot: Vec<u64>) -> RankSnapshot {
        let mut s = RankSnapshot::new(
            version,
            version,
            version,
            Action::ComputeExact,
            ExecStats::default(),
            ids,
            ranks,
            8,
            Json::Null,
        );
        s.set_hot_set(hot);
        s
    }

    #[test]
    fn topk_diff_reports_entries_and_exits() {
        let a = snap(1, vec![0, 1, 2, 3], vec![0.4, 0.3, 0.2, 0.1], vec![]);
        let b = snap(2, vec![0, 1, 2, 3], vec![0.1, 0.3, 0.2, 0.4], vec![]);
        let got = diff(&Subscription::TopK { k: 2 }, &a, &b).unwrap();
        assert_eq!(
            got,
            Notification::TopK { k: 2, version: 2, entered: vec![3], left: vec![0] }
        );
        assert!(diff(&Subscription::TopK { k: 4 }, &a, &b).is_none());
    }

    #[test]
    fn threshold_fires_on_crossings_only() {
        let a = snap(1, vec![0, 1], vec![0.1, 0.9], vec![]);
        let b = snap(2, vec![0, 1], vec![0.6, 0.9], vec![]);
        let spec = Subscription::RankThreshold { id: 0, tau: 0.5 };
        let got = diff(&spec, &a, &b).unwrap();
        assert_eq!(
            got,
            Notification::RankThreshold { id: 0, tau: 0.5, rank: 0.6, up: true, version: 2 }
        );
        // No crossing: both sides above.
        assert!(diff(&spec, &b, &b).is_none());
        // Unknown vertex counts as rank 0 (below any positive tau).
        assert!(diff(&Subscription::RankThreshold { id: 9, tau: 0.5 }, &a, &b).is_none());
    }

    #[test]
    fn hot_set_diff_uses_published_membership() {
        let a = snap(1, vec![0, 1], vec![0.5, 0.5], vec![1]);
        let b = snap(2, vec![0, 1], vec![0.5, 0.5], vec![0]);
        assert_eq!(
            diff(&Subscription::HotSet { id: 0 }, &a, &b).unwrap(),
            Notification::HotSet { id: 0, entered: true, version: 2 }
        );
        assert_eq!(
            diff(&Subscription::HotSet { id: 1 }, &a, &b).unwrap(),
            Notification::HotSet { id: 1, entered: false, version: 2 }
        );
    }

    #[test]
    fn registry_routes_to_mailboxes_and_prunes_dead_ones() {
        let reg = SubscriptionRegistry::default();
        let mb = Mailbox::new();
        let sub = reg.subscribe(Subscription::TopK { k: 1 }, &mb);
        let gone = Mailbox::new();
        reg.subscribe(Subscription::TopK { k: 1 }, &gone);
        drop(gone);
        assert_eq!(reg.len(), 2);

        let a = snap(1, vec![0, 1], vec![0.9, 0.1], vec![]);
        let b = snap(2, vec![0, 1], vec![0.1, 0.9], vec![]);
        reg.notify_publish(&a, &b);
        // Dead mailbox pruned, live one got exactly one frame.
        assert_eq!(reg.len(), 1);
        let lines = mb.drain();
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].get("sub").and_then(Json::as_u64), Some(sub));
        assert_eq!(lines[0].get("v").and_then(Json::as_u64), Some(2));
        let body = lines[0].get("notify").unwrap();
        assert_eq!(body.get("kind").and_then(Json::as_str), Some("topk"));
        assert_eq!(reg.notifications_sent(), 1);

        assert!(reg.unsubscribe(sub));
        assert!(!reg.unsubscribe(sub));
        assert!(reg.is_empty());
    }

    fn hot_note(sub_version: u64, entered: bool) -> Notification {
        Notification::HotSet { id: 1, entered, version: sub_version }
    }

    #[test]
    fn mailbox_merges_same_sub_frames_at_depth() {
        let mb = Mailbox::new();
        // Fill to the cap with distinct-sub top-K frames.
        for i in 0..MAX_MAILBOX_DEPTH as u64 {
            assert_eq!(
                mb.push_frame(
                    i,
                    Notification::TopK { k: 2, version: i, entered: vec![i], left: vec![] }
                ),
                PushOutcome::Queued
            );
        }
        // Overflow push for sub 5 composes with its queued frame
        // instead of evicting sub 0's.
        let out = mb.push_frame(
            5,
            Notification::TopK { k: 2, version: 99, entered: vec![77], left: vec![5] },
        );
        assert_eq!(out, PushOutcome::Merged);
        assert_eq!(mb.len(), MAX_MAILBOX_DEPTH);
        assert_eq!(mb.merged(), 1);
        assert_eq!(mb.dropped(), 0);
        let lines = mb.drain();
        assert_eq!(lines.len(), MAX_MAILBOX_DEPTH, "nothing lost");
        let sub5 = lines
            .iter()
            .find(|l| l.get("sub").and_then(Json::as_u64) == Some(5))
            .unwrap()
            .get("notify")
            .unwrap()
            .clone();
        // Net diff: entered {5} then {entered 77, left 5} ⇒ entered 77.
        assert_eq!(sub5.get("entered").unwrap().to_string_compact(), "[77]");
        assert_eq!(sub5.get("left").unwrap().to_string_compact(), "[]");
        assert_eq!(sub5.get("version").and_then(Json::as_u64), Some(99));
    }

    #[test]
    fn mailbox_cancels_round_trip_transitions_at_depth() {
        let mb = Mailbox::new();
        for i in 0..MAX_MAILBOX_DEPTH as u64 {
            mb.push_frame(i, hot_note(i, true));
        }
        // Sub 9's queued "entered" is exactly undone by "left".
        assert_eq!(mb.push_frame(9, hot_note(100, false)), PushOutcome::Merged);
        assert_eq!(mb.len(), MAX_MAILBOX_DEPTH - 1, "cancelled pair removed entirely");
        assert!(mb.drain().iter().all(|l| l.get("sub").and_then(Json::as_u64) != Some(9)));
    }

    #[test]
    fn mailbox_falls_back_to_evicting_oldest() {
        let mb = Mailbox::new();
        for i in 0..MAX_MAILBOX_DEPTH as u64 {
            mb.push_frame(i, hot_note(i, true));
        }
        // A brand-new sub has nothing to merge with: oldest evicted.
        let out = mb.push_frame(u64::MAX, hot_note(200, true));
        assert_eq!(out, PushOutcome::Dropped);
        assert_eq!(mb.len(), MAX_MAILBOX_DEPTH);
        assert_eq!(mb.dropped(), 1);
        let lines = mb.drain();
        assert_eq!(lines[0].get("sub").and_then(Json::as_u64), Some(1), "sub 0 evicted");
    }

    #[test]
    fn durable_subscribe_replays_the_missed_diff() {
        let reg = SubscriptionRegistry::default();
        let mb = Mailbox::new();
        let a = snap(1, vec![0, 1], vec![0.9, 0.1], vec![]);
        let (sub, replayed) =
            reg.subscribe_durable(Subscription::TopK { k: 1 }, &mb, "client-7", &a);
        assert!(!replayed, "fresh token has nothing to replay");
        assert_eq!(reg.durable_len(), 1);

        // Notify fires and keeps the durable record current.
        let b = snap(2, vec![0, 1], vec![0.1, 0.9], vec![]);
        reg.notify_publish(&a, &b);
        assert_eq!(mb.drain().len(), 1);
        let records = reg.durable_records();
        assert_eq!(records[0].state, SubState::TopK(vec![1]));
        assert_eq!(records[0].last_version, 2);

        // Simulate disconnect + restart: a fresh registry restored from
        // the checkpointed records.
        let reg2 = SubscriptionRegistry::default();
        reg2.restore_durable(records);
        // The world moved on while the client was away.
        let c = snap(5, vec![0, 1], vec![0.8, 0.2], vec![]);
        let mb2 = Mailbox::new();
        let (_, replayed) =
            reg2.subscribe_durable(Subscription::TopK { k: 1 }, &mb2, "client-7", &c);
        assert!(replayed);
        let lines = mb2.drain();
        assert_eq!(lines.len(), 1, "missed diff delivered immediately");
        let body = lines[0].get("notify").unwrap();
        assert_eq!(body.get("entered").unwrap().to_string_compact(), "[0]");
        assert_eq!(body.get("left").unwrap().to_string_compact(), "[1]");

        // A changed spec under the same token does NOT replay.
        let mb3 = Mailbox::new();
        let (_, replayed) =
            reg2.subscribe_durable(Subscription::TopK { k: 2 }, &mb3, "client-7", &c);
        assert!(!replayed);
        assert!(mb3.is_empty());

        // Explicit unsubscribe forgets the durable record.
        assert!(reg.unsubscribe(sub));
        assert_eq!(reg.durable_len(), 0);
    }

    #[test]
    fn diff_from_state_matches_diff_semantics() {
        let a = snap(1, vec![0, 1], vec![0.1, 0.9], vec![1]);
        let b = snap(2, vec![0, 1], vec![0.6, 0.9], vec![0]);
        let spec = Subscription::RankThreshold { id: 0, tau: 0.5 };
        assert_eq!(diff_from_state(&observe(&spec, &a), &spec, &b), diff(&spec, &a, &b));
        let spec = Subscription::HotSet { id: 0 };
        assert_eq!(diff_from_state(&observe(&spec, &a), &spec, &b), diff(&spec, &a, &b));
        let spec = Subscription::TopK { k: 1 };
        assert_eq!(diff_from_state(&observe(&spec, &a), &spec, &b), diff(&spec, &a, &b));
        // Unchanged state replays nothing.
        assert_eq!(diff_from_state(&observe(&spec, &b), &spec, &b), None);
    }

    #[test]
    fn community_notifications_fire_on_label_changes() {
        let reg = SubscriptionRegistry::default();
        let mb = Mailbox::new();
        let sub = reg.subscribe(Subscription::Community { id: 4 }, &mb);
        assert!(reg.has_community_subs());
        reg.notify_community(7, |id| if id == 4 { (Some(1), Some(2)) } else { (None, None) });
        reg.notify_community(8, |_| (Some(2), Some(2)));
        let lines = mb.drain();
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].get("sub").and_then(Json::as_u64), Some(sub));
        let body = lines[0].get("notify").unwrap();
        assert_eq!(body.get("kind").and_then(Json::as_str), Some("community"));
        assert_eq!(body.get("label").and_then(Json::as_u64), Some(2));
        assert_eq!(body.get("version").and_then(Json::as_u64), Some(7));
    }

    #[test]
    fn parse_covers_every_subscription_shape() {
        let p = |s: &str| Subscription::parse(&Json::parse(s).unwrap());
        assert_eq!(p(r#"{"what":"topk","k":3}"#), Ok(Subscription::TopK { k: 3 }));
        assert_eq!(p(r#"{"what":"topk"}"#), Ok(Subscription::TopK { k: 10 }));
        assert_eq!(
            p(r#"{"what":"rank","id":7,"tau":0.25}"#),
            Ok(Subscription::RankThreshold { id: 7, tau: 0.25 })
        );
        assert_eq!(p(r#"{"what":"hotset","id":7}"#), Ok(Subscription::HotSet { id: 7 }));
        assert_eq!(p(r#"{"what":"community","id":7}"#), Ok(Subscription::Community { id: 7 }));
        assert!(p(r#"{"what":"rank","id":7}"#).is_err());
        assert!(p(r#"{"what":"nope"}"#).is_err());
        assert!(p(r#"{"op":"subscribe"}"#).is_err());
    }
}
