//! Write-ahead log of effective update batches.
//!
//! Every coalesced [`UpdateBatch`](crate::stream::buffer::UpdateBatch)
//! leaving the PR-5 coalescer is appended here *before*
//! `apply_batch` mutates the graph, so a crash between the append and
//! the apply loses nothing: recovery replays the record through the
//! ordinary batch path and lands on the identical state (the coalescer
//! emits replay-exact effective ops — that property, tested since PR 5,
//! is what makes the WAL unit a batch rather than a raw op).
//!
//! ## On-disk format (little-endian)
//!
//! The log is a sequence of segment files `wal-<first_seq>.log`:
//!
//! ```text
//! segment header:  magic "VGWL" | u32 format version | u64 first_seq
//! record:          u32 payload_len | u64 seq | payload | u64 fnv1a-64
//! payload:         u32 n_ops | n_ops × (u8 tag, u64 a, u64 b)
//! ```
//!
//! The checksum covers the record from `payload_len` through the
//! payload, so a torn or truncated tail (short write, crash mid-append)
//! fails verification and [`Wal::scan`] discards it — everything before
//! the torn record replays normally. Sequence numbers are assigned
//! monotonically across segments; a new segment is started whenever the
//! current one exceeds the size cap, and on every open (an old torn
//! tail can therefore never interleave with fresh records).
//!
//! ## Sync policy
//!
//! `--durability none|batch|interval:MS` maps to [`SyncPolicy`]:
//! `none` never fsyncs (OS flush on close — fast, loses the OS cache on
//! power failure), `batch` fsyncs after every appended batch (each
//! acknowledged batch is durable), `interval:MS` fsyncs at most once
//! per interval (bounded loss window).
//!
//! ## Degradation
//!
//! Disks fail while servers run. After
//! [`MAX_CONSECUTIVE_FAILURES`] failed appends the WAL drops to
//! in-memory mode: appends become no-ops, the server keeps serving, and
//! the wire `stats.durability` section reports `durability_lost: true`
//! so operators notice. Losing durability is a monitoring event, not a
//! crash.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::stream::event::EdgeOp;
use crate::testing::faults::{CrashPoint, FaultInjector};
use crate::util::json::Json;

const MAGIC: &[u8; 4] = b"VGWL";
const FORMAT_VERSION: u32 = 1;

/// Consecutive append failures tolerated before the WAL degrades to
/// in-memory mode (a fresh success before the limit resets the count).
pub const MAX_CONSECUTIVE_FAILURES: u32 = 3;

/// Default segment rotation threshold.
pub const DEFAULT_SEGMENT_MAX_BYTES: u64 = 64 * 1024 * 1024;

/// When (if ever) appended records are fsynced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncPolicy {
    /// Never fsync; rely on the OS cache.
    None,
    /// Fsync after every appended batch.
    Batch,
    /// Fsync at most once per this many milliseconds.
    Interval(u64),
}

impl SyncPolicy {
    /// The wire/CLI spelling (`none` / `batch` / `interval:MS`).
    pub fn as_str(&self) -> String {
        match self {
            SyncPolicy::None => "none".into(),
            SyncPolicy::Batch => "batch".into(),
            SyncPolicy::Interval(ms) => format!("interval:{ms}"),
        }
    }
}

impl std::str::FromStr for SyncPolicy {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "none" => Ok(SyncPolicy::None),
            "batch" => Ok(SyncPolicy::Batch),
            other => match other.strip_prefix("interval:") {
                Some(ms) => match ms.parse::<u64>() {
                    Ok(ms) if ms > 0 => Ok(SyncPolicy::Interval(ms)),
                    _ => Err(format!("interval wants a positive millisecond count, got {ms:?}")),
                },
                None => Err(format!(
                    "unknown sync policy {other:?}; expected none, batch or interval:MS"
                )),
            },
        }
    }
}

/// The write side of one segment file. Split out as a trait so the
/// fault harness ([`crate::testing::faults::FaultyIo`]) can substitute
/// an implementation with injectable short writes / fsync failures /
/// disk-full.
pub trait SegmentWriter: Send {
    /// Append raw bytes (a faulty impl may land a prefix, then error).
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()>;
    /// Flush and fsync what has been written.
    fn sync(&mut self) -> io::Result<()>;
}

/// Creates segment writers. Production uses [`FsIo`].
pub trait WalIo: Send {
    /// Create (truncating) a new segment file at `path`.
    fn create_segment(&mut self, path: &Path) -> io::Result<Box<dyn SegmentWriter>>;
}

/// The real filesystem I/O layer.
pub struct FsIo;

impl WalIo for FsIo {
    fn create_segment(&mut self, path: &Path) -> io::Result<Box<dyn SegmentWriter>> {
        let file = std::fs::File::create(path)?;
        Ok(Box::new(FsSegment { w: io::BufWriter::new(file) }))
    }
}

struct FsSegment {
    w: io::BufWriter<std::fs::File>,
}

impl SegmentWriter for FsSegment {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        io::Write::write_all(&mut self.w, buf)
    }

    fn sync(&mut self) -> io::Result<()> {
        io::Write::flush(&mut self.w)?;
        self.w.get_ref().sync_data()
    }
}

/// One decoded WAL record: the batch's sequence number and its
/// effective ops, exactly as appended.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    pub seq: u64,
    pub ops: Vec<EdgeOp>,
}

/// Result of scanning a WAL directory on open/recovery.
#[derive(Clone, Debug, Default)]
pub struct WalScan {
    /// Every verified record, in sequence order.
    pub records: Vec<WalRecord>,
    /// The sequence number the next append should use.
    pub next_seq: u64,
    /// A torn/truncated tail was found (and discarded) in the newest
    /// segment.
    pub torn_tail_discarded: bool,
    /// A checksum failure in a *non*-newest segment cut the scan short
    /// (real corruption, not a crash artifact).
    pub corrupt_segment: bool,
}

/// Shared durability gauges: written by the WAL / checkpoint jobs on
/// their own threads, read lock-free by the wire `stats` path. One
/// instance per engine, present (with `enabled:false`) even when
/// durability is off so the stats section is always well-formed.
#[derive(Debug, Default)]
pub struct DurabilityStats {
    /// 0 = disabled, 1 = none, 2 = batch, 3 = interval.
    mode: AtomicU8,
    interval_ms: AtomicU64,
    wal_records: AtomicU64,
    wal_bytes: AtomicU64,
    wal_segments: AtomicU64,
    wal_seq: AtomicU64,
    wal_errors: AtomicU64,
    lost: AtomicBool,
    checkpoints_written: AtomicU64,
    checkpoint_failures: AtomicU64,
    last_checkpoint_seq: AtomicU64,
    replayed_batches: AtomicU64,
    replayed_ops: AtomicU64,
    recovered: AtomicBool,
    torn_tail_discarded: AtomicBool,
    snapshots_skipped: AtomicU64,
}

impl DurabilityStats {
    /// Fresh gauges, mode "disabled".
    pub fn new() -> Arc<DurabilityStats> {
        Arc::new(DurabilityStats::default())
    }

    /// Record the configured sync policy (flips `enabled` on).
    pub fn set_mode(&self, policy: SyncPolicy) {
        let (mode, ms) = match policy {
            SyncPolicy::None => (1, 0),
            SyncPolicy::Batch => (2, 0),
            SyncPolicy::Interval(ms) => (3, ms),
        };
        self.mode.store(mode, Ordering::Relaxed);
        self.interval_ms.store(ms, Ordering::Relaxed);
    }

    /// Whether durability was configured at all.
    pub fn enabled(&self) -> bool {
        self.mode.load(Ordering::Relaxed) != 0
    }

    /// Whether the WAL degraded to in-memory mode.
    pub fn durability_lost(&self) -> bool {
        self.lost.load(Ordering::Relaxed)
    }

    /// Record a recovery: how much the WAL tail replayed and what the
    /// snapshot search skipped.
    pub fn note_recovery(
        &self,
        replayed_batches: u64,
        replayed_ops: u64,
        torn_tail: bool,
        snapshots_skipped: u64,
    ) {
        self.recovered.store(true, Ordering::Relaxed);
        self.replayed_batches.store(replayed_batches, Ordering::Relaxed);
        self.replayed_ops.store(replayed_ops, Ordering::Relaxed);
        self.torn_tail_discarded.store(torn_tail, Ordering::Relaxed);
        self.snapshots_skipped.store(snapshots_skipped, Ordering::Relaxed);
    }

    /// Record a finished checkpoint attempt.
    pub fn note_checkpoint(&self, ok: bool, wal_seq: u64) {
        if ok {
            self.checkpoints_written.fetch_add(1, Ordering::Relaxed);
            self.last_checkpoint_seq.store(wal_seq, Ordering::Relaxed);
        } else {
            self.checkpoint_failures.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Last sequence number covered by a durable checkpoint.
    pub fn last_checkpoint_seq(&self) -> u64 {
        self.last_checkpoint_seq.load(Ordering::Relaxed)
    }

    /// Checkpoints successfully written this run.
    pub fn checkpoints_written(&self) -> u64 {
        self.checkpoints_written.load(Ordering::Relaxed)
    }

    /// The wire `stats.durability` section.
    pub fn to_json(&self) -> Json {
        let mode = self.mode.load(Ordering::Relaxed);
        let sync = match mode {
            0 => "off".to_string(),
            1 => "none".to_string(),
            2 => "batch".to_string(),
            _ => format!("interval:{}", self.interval_ms.load(Ordering::Relaxed)),
        };
        Json::obj(vec![
            ("enabled", Json::Bool(mode != 0)),
            ("sync", Json::Str(sync)),
            ("durability_lost", Json::Bool(self.lost.load(Ordering::Relaxed))),
            ("wal_records", Json::Num(self.wal_records.load(Ordering::Relaxed) as f64)),
            ("wal_bytes", Json::Num(self.wal_bytes.load(Ordering::Relaxed) as f64)),
            ("wal_segments", Json::Num(self.wal_segments.load(Ordering::Relaxed) as f64)),
            ("wal_seq", Json::Num(self.wal_seq.load(Ordering::Relaxed) as f64)),
            ("wal_errors", Json::Num(self.wal_errors.load(Ordering::Relaxed) as f64)),
            (
                "checkpoints_written",
                Json::Num(self.checkpoints_written.load(Ordering::Relaxed) as f64),
            ),
            (
                "checkpoint_failures",
                Json::Num(self.checkpoint_failures.load(Ordering::Relaxed) as f64),
            ),
            (
                "last_checkpoint_seq",
                Json::Num(self.last_checkpoint_seq.load(Ordering::Relaxed) as f64),
            ),
            ("recovered", Json::Bool(self.recovered.load(Ordering::Relaxed))),
            ("replayed_batches", Json::Num(self.replayed_batches.load(Ordering::Relaxed) as f64)),
            ("replayed_ops", Json::Num(self.replayed_ops.load(Ordering::Relaxed) as f64)),
            (
                "torn_tail_discarded",
                Json::Bool(self.torn_tail_discarded.load(Ordering::Relaxed)),
            ),
            ("snapshots_skipped", Json::Num(self.snapshots_skipped.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// The append side of the log.
pub struct Wal {
    dir: PathBuf,
    policy: SyncPolicy,
    io: Box<dyn WalIo>,
    seg: Option<Box<dyn SegmentWriter>>,
    seg_bytes: u64,
    seg_max_bytes: u64,
    next_seq: u64,
    last_sync: Instant,
    consecutive_failures: u32,
    lost: bool,
    stats: Arc<DurabilityStats>,
    faults: Option<Arc<FaultInjector>>,
}

impl Wal {
    /// Open the log for appending, starting at `start_seq` (recovery
    /// passes the scan's `next_seq`; a fresh log starts at 1). Always
    /// begins a new segment, so a previously torn tail can never
    /// interleave with fresh records.
    pub fn open(
        dir: impl Into<PathBuf>,
        start_seq: u64,
        policy: SyncPolicy,
        seg_max_bytes: u64,
        mut io: Box<dyn WalIo>,
        stats: Arc<DurabilityStats>,
        faults: Option<Arc<FaultInjector>>,
    ) -> Result<Wal> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let start_seq = start_seq.max(1);
        let seg = open_segment(&mut io, &dir, start_seq, &stats)?;
        stats.set_mode(policy);
        stats.wal_seq.store(start_seq - 1, Ordering::Relaxed);
        Ok(Wal {
            dir,
            policy,
            io,
            seg: Some(seg),
            seg_bytes: SEGMENT_HEADER_LEN as u64,
            seg_max_bytes: seg_max_bytes.max(SEGMENT_HEADER_LEN as u64 + 1),
            next_seq: start_seq,
            last_sync: Instant::now(),
            consecutive_failures: 0,
            lost: false,
            stats,
            faults,
        })
    }

    /// The sequence number the next append will be assigned.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Whether the log has degraded to in-memory mode.
    pub fn is_lost(&self) -> bool {
        self.lost
    }

    /// Append one effective batch, returning its sequence number. I/O
    /// failures are absorbed: they count toward degradation rather than
    /// erroring, so the write pipeline never stalls on a dying disk.
    /// The only `Err` this returns is an injected crash (tests).
    pub fn append_batch(&mut self, ops: &[EdgeOp]) -> Result<u64> {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.stats.wal_seq.store(seq, Ordering::Relaxed);
        if self.lost {
            return Ok(seq);
        }
        let record = encode_record(seq, ops);
        match self.write_record(seq, &record) {
            Ok(()) => {
                self.consecutive_failures = 0;
                self.stats.wal_records.fetch_add(1, Ordering::Relaxed);
                self.stats.wal_bytes.fetch_add(record.len() as u64, Ordering::Relaxed);
                if let Some(f) = &self.faults {
                    if f.take_crash(CrashPoint::PostWalAppend) {
                        return Err(Error::Engine(
                            "injected crash: post-wal-append".into(),
                        ));
                    }
                }
                Ok(seq)
            }
            Err(e) => {
                self.note_failure(&e);
                Ok(seq)
            }
        }
    }

    fn write_record(&mut self, seq: u64, record: &[u8]) -> io::Result<()> {
        if self.seg_bytes + record.len() as u64 > self.seg_max_bytes {
            self.rotate(seq)?;
        }
        let seg = self
            .seg
            .as_mut()
            .ok_or_else(|| io::Error::other("wal segment unavailable"))?;
        seg.write_all(record)?;
        self.seg_bytes += record.len() as u64;
        let due = match self.policy {
            SyncPolicy::None => false,
            SyncPolicy::Batch => true,
            SyncPolicy::Interval(ms) => self.last_sync.elapsed().as_millis() as u64 >= ms,
        };
        if due {
            seg.sync()?;
            self.last_sync = Instant::now();
        }
        Ok(())
    }

    fn rotate(&mut self, first_seq: u64) -> io::Result<()> {
        if let Some(seg) = self.seg.as_mut() {
            // Never leave a segment behind with unflushed user-space
            // buffers: rotation is a durability boundary.
            seg.sync()?;
        }
        let seg = open_segment(&mut self.io, &self.dir, first_seq, &self.stats)
            .map_err(|e| io::Error::other(e.to_string()))?;
        self.seg = Some(seg);
        self.seg_bytes = SEGMENT_HEADER_LEN as u64;
        Ok(())
    }

    fn note_failure(&mut self, e: &io::Error) {
        self.consecutive_failures += 1;
        self.stats.wal_errors.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "[veilgraph] wal append failed ({}/{MAX_CONSECUTIVE_FAILURES}): {e}",
            self.consecutive_failures
        );
        if self.consecutive_failures >= MAX_CONSECUTIVE_FAILURES {
            eprintln!(
                "[veilgraph] wal degraded to in-memory mode; durability lost until restart"
            );
            self.lost = true;
            self.seg = None;
            self.stats.lost.store(true, Ordering::Relaxed);
        }
    }

    /// Flush and fsync the current segment (shutdown, final checkpoint).
    pub fn sync(&mut self) -> Result<()> {
        if let Some(seg) = self.seg.as_mut() {
            seg.sync().map_err(Error::Io)?;
        }
        Ok(())
    }

    /// Delete segments made redundant by a checkpoint at `seq`: a
    /// segment is safe to drop when the *next* segment starts at or
    /// before `seq + 1` (every record in it is then ≤ `seq`). The
    /// segment currently being appended is never dropped.
    pub fn prune_up_to(&mut self, seq: u64) {
        let segs = match list_segments(&self.dir) {
            Ok(s) => s,
            Err(_) => return,
        };
        for pair in segs.windows(2) {
            let (first, _) = &pair[0];
            let (next_first, _) = &pair[1];
            if *next_first <= seq.saturating_add(1) && *first < self.next_seq {
                std::fs::remove_file(&pair[0].1).ok();
            }
        }
    }

    /// Scan a WAL directory: decode every verified record in sequence
    /// order, discarding a torn tail in the newest segment (normal
    /// crash artifact) and stopping at corruption anywhere else.
    pub fn scan(dir: &Path) -> Result<WalScan> {
        let mut out = WalScan { next_seq: 1, ..WalScan::default() };
        let segs = match list_segments(dir) {
            Ok(s) => s,
            Err(_) => return Ok(out), // no directory yet: empty log
        };
        let last = segs.len().saturating_sub(1);
        for (i, (first_seq, path)) in segs.iter().enumerate() {
            let bytes = std::fs::read(path)?;
            match scan_segment(&bytes, *first_seq, &mut out.records) {
                SegmentEnd::Clean => {}
                SegmentEnd::Torn => {
                    if i == last {
                        out.torn_tail_discarded = true;
                    } else {
                        out.corrupt_segment = true;
                        break;
                    }
                }
            }
        }
        if let Some(last) = out.records.last() {
            out.next_seq = last.seq + 1;
        } else if let Some((first_seq, _)) = segs.last() {
            // Segments exist but hold no verifiable records (e.g. all
            // torn): resume past the highest segment start.
            out.next_seq = *first_seq;
        }
        Ok(out)
    }
}

const SEGMENT_HEADER_LEN: usize = 4 + 4 + 8;

fn segment_path(dir: &Path, first_seq: u64) -> PathBuf {
    dir.join(format!("wal-{first_seq:020}.log"))
}

fn open_segment(
    io: &mut Box<dyn WalIo>,
    dir: &Path,
    first_seq: u64,
    stats: &Arc<DurabilityStats>,
) -> Result<Box<dyn SegmentWriter>> {
    let mut header = Vec::with_capacity(SEGMENT_HEADER_LEN);
    header.extend_from_slice(MAGIC);
    header.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    header.extend_from_slice(&first_seq.to_le_bytes());
    let mut seg = io.create_segment(&segment_path(dir, first_seq)).map_err(Error::Io)?;
    seg.write_all(&header).map_err(Error::Io)?;
    stats.wal_segments.fetch_add(1, Ordering::Relaxed);
    Ok(seg)
}

/// All segment files in `dir`, sorted by their first sequence number.
fn list_segments(dir: &Path) -> io::Result<Vec<(u64, PathBuf)>> {
    let mut segs = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(num) = name.strip_prefix("wal-").and_then(|n| n.strip_suffix(".log")) {
            if let Ok(first_seq) = num.parse::<u64>() {
                segs.push((first_seq, entry.path()));
            }
        }
    }
    segs.sort();
    Ok(segs)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn op_tag(op: &EdgeOp) -> (u8, u64, u64) {
    match *op {
        EdgeOp::AddEdge(u, v) => (0, u, v),
        EdgeOp::RemoveEdge(u, v) => (1, u, v),
        EdgeOp::AddVertex(u) => (2, u, 0),
        EdgeOp::RemoveVertex(u) => (3, u, 0),
    }
}

fn encode_record(seq: u64, ops: &[EdgeOp]) -> Vec<u8> {
    let payload_len = 4 + ops.len() * 17;
    let mut buf = Vec::with_capacity(4 + 8 + payload_len + 8);
    buf.extend_from_slice(&(payload_len as u32).to_le_bytes());
    buf.extend_from_slice(&seq.to_le_bytes());
    buf.extend_from_slice(&(ops.len() as u32).to_le_bytes());
    for op in ops {
        let (tag, a, b) = op_tag(op);
        buf.push(tag);
        buf.extend_from_slice(&a.to_le_bytes());
        buf.extend_from_slice(&b.to_le_bytes());
    }
    let digest = fnv1a(&buf);
    buf.extend_from_slice(&digest.to_le_bytes());
    buf
}

enum SegmentEnd {
    Clean,
    Torn,
}

/// Decode one segment's records into `out`; returns whether the
/// segment ended cleanly or in a torn/invalid record.
fn scan_segment(bytes: &[u8], first_seq: u64, out: &mut Vec<WalRecord>) -> SegmentEnd {
    if bytes.len() < SEGMENT_HEADER_LEN
        || &bytes[..4] != MAGIC
        || u32::from_le_bytes(bytes[4..8].try_into().unwrap()) != FORMAT_VERSION
        || u64::from_le_bytes(bytes[8..16].try_into().unwrap()) != first_seq
    {
        return SegmentEnd::Torn;
    }
    let mut pos = SEGMENT_HEADER_LEN;
    let mut expect_seq = first_seq;
    while pos < bytes.len() {
        if pos + 12 > bytes.len() {
            return SegmentEnd::Torn;
        }
        let payload_len =
            u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let end = pos + 12 + payload_len;
        if end + 8 > bytes.len() {
            return SegmentEnd::Torn;
        }
        let digest = u64::from_le_bytes(bytes[end..end + 8].try_into().unwrap());
        if fnv1a(&bytes[pos..end]) != digest {
            return SegmentEnd::Torn;
        }
        let seq = u64::from_le_bytes(bytes[pos + 4..pos + 12].try_into().unwrap());
        if seq != expect_seq {
            return SegmentEnd::Torn;
        }
        match decode_ops(&bytes[pos + 12..end]) {
            Some(ops) => out.push(WalRecord { seq, ops }),
            None => return SegmentEnd::Torn,
        }
        expect_seq += 1;
        pos = end + 8;
    }
    SegmentEnd::Clean
}

fn decode_ops(payload: &[u8]) -> Option<Vec<EdgeOp>> {
    if payload.len() < 4 {
        return None;
    }
    let n = u32::from_le_bytes(payload[..4].try_into().unwrap()) as usize;
    if payload.len() != 4 + n * 17 {
        return None;
    }
    let mut ops = Vec::with_capacity(n);
    let mut pos = 4;
    for _ in 0..n {
        let tag = payload[pos];
        let a = u64::from_le_bytes(payload[pos + 1..pos + 9].try_into().unwrap());
        let b = u64::from_le_bytes(payload[pos + 9..pos + 17].try_into().unwrap());
        ops.push(match tag {
            0 => EdgeOp::AddEdge(a, b),
            1 => EdgeOp::RemoveEdge(a, b),
            2 => EdgeOp::AddVertex(a),
            3 => EdgeOp::RemoveVertex(a),
            _ => return None,
        });
        pos += 17;
    }
    Some(ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::faults::FaultyIo;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "vg-wal-{name}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    fn open(dir: &Path, start_seq: u64) -> Wal {
        Wal::open(
            dir,
            start_seq,
            SyncPolicy::Batch,
            DEFAULT_SEGMENT_MAX_BYTES,
            Box::new(FsIo),
            DurabilityStats::new(),
            None,
        )
        .unwrap()
    }

    fn ops(seed: u64) -> Vec<EdgeOp> {
        vec![EdgeOp::add(seed, seed + 1), EdgeOp::remove(seed, seed + 2), EdgeOp::AddVertex(seed)]
    }

    #[test]
    fn sync_policy_parses() {
        assert_eq!("none".parse::<SyncPolicy>(), Ok(SyncPolicy::None));
        assert_eq!("batch".parse::<SyncPolicy>(), Ok(SyncPolicy::Batch));
        assert_eq!("interval:250".parse::<SyncPolicy>(), Ok(SyncPolicy::Interval(250)));
        assert!("interval:0".parse::<SyncPolicy>().is_err());
        assert!("interval:fast".parse::<SyncPolicy>().is_err());
        assert!("sometimes".parse::<SyncPolicy>().is_err());
        assert_eq!(SyncPolicy::Interval(250).as_str(), "interval:250");
    }

    #[test]
    fn append_then_scan_roundtrips() {
        let dir = tmp("roundtrip");
        let mut wal = open(&dir, 1);
        for i in 0..5u64 {
            assert_eq!(wal.append_batch(&ops(i * 10)).unwrap(), i + 1);
        }
        drop(wal);
        let scan = Wal::scan(&dir).unwrap();
        assert_eq!(scan.records.len(), 5);
        assert_eq!(scan.next_seq, 6);
        assert!(!scan.torn_tail_discarded);
        for (i, rec) in scan.records.iter().enumerate() {
            assert_eq!(rec.seq, i as u64 + 1);
            assert_eq!(rec.ops, ops(i as u64 * 10));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scan_of_missing_dir_is_empty() {
        let scan = Wal::scan(&tmp("missing-never-created")).unwrap();
        assert!(scan.records.is_empty());
        assert_eq!(scan.next_seq, 1);
    }

    #[test]
    fn torn_tail_is_discarded() {
        let dir = tmp("torn");
        let mut wal = open(&dir, 1);
        for i in 0..3u64 {
            wal.append_batch(&ops(i)).unwrap();
        }
        drop(wal);
        // Truncate the single segment mid-record: keep the header and
        // first two records, then half of the third.
        let segs = list_segments(&dir).unwrap();
        assert_eq!(segs.len(), 1);
        let bytes = std::fs::read(&segs[0].1).unwrap();
        let record_len = encode_record(1, &ops(0)).len();
        let keep = SEGMENT_HEADER_LEN + 2 * record_len + record_len / 2;
        std::fs::write(&segs[0].1, &bytes[..keep]).unwrap();
        let scan = Wal::scan(&dir).unwrap();
        assert_eq!(scan.records.len(), 2, "torn third record discarded");
        assert!(scan.torn_tail_discarded);
        assert_eq!(scan.next_seq, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_record_stops_scan() {
        let dir = tmp("corrupt");
        let mut wal = open(&dir, 1);
        for i in 0..3u64 {
            wal.append_batch(&ops(i)).unwrap();
        }
        drop(wal);
        let segs = list_segments(&dir).unwrap();
        let mut bytes = std::fs::read(&segs[0].1).unwrap();
        // Flip a byte inside the second record's payload.
        let record_len = encode_record(1, &ops(0)).len();
        bytes[SEGMENT_HEADER_LEN + record_len + 20] ^= 0xFF;
        std::fs::write(&segs[0].1, &bytes).unwrap();
        let scan = Wal::scan(&dir).unwrap();
        assert_eq!(scan.records.len(), 1, "scan stops at the corrupt record");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_starts_a_fresh_segment_and_continues_seq() {
        let dir = tmp("reopen");
        let mut wal = open(&dir, 1);
        wal.append_batch(&ops(0)).unwrap();
        wal.append_batch(&ops(1)).unwrap();
        drop(wal);
        let scan = Wal::scan(&dir).unwrap();
        let mut wal = open(&dir, scan.next_seq);
        assert_eq!(wal.append_batch(&ops(2)).unwrap(), 3);
        drop(wal);
        let scan = Wal::scan(&dir).unwrap();
        assert_eq!(scan.records.len(), 3);
        assert_eq!(list_segments(&dir).unwrap().len(), 2, "reopen rotated");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_and_pruning() {
        let dir = tmp("rotate");
        let stats = DurabilityStats::new();
        let mut wal = Wal::open(
            &dir,
            1,
            SyncPolicy::None,
            // Tiny cap: every record rotates into its own segment.
            (SEGMENT_HEADER_LEN + 1) as u64,
            Box::new(FsIo),
            Arc::clone(&stats),
            None,
        )
        .unwrap();
        for i in 0..4u64 {
            wal.append_batch(&ops(i)).unwrap();
        }
        wal.sync().unwrap();
        assert!(list_segments(&dir).unwrap().len() >= 4);
        // A checkpoint at seq 3 makes every segment whose successor
        // starts at ≤ 4 redundant.
        wal.prune_up_to(3);
        let remaining = list_segments(&dir).unwrap();
        let scan = Wal::scan(&dir).unwrap();
        assert!(remaining.len() < 4, "old segments pruned");
        assert!(scan.records.iter().all(|r| r.seq >= 4 || r.seq > 3 || r.seq == 4));
        assert_eq!(scan.records.last().unwrap().seq, 4, "newest record survives pruning");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disk_full_degrades_to_memory_mode_without_erroring() {
        let dir = tmp("degrade");
        let inj = FaultInjector::new();
        let stats = DurabilityStats::new();
        let mut wal = Wal::open(
            &dir,
            1,
            SyncPolicy::Batch,
            DEFAULT_SEGMENT_MAX_BYTES,
            Box::new(FaultyIo::new(Arc::clone(&inj))),
            Arc::clone(&stats),
            Some(Arc::clone(&inj)),
        )
        .unwrap();
        wal.append_batch(&ops(0)).unwrap();
        inj.set_disk_budget(3); // next writes short-write then die
        for i in 1..=MAX_CONSECUTIVE_FAILURES as u64 {
            let seq = wal.append_batch(&ops(i)).unwrap();
            assert_eq!(seq, i + 1, "appends keep assigning seqs through failures");
        }
        assert!(wal.is_lost());
        assert!(stats.durability_lost());
        // Further appends are absorbed no-ops.
        wal.append_batch(&ops(99)).unwrap();
        assert!(wal.sync().is_ok());
        // The one durable record still scans (short-written garbage is
        // a torn tail).
        let scan = Wal::scan(&dir).unwrap();
        assert_eq!(scan.records.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn post_wal_append_crash_point_fires_after_the_write() {
        let dir = tmp("crashpoint");
        let inj = FaultInjector::new();
        let mut wal = Wal::open(
            &dir,
            1,
            SyncPolicy::Batch,
            DEFAULT_SEGMENT_MAX_BYTES,
            Box::new(FsIo),
            DurabilityStats::new(),
            Some(Arc::clone(&inj)),
        )
        .unwrap();
        inj.arm_crash(CrashPoint::PostWalAppend);
        assert!(wal.append_batch(&ops(0)).is_err(), "armed point kills the append");
        drop(wal);
        let scan = Wal::scan(&dir).unwrap();
        assert_eq!(scan.records.len(), 1, "the record was durable before the crash");
        std::fs::remove_dir_all(&dir).ok();
    }
}
