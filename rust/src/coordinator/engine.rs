//! The VeilGraph engine: Alg. 1's execution structure.
//!
//! ```text
//! OnStart
//! repeat
//!   msg ← TakeMessage(stream)
//!   if msg is Add/Remove        → Register*(msg)
//!   else if msg is Query:
//!     update? ← BeforeUpdates(graphUpdates, statistics)
//!     if update? → ApplyUpdates
//!     response ← OnQuery(…)
//!     newRanks ← RepeatLast | ComputeApproximate | ComputeExact
//!     OutputResult(newRanks)
//!     OnQueryResult(…)
//! until stopped
//! OnStop
//! ```
//!
//! The engine owns the graph, the pending-update buffer, the current rank
//! vector, the (r, n, Δ) parameters and the summarized executor (XLA or
//! sparse). One engine = one logical VeilGraph job; the server
//! ([`crate::coordinator::server`]) wraps it behind a queue for
//! concurrent producers.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::coordinator::checkpoint::{
    CheckpointImage, CheckpointJob, CheckpointOutcome, DurabilityConfig, RecoveryReport,
};
use crate::coordinator::policies::StalenessPolicy;
use crate::coordinator::serving::{
    RankSnapshot, SnapshotPublisher, SnapshotReader, DEFAULT_PUBLISHED_TOP_K,
};
use crate::coordinator::udf::{Action, DefaultSuite, ExecStats, QueryContext, UdfSuite};
use crate::coordinator::wal::{DurabilityStats, FsIo, Wal};
use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::graph::dynamic::DynamicGraph;
use crate::graph::snapshot::{SnapshotBuild, SnapshotCache, SnapshotStats};
use crate::graph::{VertexId, VertexIdx};
use crate::metrics::registry::MetricsRegistry;
use crate::pagerank::power::{PageRank, PageRankConfig};
use crate::pagerank::summarized::merge_ranks_into;
use crate::runtime::executor::SummarizedExecutor;
use crate::stream::buffer::UpdateBuffer;
use crate::stream::event::{EdgeOp, UpdateEvent};
use crate::stream::window::WindowState;
use crate::summary::bigvertex::SummaryGraph;
use crate::summary::hot::{compute_hot_set_pooled, HotSetInputs};
use crate::summary::params::SummaryParams;
use crate::summary::scratch::{ScratchStats, SummaryScratch};
use crate::testing::faults::{CrashPoint, FaultInjector};
use crate::util::threadpool::ThreadPool;
use crate::util::timer::Stopwatch;

/// Summary-pipeline counters (see [`Engine::summary_stats`]) — the
/// summarized twin of [`SnapshotStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SummaryStats {
    /// Summarized builds served (hot-set selection + summary construction).
    pub builds: u64,
    /// |K| of the most recent build.
    pub last_hot_vertices: usize,
    /// |E_K| of the most recent build.
    pub last_internal_edges: usize,
    /// |E_B| of the most recent build.
    pub last_boundary_edges: usize,
    /// Scratch growth/reuse counters — steady-state queries on a
    /// same-size graph must only ever bump `reused`.
    pub scratch: ScratchStats,
}

/// A served query: execution metadata plus the published ranking. The
/// ranking itself is the engine's immutable [`RankSnapshot`], shared by
/// `Arc` — serving a query no longer clones O(|V|) `ids`/`ranks`, and
/// consecutive queries that leave the ranking untouched share one
/// allocation.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// Measurement point `t` (1-based; 0 is the initial computation).
    pub query_id: u64,
    /// How the query was served.
    pub action: Action,
    /// Execution statistics.
    pub exec: ExecStats,
    /// The ranking this query observed (the engine's published snapshot
    /// as of this measurement point).
    pub snapshot: Arc<RankSnapshot>,
}

impl QueryResult {
    /// Vertex ids in dense order, aligned with [`Self::ranks`].
    pub fn ids(&self) -> &[VertexId] {
        &self.snapshot.ids
    }

    /// PageRank scores (full graph).
    pub fn ranks(&self) -> &[f64] {
        &self.snapshot.ranks
    }

    /// Top-k `(vertex, score)` pairs, descending (ties: ascending id).
    /// `k` at or below the snapshot's precomputed top-K cap is O(k).
    pub fn top(&self, k: usize) -> Vec<(VertexId, f64)> {
        self.snapshot.top(k)
    }

    /// Top-k ids only (for RBO comparisons).
    pub fn top_ids(&self, k: usize) -> Vec<VertexId> {
        self.snapshot.top_ids(k)
    }

    /// Rank of one vertex by external id.
    pub fn rank_of(&self, id: VertexId) -> Option<f64> {
        self.snapshot.rank_of(id)
    }
}

/// A wire query answered immediately from the published snapshot, plus
/// the staleness decision that may have scheduled an off-thread
/// recompute (see [`Engine::query_async`]).
#[derive(Clone, Debug)]
pub struct AsyncQueryResult {
    /// Measurement point `t` (shared counter with [`Engine::query`]).
    pub query_id: u64,
    /// What the staleness policy decided (possibly degraded under queue
    /// pressure); `RepeatLast` means no recompute was warranted.
    pub decision: Action,
    /// Whether a recompute job was actually handed to the caller — false
    /// when one is already in flight even if `decision` escalated.
    pub scheduled: bool,
    /// The snapshot this query was answered from (post-absorb: pending
    /// writes were applied and the topology republished first).
    pub snapshot: Arc<RankSnapshot>,
}

/// How [`Engine::query_async`] may turn an escalated staleness decision
/// into an off-thread recompute job. The server picks a mode per query
/// from its outstanding-job bookkeeping (see
/// [`crate::coordinator::server`]): `WhenDue` with no job in flight,
/// `ExactOnly` to supersede a stale in-flight job, `Never` otherwise.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Record the decision (and serve degraded) but never hand back a
    /// job.
    Never,
    /// Schedule whenever the decision escalates past
    /// [`Action::RepeatLast`].
    WhenDue,
    /// Schedule only a full-accuracy job. This is the supersession
    /// guard: a replacement job is only worth cancelling its
    /// predecessor for when it refreshes *every* vertex, so discarding
    /// the superseded result loses nothing.
    ExactOnly,
}

/// How [`Engine::finish_recompute`] (and its sharded twin) integrated
/// an off-thread result: whether the version fence held, and — when it
/// did not — whether the post-fence ops were reconciled into the
/// published ranking instead of being counted as a plain fence miss.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecomputeOutcome {
    /// The graph did not move while the job ran; the result installed
    /// verbatim.
    pub fence_ok: bool,
    /// The fence missed but the armed fence log replayed the post-fence
    /// ops as a first-order rank correction before publishing.
    pub reconciled: bool,
}

/// Fence-log growth bound: past this many post-fence effective ops a
/// reconciliation sweep approaches recompute cost, so the log taints
/// and the miss falls back to the plain by-id merge.
pub(crate) const FENCE_LOG_CAP: usize = 65_536;

/// Effective ops applied after a recompute fence was captured — the
/// reconciliation input that turns a fence miss into a cheap
/// first-order correction instead of a discarded result. Tainted (and
/// emptied) by vertex removals — reconciliation needs pre-removal
/// adjacency the live graph no longer has — and by growth past
/// [`FENCE_LOG_CAP`].
struct FenceLog {
    /// Graph version the paired recompute was fenced at; the log only
    /// reconciles the job it was armed for.
    from_version: u64,
    ops: Vec<EdgeOp>,
    tainted: bool,
}

impl FenceLog {
    fn append(&mut self, ops: &[EdgeOp]) {
        if self.tainted {
            return;
        }
        let removes = ops.iter().any(|op| matches!(op, EdgeOp::RemoveVertex(_)));
        if removes || self.ops.len() + ops.len() > FENCE_LOG_CAP {
            self.tainted = true;
            self.ops.clear();
            return;
        }
        self.ops.extend_from_slice(ops);
    }
}

/// Inputs for an approximate (summarized) recompute, cloned at the
/// version fence.
struct ApproxInputs {
    graph: DynamicGraph,
    params: SummaryParams,
    prev_degree: HashMap<VertexId, usize>,
    new_vertices: Vec<VertexId>,
}

/// A version-fenced recompute: everything PageRank needs, captured from
/// the engine at scheduling time so the computation can run on any other
/// thread while the engine keeps absorbing writes and publishing reads.
/// Exact jobs freeze the topology as the engine's cached `Arc<Csr>`
/// (zero-copy); approximate jobs clone the dynamic graph plus the carry
/// state the hot-set selection needs.
pub struct RecomputeJob {
    decision: Action,
    query_id: u64,
    graph_version: u64,
    /// `updates_since_refresh` this job accounts for — returned to the
    /// engine if the job corrects nothing (empty summary).
    accounted_updates: u64,
    ids: Vec<VertexId>,
    warm_ranks: Vec<f64>,
    pr_config: PageRankConfig,
    csr: Option<Arc<Csr>>,
    approx: Option<ApproxInputs>,
}

/// The outcome of a [`RecomputeJob`], handed back to the engine thread
/// via [`Engine::finish_recompute`].
pub struct RecomputeResult {
    /// Measurement point that scheduled the job.
    pub query_id: u64,
    /// Graph version the job was fenced at.
    pub graph_version: u64,
    /// How the ranking was recomputed.
    pub action: Action,
    /// Execution statistics (elapsed covers the whole off-thread run).
    pub exec: ExecStats,
    accounted_updates: u64,
    refreshed: bool,
    carry_back: Option<(HashMap<VertexId, usize>, Vec<VertexId>)>,
    ids: Vec<VertexId>,
    ranks: Vec<f64>,
    /// The hot set |K| the job selected (external ids; empty for exact
    /// runs) — installed as the engine's hot set and published with the
    /// snapshot so hot-set standing queries can diff membership.
    hot_set: Vec<VertexId>,
}

impl RecomputeResult {
    /// Whether the job actually produced a refreshed ranking (an empty
    /// summary or failed executor corrects nothing and publishes
    /// nothing).
    pub fn refreshed(&self) -> bool {
        self.refreshed
    }
}

impl RecomputeJob {
    /// The accuracy tier this job computes.
    pub fn decision(&self) -> Action {
        self.decision
    }

    /// Graph version the job is fenced at.
    pub fn graph_version(&self) -> u64 {
        self.graph_version
    }

    /// Execute the recompute on the caller's thread. Self-contained: no
    /// access to the engine, its pool or its scratch (the engine keeps
    /// using those concurrently).
    pub fn run(self) -> RecomputeResult {
        self.run_with(None)
    }

    /// Execute the recompute, sharding its compute stages over `pool`
    /// when one is provided — the dedicated recompute pool of
    /// `serve --recompute-workers`, so an exact job never contends with
    /// the engine pool serving summarized queries. Safe on any thread
    /// that is not one of `pool`'s own workers.
    pub fn run_with(self, pool: Option<&ThreadPool>) -> RecomputeResult {
        let sw = Stopwatch::start();
        let shards = match pool {
            Some(pool) => self.pr_config.effective_shards(pool),
            None => 1,
        };
        let mut exec = ExecStats::default();
        let mut refreshed = true;
        let mut carry_back = None;
        let mut hot_set: Vec<VertexId> = Vec::new();
        let ranks = match (self.decision, self.approx) {
            (Action::ComputeApproximate, Some(a)) => {
                let mut scratch = SummaryScratch::new();
                let inputs = HotSetInputs {
                    graph: &a.graph,
                    prev_degree: &a.prev_degree,
                    new_vertices: &a.new_vertices,
                    prev_ranks: &self.warm_ranks,
                };
                let hot = compute_hot_set_pooled(&inputs, &a.params, &mut scratch, pool, shards);
                let default = self.pr_config.init_rank(a.graph.num_vertices());
                let summary = SummaryGraph::build_pooled(
                    &a.graph,
                    &hot,
                    &self.warm_ranks,
                    default,
                    &mut scratch,
                    pool,
                    shards,
                );
                hot_set = hot.all().into_iter().map(|i| a.graph.id(i)).collect();
                scratch.recycle_hot(hot);
                exec.summary_vertices = summary.num_vertices();
                exec.summary_edges = summary.num_edges();
                let mut ranks = self.warm_ranks;
                if summary.num_vertices() > 0 {
                    let mut executor = SummarizedExecutor::sparse_only();
                    match executor.execute_pooled(&summary, &self.pr_config, pool) {
                        Ok((res, backend)) => {
                            exec.backend = Some(backend);
                            exec.iterations = res.iterations;
                            merge_ranks_into(&mut ranks, &summary, &res.ranks, default);
                        }
                        Err(_) => refreshed = false,
                    }
                } else {
                    // Sub-threshold drift: the summary corrected nothing.
                    refreshed = false;
                }
                if !refreshed {
                    // Hand the carry state back so the accumulated-error
                    // signal keeps counting toward a future refresh.
                    carry_back = Some((a.prev_degree, a.new_vertices));
                }
                ranks
            }
            _ => {
                let csr = self.csr.expect("exact recompute job carries a fenced CSR");
                let pr = PageRank::new(self.pr_config);
                let warm = self.pr_config.warm_start_exact
                    && self.warm_ranks.len() == csr.num_vertices()
                    && !self.warm_ranks.is_empty();
                let res = match (pool, warm) {
                    (Some(pool), true) => pr.run_parallel_from(&csr, self.warm_ranks, pool),
                    (Some(pool), false) => pr.run_parallel(&csr, pool),
                    (None, true) => pr.run_from(&csr, self.warm_ranks),
                    (None, false) => pr.run(&csr),
                };
                exec.iterations = res.iterations;
                res.ranks
            }
        };
        exec.elapsed_secs = sw.secs();
        RecomputeResult {
            query_id: self.query_id,
            graph_version: self.graph_version,
            action: self.decision,
            exec,
            accounted_updates: self.accounted_updates,
            refreshed,
            carry_back,
            ids: self.ids,
            ranks,
            hot_set,
        }
    }
}

/// Builder for [`Engine`].
pub struct EngineBuilder {
    params: SummaryParams,
    pr_config: PageRankConfig,
    /// Set via [`Self::parallelism`]; applied to `pr_config` at build
    /// time so it survives a later [`Self::pagerank`] call replacing the
    /// whole config (order-independent builder).
    parallelism: Option<usize>,
    /// Externally owned worker pool (see [`Self::shared_pool`]); when
    /// absent the engine spawns its own per [`pool_for`].
    shared_pool: Option<Arc<ThreadPool>>,
    artifacts_dir: Option<std::path::PathBuf>,
    warmup: bool,
    max_xla_k: Option<usize>,
    published_top_k: usize,
    udf: Box<dyn UdfSuite>,
    /// Set via [`Self::durability`]; consumed by [`Self::build_durable`].
    durability: Option<DurabilityConfig>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Worker pool matching the config's `parallelism` knob (`None` when the
/// executors run serial — no idle threads for the default config).
fn pool_for(pr: &PageRankConfig) -> Option<ThreadPool> {
    match pr.parallelism {
        1 => None,
        0 => Some(ThreadPool::with_default_size()),
        k => Some(ThreadPool::new(k)),
    }
}

impl EngineBuilder {
    /// Defaults: paper mid-grid parameters (r=0.2, n=1, Δ=0.1), β=0.85,
    /// sparse executor, `DefaultSuite` UDFs.
    pub fn new() -> Self {
        Self {
            params: SummaryParams::new(0.2, 1, 0.1),
            pr_config: PageRankConfig::default(),
            parallelism: None,
            shared_pool: None,
            artifacts_dir: None,
            warmup: false,
            max_xla_k: None,
            published_top_k: DEFAULT_PUBLISHED_TOP_K,
            udf: Box::new(DefaultSuite),
            durability: None,
        }
    }

    /// Set (r, n, Δ).
    pub fn params(mut self, p: SummaryParams) -> Self {
        self.params = p;
        self
    }

    /// Set the PageRank configuration.
    pub fn pagerank(mut self, c: PageRankConfig) -> Self {
        self.pr_config = c;
        self
    }

    /// Shard count for the PageRank executors (`1` = serial — the
    /// default; `0` = one shard per available core; `k > 1` = exactly
    /// `k`). Overrides [`PageRankConfig::parallelism`] at build time —
    /// order-independent with respect to [`Self::pagerank`]. When the
    /// resolved value is not `1`, the engine owns a worker pool reused
    /// by every exact and sparse-summarized computation it serves.
    pub fn parallelism(mut self, shards: usize) -> Self {
        self.parallelism = Some(shards);
        self
    }

    /// Fold the standalone `parallelism` override into the PageRank
    /// config (call once, at build time).
    fn resolve_parallelism(&mut self) {
        if let Some(p) = self.parallelism {
            self.pr_config.parallelism = p;
        }
    }

    /// Share an existing worker pool instead of spawning one per engine.
    /// The experiment harness passes ONE pool to every combination replay
    /// (total threads = outer workers + one shard pool, not their
    /// product). The pool serves both the snapshot builds and the sharded
    /// executors; `parallelism` still sets the shard count (`0` = one
    /// shard per pool worker). Never hand an engine the pool whose
    /// workers *call into* that engine — scoped dispatch would deadlock.
    pub fn shared_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.shared_pool = Some(pool);
        self
    }

    /// Resolve the engine's pool: a shared one wins, else spawn per
    /// [`pool_for`].
    fn resolve_pool(&mut self) -> Option<Arc<ThreadPool>> {
        self.shared_pool.take().or_else(|| pool_for(&self.pr_config).map(Arc::new))
    }

    /// Attach the XLA runtime with artifacts from `dir`.
    pub fn artifacts_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    /// Compile all artifact tiers at build time (keeps compilation off
    /// the query path).
    pub fn warmup(mut self, yes: bool) -> Self {
        self.warmup = yes;
        self
    }

    /// Route summaries with |K| ≤ `k` to the XLA dense path (see
    /// [`crate::runtime::executor::DEFAULT_MAX_XLA_K`] for the cost
    /// rationale).
    pub fn max_xla_k(mut self, k: usize) -> Self {
        self.max_xla_k = Some(k);
        self
    }

    /// How many top entries every published [`RankSnapshot`] pre-ranks
    /// (default [`DEFAULT_PUBLISHED_TOP_K`]). Read-path `top(k)` with
    /// `k ≤` this cap is an O(k) copy; larger `k` re-selects on demand.
    pub fn published_top_k(mut self, k: usize) -> Self {
        self.published_top_k = k;
        self
    }

    /// Install a custom UDF suite.
    pub fn udf(mut self, udf: Box<dyn UdfSuite>) -> Self {
        self.udf = udf;
        self
    }

    /// Build the engine over an initial edge list and run the initial
    /// complete PageRank (the paper's setup: “each execution will begin
    /// with a complete PageRank execution”).
    pub fn build_from_edges(
        self,
        edges: impl IntoIterator<Item = (VertexId, VertexId)>,
    ) -> Result<Engine> {
        let (graph, _dups) = DynamicGraph::from_edges(edges);
        self.build_from_graph(graph)
    }

    /// Resume from a checkpoint written by [`Engine::save_checkpoint`]:
    /// restores the graph, the rank vector and the query counter without
    /// re-running the initial exact computation.
    pub fn build_from_checkpoint(self, path: impl AsRef<std::path::Path>) -> Result<Engine> {
        let ckpt = crate::coordinator::checkpoint::load(path)?;
        self.build_restored(ckpt.graph, ckpt.ranks, ckpt.query_count)
    }

    /// Build an engine around already-computed state (the restore path:
    /// no initial exact run; the restored ranking is republished so
    /// readers can serve before the first post-restore query).
    fn build_restored(
        mut self,
        graph: DynamicGraph,
        ranks: Vec<f64>,
        query_count: u64,
    ) -> Result<Engine> {
        self.resolve_parallelism();
        let pool = self.resolve_pool();
        let mut executor = match &self.artifacts_dir {
            Some(dir) => SummarizedExecutor::with_artifacts(dir)?,
            None => SummarizedExecutor::sparse_only(),
        };
        if let Some(k) = self.max_xla_k {
            executor.set_max_xla_k(k);
        }
        if self.warmup {
            executor.warmup()?;
        }
        self.udf.on_start();
        let mut engine = Engine {
            graph,
            buffer: UpdateBuffer::new(),
            params: self.params,
            pr_config: self.pr_config,
            executor,
            pool,
            snapshot: SnapshotCache::new(),
            scratch: SummaryScratch::new(),
            summary_totals: SummaryStats::default(),
            udf: self.udf,
            metrics: MetricsRegistry::new(),
            published: SnapshotPublisher::new(),
            published_top_k: self.published_top_k,
            ranks,
            last_hot_set: Vec::new(),
            carry_prev_degree: HashMap::new(),
            carry_new_vertices: Vec::new(),
            query_count,
            queries_since_exact: 0,
            last_publish: std::time::Instant::now(),
            queries_since_publish: 0,
            updates_since_refresh: 0,
            fence_log: None,
            reconcile: true,
            stopped: false,
            wal: None,
            durability: DurabilityStats::new(),
            dur_dir: None,
            dur_keep: 3,
            dur_checkpoint_every: 64,
            faults: None,
            replaying: false,
            applies_since_checkpoint: 0,
            checkpoint_in_flight: false,
            recovered_window: None,
        };
        engine.publish_now(engine.query_count, Action::ComputeExact, ExecStats::default());
        Ok(engine)
    }

    /// Configure durability: a write-ahead log plus periodic
    /// crash-consistent checkpoints under `cfg.dir`. Consumed by
    /// [`Self::build_durable`].
    pub fn durability(mut self, cfg: DurabilityConfig) -> Self {
        self.durability = Some(cfg);
        self
    }

    /// Build with durability. If the configured directory holds state
    /// from a previous run, recovery runs first: the newest valid
    /// snapshot loads (older ones tried on corruption), the WAL tail
    /// replays through the ordinary batch path, and the recovered
    /// ranking republishes — `initial_edges` is only consulted when the
    /// directory is empty. The first recompute then warm-starts from
    /// the recovered ranks. Returns the engine and an accounting of
    /// what recovery did.
    pub fn build_durable(
        mut self,
        initial_edges: impl IntoIterator<Item = (VertexId, VertexId)>,
    ) -> Result<(Engine, RecoveryReport)> {
        let mut cfg = self.durability.take().ok_or_else(|| {
            Error::Usage("build_durable requires .durability(DurabilityConfig)".into())
        })?;
        std::fs::create_dir_all(&cfg.dir)?;
        let recovered = crate::coordinator::checkpoint::recover(&cfg.dir)?;
        let report = recovered.report.clone();
        let stats = DurabilityStats::new();
        let mut window = None;
        let mut durable_subs = Vec::new();
        let mut engine = match recovered.image {
            Some(mut image) => {
                image.graph.set_version(image.graph_version);
                window = image.window.take();
                durable_subs = std::mem::take(&mut image.durable_subs);
                self.build_restored(image.graph, image.ranks, image.query_count)?
            }
            None => self.build_from_edges(initial_edges)?,
        };
        if report.snapshot_loaded.is_some() || !recovered.tail.is_empty() {
            stats.note_recovery(
                report.replayed_batches as u64,
                report.replayed_ops as u64,
                report.torn_tail_discarded,
                report.snapshots_skipped as u64,
            );
        }
        engine.published.subscriptions().restore_durable(durable_subs);
        engine.recovered_window = window;
        // Replay the tail: each WAL record is one already-coalesced
        // effective batch, re-applied through the same path that
        // produced it — the recovered CSR and rank layout come out
        // bit-identical to the pre-crash state.
        engine.replaying = true;
        for rec in &recovered.tail {
            engine.ingest_batch(rec.ops.iter().copied());
            engine.apply_pending_batch();
        }
        engine.replaying = false;
        if !recovered.tail.is_empty() {
            // Replayed batches may have grown the graph past the
            // checkpointed rank vector; published snapshots carry one
            // rank per vertex.
            engine.extend_ranks_for_new_vertices();
            engine.publish_now(engine.query_count, Action::ComputeExact, ExecStats::default());
        }
        let io = cfg.io.take().unwrap_or_else(|| Box::new(FsIo));
        let wal = Wal::open(
            cfg.dir.clone(),
            recovered.next_seq,
            cfg.sync,
            cfg.segment_max_bytes,
            io,
            Arc::clone(&stats),
            cfg.faults.clone(),
        )?;
        engine.wal = Some(wal);
        engine.durability = stats;
        engine.dur_dir = Some(cfg.dir);
        engine.dur_keep = cfg.keep_snapshots;
        engine.dur_checkpoint_every = cfg.checkpoint_every;
        engine.faults = cfg.faults;
        Ok((engine, report))
    }

    /// Build from an existing graph.
    pub fn build_from_graph(mut self, graph: DynamicGraph) -> Result<Engine> {
        self.resolve_parallelism();
        let pool = self.resolve_pool();
        let mut executor = match &self.artifacts_dir {
            Some(dir) => SummarizedExecutor::with_artifacts(dir)?,
            None => SummarizedExecutor::sparse_only(),
        };
        if let Some(k) = self.max_xla_k {
            executor.set_max_xla_k(k);
        }
        if self.warmup {
            executor.warmup()?;
        }
        self.udf.on_start();
        let mut engine = Engine {
            graph,
            buffer: UpdateBuffer::new(),
            params: self.params,
            pr_config: self.pr_config,
            executor,
            pool,
            snapshot: SnapshotCache::new(),
            scratch: SummaryScratch::new(),
            summary_totals: SummaryStats::default(),
            udf: self.udf,
            metrics: MetricsRegistry::new(),
            published: SnapshotPublisher::new(),
            published_top_k: self.published_top_k,
            ranks: Vec::new(),
            last_hot_set: Vec::new(),
            carry_prev_degree: HashMap::new(),
            carry_new_vertices: Vec::new(),
            query_count: 0,
            queries_since_exact: 0,
            last_publish: std::time::Instant::now(),
            queries_since_publish: 0,
            updates_since_refresh: 0,
            fence_log: None,
            reconcile: true,
            stopped: false,
            wal: None,
            durability: DurabilityStats::new(),
            dur_dir: None,
            dur_keep: 3,
            dur_checkpoint_every: 64,
            faults: None,
            replaying: false,
            applies_since_checkpoint: 0,
            checkpoint_in_flight: false,
            recovered_window: None,
        };
        // Initial complete execution (measurement point 0).
        let (iters, secs) = crate::util::timer::timed(|| engine.compute_exact());
        engine.metrics.time("initial_exact_secs", secs);
        engine.publish_now(
            0,
            Action::ComputeExact,
            ExecStats { elapsed_secs: secs, iterations: iters, ..Default::default() },
        );
        Ok(engine)
    }
}

/// The VeilGraph coordinator engine.
pub struct Engine {
    graph: DynamicGraph,
    buffer: UpdateBuffer,
    params: SummaryParams,
    pr_config: PageRankConfig,
    executor: SummarizedExecutor,
    /// The engine's ONE worker pool, shared by snapshot builds and the
    /// sharded executors — owned (spawned at build time) or handed in via
    /// [`EngineBuilder::shared_pool`]. `None` ⇔ serial config with no
    /// shared pool.
    pool: Option<Arc<ThreadPool>>,
    /// Version-keyed CSR cache over `graph` (see
    /// [`crate::graph::snapshot`]): repeat queries on an unchanged graph
    /// skip the freeze step entirely.
    snapshot: SnapshotCache,
    /// Reusable workspace for the summarized pipeline: hot bitmap, BFS
    /// visit state and the epoch-stamped dense→local / inverse-degree
    /// maps. After the first summarized query the pipeline performs no
    /// O(|V|) allocations on a same-size graph (see
    /// [`Engine::summary_stats`]).
    scratch: SummaryScratch,
    /// Cumulative summary-pipeline counters (builds + last sizes).
    summary_totals: SummaryStats,
    udf: Box<dyn UdfSuite>,
    metrics: MetricsRegistry,
    /// Read/write split (see [`crate::coordinator::serving`]): after each
    /// recompute the engine publishes an immutable `Arc<RankSnapshot>`
    /// here; any number of [`SnapshotReader`]s serve `top`/`rank`/`stats`
    /// from it without entering the engine.
    published: SnapshotPublisher,
    /// Top-K entries pre-ranked per published snapshot.
    published_top_k: usize,
    /// Current full rank vector (dense index order).
    ranks: Vec<f64>,
    /// Hot set |K| from the most recent approximate run (external ids;
    /// cleared by exact runs, which refresh every vertex). Published with
    /// each snapshot so hot-set standing queries can diff membership.
    last_hot_set: Vec<VertexId>,
    /// `d_{t-1}` accumulated across applies since the last recompute —
    /// if a query repeats the cached answer after applying updates, the
    /// degree baseline must survive to the next measurement point.
    carry_prev_degree: HashMap<VertexId, usize>,
    carry_new_vertices: Vec<VertexId>,
    query_count: u64,
    queries_since_exact: u64,
    /// When the engine last published a fresh snapshot (staleness anchor;
    /// mirrors `RankSnapshot::published_at` of the latest publish).
    last_publish: std::time::Instant,
    /// Queries served since that publish (the snapshot-age-in-queries
    /// gauge staleness policies escalate on).
    queries_since_publish: u64,
    /// Effective (coalesced) updates applied since the ranking was last
    /// recomputed — the accumulated-error proxy for staleness policies.
    updates_since_refresh: u64,
    /// Post-fence effective ops, armed per recompute while
    /// reconciliation is on.
    fence_log: Option<FenceLog>,
    /// Reconcile fence-missed recomputes instead of demoting them to a
    /// plain by-id merge.
    reconcile: bool,
    stopped: bool,
    // ---- durability (inert when the engine runs without a data dir) ----
    /// Write-ahead log; `Some` ⇔ durability configured.
    wal: Option<Wal>,
    /// Shared durability gauges (the wire `stats.durability` section);
    /// always present, reporting `enabled: false` without a WAL.
    durability: Arc<DurabilityStats>,
    /// Durability directory (WAL segments + checkpoint files).
    dur_dir: Option<std::path::PathBuf>,
    /// Snapshots retained for corruption fallback.
    dur_keep: usize,
    /// Applied batches between checkpoints.
    dur_checkpoint_every: u64,
    /// Fault injection (tests; `None` in production).
    faults: Option<Arc<FaultInjector>>,
    /// True while recovery replays the WAL tail — replayed batches must
    /// not be appended again.
    replaying: bool,
    /// Applied batches since the last checkpoint was cut.
    applies_since_checkpoint: u64,
    /// An off-thread checkpoint job is outstanding (at most one).
    checkpoint_in_flight: bool,
    /// Window admission state recovered from the loaded snapshot; the
    /// server claims it via [`Engine::take_recovered_window`].
    recovered_window: Option<WindowState>,
}

impl Engine {
    /// Ingest one graph operation (Alg. 1 lines 4–5).
    pub fn ingest(&mut self, op: EdgeOp) {
        self.buffer.register(op);
        self.metrics.inc("ops_ingested", 1);
        self.refresh_ingest_gauges();
    }

    /// Ingest a batch of operations in one step: one buffer registration
    /// pass and one metrics update for the whole batch. The ops coalesce
    /// with everything else pending when the next query applies updates.
    pub fn ingest_batch(&mut self, ops: impl IntoIterator<Item = EdgeOp>) {
        let n = self.buffer.register_batch(ops);
        self.metrics.inc("ops_ingested", n as u64);
        self.metrics.inc("batches_ingested", 1);
        self.refresh_ingest_gauges();
    }

    /// Mirror the buffer's O(1) coalescing counters into the serving
    /// layer's live gauges so the off-queue `stats` op sees write-path
    /// pressure between publishes.
    fn refresh_ingest_gauges(&self) {
        use std::sync::atomic::Ordering;
        let g = self.published.ingest_gauges();
        let (raw, eff) = self.buffer.coalesce_totals();
        g.coalesced_raw_ops.store(raw as u64, Ordering::Relaxed);
        g.coalesced_effective_ops.store(eff as u64, Ordering::Relaxed);
        g.pending_effective_estimate
            .store(self.buffer.pending_effective_estimate() as u64, Ordering::Relaxed);
    }

    /// Ingest a batch (alias of [`Self::ingest_batch`] — routed through
    /// the batch path, not a per-op `register` loop).
    pub fn ingest_many(&mut self, ops: impl IntoIterator<Item = EdgeOp>) {
        self.ingest_batch(ops);
    }

    /// The batch-aware ApplyUpdates step: drain + coalesce the pending
    /// buffer, capture the degree baseline for the hot set, then apply
    /// the effective ops grouped by row. Surfaces
    /// `ingest_{coalesce,apply}_secs` timings and raw/effective gauges.
    fn apply_pending_batch(&mut self) {
        let sw = Stopwatch::start();
        let batch = self.buffer.take_batch(&self.graph);
        self.metrics.time("ingest_coalesce_secs", sw.secs());
        // Durability: the effective batch becomes a WAL record *before*
        // it mutates the graph — crash recovery replays exactly these
        // records back through this same path. Replayed batches skip
        // the append (they are already in the log). I/O failures are
        // absorbed inside the WAL (degradation, not errors); the only
        // `Err` here is an injected crash point.
        if !self.replaying && !batch.ops().is_empty() {
            if let Some(wal) = self.wal.as_mut() {
                if let Err(e) = wal.append_batch(batch.ops()) {
                    // The record is durable, the in-memory apply never
                    // happens, and the engine goes dead — exactly the
                    // state a process killed here leaves behind.
                    eprintln!("[veilgraph] {e}");
                    self.stopped = true;
                    return;
                }
            }
        }
        // Keep the EARLIEST previous degree per vertex across applies
        // (`d_{t-1}` must survive repeat-last queries to the next
        // measurement point). Membership goes through a hash set so a
        // large new-vertex batch stays linear, not O(touched x carried).
        let mut known_new: HashSet<VertexId> = self.carry_new_vertices.iter().copied().collect();
        for &id in batch.touched() {
            match self.graph.index(id) {
                Some(idx) => {
                    if !self.carry_prev_degree.contains_key(&id) && !known_new.contains(&id) {
                        let d = self.graph.degree(idx);
                        self.carry_prev_degree.insert(id, d);
                    }
                }
                None => {
                    if known_new.insert(id) {
                        self.carry_new_vertices.push(id);
                    }
                }
            }
        }
        let shards = match self.pool.as_deref() {
            Some(pool) => self.pr_config.effective_shards(pool),
            None => 1,
        };
        let sw = Stopwatch::start();
        let res = self.graph.apply_batch(batch.ops(), self.pool.as_deref(), shards);
        self.metrics.time("ingest_apply_secs", sw.secs());
        // While a recompute fence is armed, the effective ops feed the
        // reconciliation log (the same records the WAL just absorbed).
        if let Some(flog) = &mut self.fence_log {
            flog.append(batch.ops());
        }
        self.metrics.inc("applies", 1);
        self.metrics.inc("batch_raw_ops", batch.raw_ops as u64);
        self.metrics.inc("batch_effective_ops", batch.effective_ops() as u64);
        self.metrics.set("last_batch_raw_ops", batch.raw_ops as f64);
        self.metrics.set("last_batch_effective_ops", batch.effective_ops() as f64);
        self.updates_since_refresh += res.applied as u64;
        if self.wal.is_some() && !self.replaying {
            self.applies_since_checkpoint += 1;
        }
        self.refresh_ingest_gauges();
    }

    /// Serve one query (Alg. 1 lines 6–20).
    pub fn query(&mut self) -> Result<QueryResult> {
        if self.stopped {
            return Err(Error::Engine("engine is stopped".into()));
        }
        let sw = Stopwatch::start();
        self.query_count += 1;
        let query_id = self.query_count;
        let stats = self.buffer.statistics(&self.graph);

        // BeforeUpdates → ApplyUpdates (batched: coalesce, then apply)
        let update = self.udf.before_updates(self.buffer.pending(), &stats);
        if update && !self.buffer.is_empty() {
            self.apply_pending_batch();
        }

        let snapshot_age_secs = self.last_publish.elapsed().as_secs_f64();
        self.metrics.set("snapshot_age_secs", snapshot_age_secs);
        self.metrics.set("snapshot_age_queries", self.queries_since_publish as f64);
        let ctx = QueryContext {
            query_id,
            stats,
            num_vertices: self.graph.num_vertices(),
            num_edges: self.graph.num_edges(),
            queries_since_exact: self.queries_since_exact,
            snapshot_age_queries: self.queries_since_publish,
            snapshot_age_secs,
            updates_since_refresh: self.updates_since_refresh,
        };

        // OnQuery → dispatch
        let action = self.udf.on_query(&ctx);
        let mut exec = ExecStats {
            elapsed_secs: 0.0,
            backend: None,
            summary_vertices: 0,
            summary_edges: 0,
            iterations: 0,
        };
        let ranks_len_before = self.ranks.len();
        // A recompute actually produced new scores (vs. merely extending
        // the vector for new vertices) — drives both the publish decision
        // and the staleness bookkeeping.
        let mut ranks_refreshed = false;
        match action {
            Action::RepeatLast => {
                self.extend_ranks_for_new_vertices();
                self.queries_since_exact += 1;
            }
            Action::ComputeApproximate => {
                let summary = self.build_summary();
                exec.summary_vertices = summary.num_vertices();
                exec.summary_edges = summary.num_edges();
                if summary.num_vertices() > 0 {
                    let pool = self.pool.as_deref();
                    let (res, backend) =
                        self.executor.execute_pooled(&summary, &self.pr_config, pool)?;
                    exec.backend = Some(backend);
                    exec.iterations = res.iterations;
                    let sw_merge = Stopwatch::start();
                    let default = self.pr_config.init_rank(self.graph.num_vertices());
                    merge_ranks_into(&mut self.ranks, &summary, &res.ranks, default);
                    self.metrics.time("summary_merge_secs", sw_merge.secs());
                    ranks_refreshed = true;
                } else {
                    self.extend_ranks_for_new_vertices();
                }
                // An empty-summary "approximation" corrected nothing —
                // then keep the `d_{t-1}` baselines and the accumulated-
                // updates signal, or sub-threshold drift could never
                // accumulate into a future hot set / exact refresh.
                if ranks_refreshed {
                    self.carry_prev_degree.clear();
                    self.carry_new_vertices.clear();
                    self.updates_since_refresh = 0;
                }
                self.queries_since_exact += 1;
            }
            Action::ComputeExact => {
                exec.iterations = self.compute_exact();
                self.last_hot_set.clear();
                self.carry_prev_degree.clear();
                self.carry_new_vertices.clear();
                self.updates_since_refresh = 0;
                self.queries_since_exact = 0;
                ranks_refreshed = true;
            }
        }
        let ranks_grew = self.ranks.len() != ranks_len_before;
        exec.elapsed_secs = sw.secs();

        // Metrics + OnQueryResult
        self.metrics.inc("queries", 1);
        let action_counter = match action {
            Action::RepeatLast => "action_repeat-last",
            Action::ComputeApproximate => "action_approximate",
            Action::ComputeExact => "action_exact",
        };
        self.metrics.inc(action_counter, 1);
        self.metrics.time("query_secs", exec.elapsed_secs);
        self.metrics.set("last_summary_vertices", exec.summary_vertices as f64);
        self.metrics.set("last_summary_edges", exec.summary_edges as f64);
        self.udf.on_query_result(&ctx, action, &exec);

        // Count this query against the published snapshot's age; a fresh
        // publish below resets the counter.
        self.queries_since_publish += 1;
        let snapshot = self.publish_result(query_id, action, &exec, ranks_refreshed, ranks_grew);
        Ok(QueryResult { query_id, action, exec, snapshot })
    }

    /// The asynchronous serving path: absorb pending writes, answer from
    /// the (republished) snapshot immediately, and — when the staleness
    /// policy escalates — hand back a version-fenced [`RecomputeJob`] for
    /// a worker thread to run instead of recomputing inline. The engine
    /// thread therefore never blocks on PageRank: writes, recomputes and
    /// reads all overlap, and `pressure` (engine-queue occupancy in
    /// [0, 1]) degrades the decision down the accuracy ladder instead of
    /// letting work queue unboundedly.
    ///
    /// `mode` gates job creation (see [`ScheduleMode`]): the server
    /// passes `Never` while an up-to-date recompute is already in
    /// flight — the decision is still recorded (and served degraded)
    /// but no second job is created — and `ExactOnly` when a stale
    /// in-flight job is worth superseding.
    pub fn query_async(
        &mut self,
        policy: &StalenessPolicy,
        pressure: f64,
        mode: ScheduleMode,
    ) -> Result<(AsyncQueryResult, Option<RecomputeJob>)> {
        if self.stopped {
            return Err(Error::Engine("engine is stopped".into()));
        }
        self.query_count += 1;
        let query_id = self.query_count;
        if !self.buffer.is_empty() {
            self.apply_pending_batch();
        }
        let ranks_len_before = self.ranks.len();
        self.extend_ranks_for_new_vertices();
        let ranks_grew = self.ranks.len() != ranks_len_before;
        let age_secs = self.last_publish.elapsed().as_secs_f64();
        self.metrics.set("snapshot_age_secs", age_secs);
        self.metrics.set("snapshot_age_queries", self.queries_since_publish as f64);
        let decision = policy.decide_under_pressure(
            self.updates_since_refresh,
            self.queries_since_publish,
            age_secs,
            pressure,
        );
        self.metrics.inc("queries", 1);
        self.metrics.inc("async_queries", 1);
        self.metrics.inc(
            match decision {
                Action::RepeatLast => "decision_repeat-last",
                Action::ComputeApproximate => "decision_approximate",
                Action::ComputeExact => "decision_exact",
            },
            1,
        );
        self.queries_since_exact += 1;
        self.queries_since_publish += 1;
        let may_schedule = match mode {
            ScheduleMode::Never => false,
            ScheduleMode::WhenDue => decision != Action::RepeatLast,
            ScheduleMode::ExactOnly => decision == Action::ComputeExact,
        };
        let job =
            if may_schedule { Some(self.begin_recompute(decision, query_id)) } else { None };
        // The answer itself always repeats the published ranking (the
        // recompute, if any, publishes later from the worker's result).
        let exec = ExecStats::default();
        let snapshot = self.publish_result(query_id, Action::RepeatLast, &exec, false, ranks_grew);
        let scheduled = job.is_some();
        Ok((AsyncQueryResult { query_id, decision, scheduled, snapshot }, job))
    }

    /// Integrate an off-thread recompute back into the engine and
    /// publish it. `fence_ok` reports whether the fence held (the graph
    /// did not move while the job ran) and the result installed
    /// verbatim; on a fence miss the fenced ranking is merged by vertex
    /// id into the live rank vector — internally consistent, never
    /// regressing topology for readers — and, when the armed fence log
    /// is clean, the post-fence ops replay as a first-order rank
    /// correction (`reconciled`), so the miss does not demote the
    /// publish. Jobs that corrected nothing (empty summary) restore the
    /// carry state they consumed and publish nothing.
    pub fn finish_recompute(&mut self, res: RecomputeResult) -> RecomputeOutcome {
        self.metrics.inc("recomputes_offthread", 1);
        self.metrics.time("recompute_offthread_secs", res.exec.elapsed_secs);
        let log = self.fence_log.take();
        if !res.refreshed {
            self.metrics.inc("recomputes_empty", 1);
            if let Some((prev_degree, new_vertices)) = res.carry_back {
                for (id, d) in prev_degree {
                    self.carry_prev_degree.entry(id).or_insert(d);
                }
                let known: HashSet<VertexId> = self.carry_new_vertices.iter().copied().collect();
                for v in new_vertices {
                    if !known.contains(&v) {
                        self.carry_new_vertices.push(v);
                    }
                }
            }
            self.updates_since_refresh += res.accounted_updates;
            return RecomputeOutcome { fence_ok: false, reconciled: false };
        }
        let fence_ok = res.graph_version == self.graph.version();
        let mut reconciled = false;
        self.last_hot_set = res.hot_set;
        if fence_ok {
            self.ranks = res.ranks;
        } else {
            self.extend_ranks_for_new_vertices();
            for (id, r) in res.ids.iter().zip(&res.ranks) {
                if let Some(idx) = self.graph.index(*id) {
                    self.ranks[idx as usize] = *r;
                }
            }
            match log {
                Some(log)
                    if self.reconcile
                        && !log.tainted
                        && log.from_version == res.graph_version =>
                {
                    self.reconcile_touched(&log.ops);
                    self.metrics.inc("recomputes_reconciled", 1);
                    reconciled = true;
                }
                _ => {
                    self.metrics.inc("recompute_fence_misses", 1);
                }
            }
        }
        if res.action == Action::ComputeExact {
            self.queries_since_exact = 0;
        }
        self.metrics.inc(
            match res.action {
                Action::ComputeApproximate => "action_approximate",
                _ => "action_exact",
            },
            1,
        );
        self.metrics.set("last_summary_vertices", res.exec.summary_vertices as f64);
        self.metrics.set("last_summary_edges", res.exec.summary_edges as f64);
        self.publish_snapshot(res.query_id, res.action, res.exec, None);
        RecomputeOutcome { fence_ok, reconciled }
    }

    /// Replay post-fence ops as a first-order rank correction: every
    /// vertex whose in-mass an op changed (endpoints plus the source's
    /// current out-neighbors, whose per-edge share moved with the
    /// out-degree) gets one gather
    /// `teleport + β·Σ_{w∈in(v)} r_w / d_out(w) + dangling-share`
    /// from a frozen base; writes land after the sweep so the pass is
    /// order-independent.
    fn reconcile_touched(&mut self, ops: &[EdgeOp]) {
        use std::collections::BTreeSet;
        let mut touched: BTreeSet<VertexId> = BTreeSet::new();
        for op in ops {
            match *op {
                EdgeOp::AddEdge(u, d) | EdgeOp::RemoveEdge(u, d) => {
                    touched.insert(u);
                    touched.insert(d);
                    if let Some(ui) = self.graph.index(u) {
                        for &w in self.graph.out_neighbors(ui) {
                            touched.insert(self.graph.id(w));
                        }
                    }
                }
                EdgeOp::AddVertex(v) => {
                    touched.insert(v);
                }
                EdgeOp::RemoveVertex(_) => unreachable!("tainted fence log reached reconciliation"),
            }
        }
        let n = self.graph.num_vertices();
        if touched.is_empty() || n == 0 {
            return;
        }
        let mut dangling_mass = 0.0;
        for u in 0..n as VertexIdx {
            if self.graph.out_degree(u) == 0 {
                dangling_mass += self.ranks[u as usize];
            }
        }
        let cfg = &self.pr_config;
        let teleport = cfg.teleport(n);
        let share =
            if cfg.dangling_redistribution { cfg.beta * dangling_mass / n as f64 } else { 0.0 };
        let mut fixes: Vec<(VertexIdx, f64)> = Vec::with_capacity(touched.len());
        for &vid in &touched {
            let Some(idx) = self.graph.index(vid) else {
                continue; // coalesced away before the fence resolved
            };
            let mut in_mass = 0.0;
            for &w in self.graph.in_neighbors(idx) {
                let d = self.graph.out_degree(w);
                if d > 0 {
                    in_mass += self.ranks[w as usize] / d as f64;
                }
            }
            fixes.push((idx, teleport + cfg.beta * in_mass + share));
        }
        let fixed = fixes.len() as u64;
        for (idx, x) in fixes {
            self.ranks[idx as usize] = x;
        }
        self.metrics.inc("reconciled_vertices", fixed);
    }

    /// Capture a version-fenced [`RecomputeJob`] for `decision`, taking
    /// ownership of the staleness signals it accounts for: the carry
    /// state moves into the job and `updates_since_refresh` resets, so
    /// updates applied after this fence accumulate toward the *next*
    /// recompute.
    fn begin_recompute(&mut self, decision: Action, query_id: u64) -> RecomputeJob {
        let accounted_updates = self.updates_since_refresh;
        self.updates_since_refresh = 0;
        let approx = if decision == Action::ComputeApproximate {
            Some(ApproxInputs {
                graph: self.graph.clone(),
                params: self.params,
                prev_degree: std::mem::take(&mut self.carry_prev_degree),
                new_vertices: std::mem::take(&mut self.carry_new_vertices),
            })
        } else {
            self.carry_prev_degree.clear();
            self.carry_new_vertices.clear();
            None
        };
        let csr = if decision == Action::ComputeExact {
            let shards = match self.pool.as_deref() {
                Some(pool) => self.pr_config.effective_shards(pool),
                None => 1,
            };
            let (csr, build) = self.snapshot.get(&self.graph, self.pool.as_deref(), shards);
            self.metrics.inc(
                match build {
                    SnapshotBuild::CacheHit => "snapshot_cache_hits",
                    SnapshotBuild::Incremental => "snapshot_builds_incremental",
                    SnapshotBuild::Full => "snapshot_builds_full",
                },
                1,
            );
            Some(csr)
        } else {
            None
        };
        self.metrics.inc("recomputes_scheduled", 1);
        if self.reconcile {
            self.fence_log = Some(FenceLog {
                from_version: self.graph.version(),
                ops: Vec::new(),
                tainted: false,
            });
        }
        RecomputeJob {
            decision,
            query_id,
            graph_version: self.graph.version(),
            accounted_updates,
            ids: self.graph.ids().to_vec(),
            warm_ranks: self.ranks.clone(),
            pr_config: self.pr_config,
            csr,
            approx,
        }
    }

    /// Consume a prepared event stream, returning one result per query.
    /// Runs of consecutive ops ride the batch path: they are registered
    /// as one [`Self::ingest_batch`] per run and coalesced at the next
    /// query's apply step.
    pub fn run_stream(
        &mut self,
        events: impl IntoIterator<Item = UpdateEvent>,
    ) -> Result<Vec<QueryResult>> {
        let mut out = Vec::new();
        self.run_stream_with(events, |_, r| {
            out.push(r);
            Ok(())
        })?;
        Ok(out)
    }

    /// [`Self::run_stream`] with a per-query callback instead of a
    /// collected vec — the one batching loop the replay harness and the
    /// collecting variant both ride (op runs → `ingest_batch` → query).
    /// The callback sees the engine (post-query) alongside each result.
    /// Trailing ops after the last query stay buffered, as before.
    pub fn run_stream_with(
        &mut self,
        events: impl IntoIterator<Item = UpdateEvent>,
        mut on_result: impl FnMut(&Engine, QueryResult) -> Result<()>,
    ) -> Result<()> {
        let mut pending: Vec<EdgeOp> = Vec::new();
        for ev in events {
            match ev {
                UpdateEvent::Op(op) => pending.push(op),
                UpdateEvent::Query => {
                    if !pending.is_empty() {
                        self.ingest_batch(std::mem::take(&mut pending));
                    }
                    let r = self.query()?;
                    on_result(self, r)?;
                }
                UpdateEvent::Stop => break,
            }
        }
        if !pending.is_empty() {
            self.ingest_batch(pending);
        }
        Ok(())
    }

    /// Toggle fence reconciliation (on by default). Off restores the
    /// pre-reconciliation behavior: a fence miss merges by id and
    /// counts a `recompute_fence_misses`.
    pub fn set_reconcile(&mut self, on: bool) {
        self.reconcile = on;
        if !on {
            self.fence_log = None;
        }
    }

    /// Stop the engine (Alg. 1 `OnStop`); further queries error.
    pub fn stop(&mut self) {
        if !self.stopped {
            self.udf.on_stop();
            self.stopped = true;
        }
    }

    // ---- internals -----------------------------------------------------

    /// Run the exact power method (warm-started) and install the ranks.
    /// The CSR comes from the version-keyed snapshot cache — a repeat
    /// query on an unmutated graph performs zero CSR allocations, and
    /// rebuilds are incremental + sharded across the engine's pool.
    /// Returns iterations executed.
    fn compute_exact(&mut self) -> usize {
        let shards = match self.pool.as_deref() {
            Some(pool) => self.pr_config.effective_shards(pool),
            None => 1,
        };
        let (csr, build) = self.snapshot.get(&self.graph, self.pool.as_deref(), shards);
        self.metrics.inc(
            match build {
                SnapshotBuild::CacheHit => "snapshot_cache_hits",
                SnapshotBuild::Incremental => "snapshot_builds_incremental",
                SnapshotBuild::Full => "snapshot_builds_full",
            },
            1,
        );
        let pr = PageRank::new(self.pr_config);
        self.extend_ranks_for_new_vertices();
        let warm = self.pr_config.warm_start_exact
            && self.ranks.len() == csr.num_vertices()
            && !self.ranks.is_empty();
        let res = match (self.pool.as_deref(), warm) {
            (Some(pool), true) => pr.run_parallel_from(&csr, self.ranks.clone(), pool),
            (Some(pool), false) => pr.run_parallel(&csr, pool),
            (None, true) => pr.run_from(&csr, self.ranks.clone()),
            (None, false) => pr.run(&csr),
        };
        self.ranks = res.ranks;
        res.iterations
    }

    /// Build the hot set + summary graph for the current carry state —
    /// both stages sharded over the engine pool and drawing all O(|V|)
    /// working state from the engine's [`SummaryScratch`]. The hot
    /// bitmap is recycled before returning; stage timings and |K| /
    /// |E_K| / |E_B| gauges land in the metrics registry.
    fn build_summary(&mut self) -> SummaryGraph {
        let shards = match self.pool.as_deref() {
            Some(pool) => self.pr_config.effective_shards(pool),
            None => 1,
        };
        let pool = self.pool.as_deref();
        let sw = Stopwatch::start();
        let inputs = HotSetInputs {
            graph: &self.graph,
            prev_degree: &self.carry_prev_degree,
            new_vertices: &self.carry_new_vertices,
            prev_ranks: &self.ranks,
        };
        let hot = compute_hot_set_pooled(&inputs, &self.params, &mut self.scratch, pool, shards);
        let hot_secs = sw.secs();
        let sw = Stopwatch::start();
        let default = self.pr_config.init_rank(self.graph.num_vertices());
        let summary = SummaryGraph::build_pooled(
            &self.graph,
            &hot,
            &self.ranks,
            default,
            &mut self.scratch,
            pool,
            shards,
        );
        let build_secs = sw.secs();
        let hot_ids: Vec<VertexId> =
            hot.all().into_iter().map(|i| self.graph.id(i)).collect();
        self.last_hot_set = hot_ids;
        self.scratch.recycle_hot(hot);
        self.metrics.time("summary_hot_set_secs", hot_secs);
        self.metrics.time("summary_build_secs", build_secs);
        self.metrics.set("last_hot_set_size", summary.num_vertices() as f64);
        self.metrics.set("last_summary_internal_edges", summary.num_internal_edges() as f64);
        self.metrics.set("last_summary_boundary_edges", summary.num_boundary_edges as f64);
        self.summary_totals.builds += 1;
        self.summary_totals.last_hot_vertices = summary.num_vertices();
        self.summary_totals.last_internal_edges = summary.num_internal_edges();
        self.summary_totals.last_boundary_edges = summary.num_boundary_edges;
        summary
    }

    /// Grow the rank vector with teleport-level defaults when the graph
    /// gained vertices.
    fn extend_ranks_for_new_vertices(&mut self) {
        let n = self.graph.num_vertices();
        if self.ranks.len() < n {
            self.ranks.resize(n, self.pr_config.init_rank(n));
        }
    }

    /// Freeze the current ranking into a freshly produced published
    /// snapshot (one O(|V|) copy + O(n log n) index build, then atomic
    /// swap) and reset the staleness anchors.
    fn publish_now(&mut self, query_id: u64, action: Action, exec: ExecStats) -> Arc<RankSnapshot> {
        self.publish_snapshot(query_id, action, exec, None)
    }

    /// The one publish path. `carry_age_from` distinguishes a genuine
    /// recompute (None: the ranking is fresh, staleness anchors reset)
    /// from a republish forced by topology alone (Some: the served ranks
    /// are as old as they ever were, so the new snapshot inherits the
    /// previous age anchor and the age gauges keep growing).
    fn publish_snapshot(
        &mut self,
        query_id: u64,
        action: Action,
        exec: ExecStats,
        carry_age_from: Option<std::time::Instant>,
    ) -> Arc<RankSnapshot> {
        if let Some(inj) = self.faults.as_ref() {
            if inj.take_crash(CrashPoint::PrePublish) {
                // Injected crash: the recompute finished and the WAL
                // holds every applied batch, but the publish never
                // happens — readers keep the previous snapshot, exactly
                // as after a real crash here. Recovery reconstructs the
                // unpublished state from snapshot + tail replay.
                eprintln!("[veilgraph] injected crash: pre-publish");
                self.stopped = true;
                return self.published.latest();
            }
        }
        let version = self.published.latest().version + 1;
        let mut snap = RankSnapshot::new(
            version,
            self.graph.version(),
            query_id,
            action,
            exec,
            self.graph.ids().to_vec(),
            self.ranks.clone(),
            self.published_top_k,
            self.metrics.to_json(),
        );
        snap.set_hot_set(self.last_hot_set.clone());
        if let Some(at) = carry_age_from {
            snap.published_at = at;
        } else {
            self.queries_since_publish = 0;
        }
        self.last_publish = snap.published_at;
        let snap = Arc::new(snap);
        self.published.publish(Arc::clone(&snap));
        snap
    }

    /// Publish after a query — or, when neither the ranking nor the graph
    /// moved (repeat-last / empty-summary queries), hand back the already
    /// published snapshot so the whole query is allocation-free.
    fn publish_result(
        &mut self,
        query_id: u64,
        action: Action,
        exec: &ExecStats,
        ranks_refreshed: bool,
        ranks_grew: bool,
    ) -> Arc<RankSnapshot> {
        let latest = self.published.latest();
        if latest.version > 0
            && !ranks_refreshed
            && !ranks_grew
            && latest.graph_version == self.graph.version()
        {
            return latest;
        }
        // Republished-but-stale ranks (repeat-last after an applied batch,
        // or a rank vector merely extended for new vertices: readers must
        // see the new topology, but no recompute happened) keep their age
        // anchor — otherwise a steady update trickle would pin the
        // staleness gauges at zero and starve `StalenessPolicy`'s age
        // escalation.
        let carry = if !ranks_refreshed && latest.version > 0 {
            Some(latest.published_at)
        } else {
            None
        };
        self.publish_snapshot(query_id, action, exec.clone(), carry)
    }

    // ---- accessors -----------------------------------------------------

    /// The current graph.
    pub fn graph(&self) -> &DynamicGraph {
        &self.graph
    }

    /// The current full rank vector (dense index order).
    pub fn ranks(&self) -> &[f64] {
        &self.ranks
    }

    /// The latest published snapshot (equals [`Self::ranks`] at the same
    /// version — the read path's view of this engine).
    pub fn latest_snapshot(&self) -> Arc<RankSnapshot> {
        self.published.latest()
    }

    /// A read-only handle onto this engine's published snapshots,
    /// cloneable across any number of reader threads. Readers never
    /// block on (or wait for) the engine.
    pub fn reader(&self) -> SnapshotReader {
        self.published.reader()
    }

    /// Top-K entries pre-ranked per published snapshot.
    pub fn published_top_k(&self) -> usize {
        self.published_top_k
    }

    /// Engine metrics.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Model parameters.
    pub fn params(&self) -> SummaryParams {
        self.params
    }

    /// Configured shard knob for the PageRank executors (`1` = serial,
    /// `0` = auto: one shard per worker of the engine's pool).
    pub fn parallelism(&self) -> usize {
        self.pr_config.parallelism
    }

    /// Snapshot-pipeline counters (hits / incremental / full builds).
    pub fn snapshot_stats(&self) -> SnapshotStats {
        self.snapshot.stats()
    }

    /// Summary-pipeline counters: builds served, the last build's |K| /
    /// |E_K| / |E_B|, and the scratch growth/reuse evidence that
    /// steady-state summarized queries allocate nothing O(|V|)-sized.
    pub fn summary_stats(&self) -> SummaryStats {
        SummaryStats { scratch: self.scratch.stats(), ..self.summary_totals }
    }

    /// Number of queries served.
    pub fn query_count(&self) -> u64 {
        self.query_count
    }

    /// Whether the XLA backend is attached.
    pub fn has_xla(&self) -> bool {
        self.executor.has_xla()
    }

    // ---- durability ----------------------------------------------------

    /// Apply any pending (coalesced) updates now, without serving a
    /// query — graceful shutdown and the ingest benches use this to
    /// drive the WAL + apply path directly.
    pub fn flush_pending(&mut self) {
        if !self.buffer.is_empty() {
            self.apply_pending_batch();
        }
    }

    /// Shared durability gauges (always present; they report
    /// `enabled: false` when the engine runs without a WAL).
    pub fn durability_stats(&self) -> Arc<DurabilityStats> {
        Arc::clone(&self.durability)
    }

    /// Whether this engine runs with a WAL and checkpoints.
    pub fn durable(&self) -> bool {
        self.dur_dir.is_some()
    }

    /// The window admission state recovered from the loaded checkpoint
    /// (one-shot; the server claims it when rebuilding its window
    /// stage under a fresh epoch).
    pub fn take_recovered_window(&mut self) -> Option<WindowState> {
        self.recovered_window.take()
    }

    /// Whether enough batches have applied since the last checkpoint to
    /// cut a new one (and none is already in flight).
    pub fn checkpoint_due(&self) -> bool {
        self.dur_dir.is_some()
            && !self.checkpoint_in_flight
            && self.applies_since_checkpoint >= self.dur_checkpoint_every
    }

    /// Freeze the engine state into an off-thread [`CheckpointJob`].
    /// `window` is the serving layer's admission state, exported by the
    /// caller (the engine does not own the window stage). Returns
    /// `None` without durability or while a checkpoint is in flight.
    pub fn begin_checkpoint(&mut self, window: Option<WindowState>) -> Option<CheckpointJob> {
        let dir = self.dur_dir.clone()?;
        if self.checkpoint_in_flight {
            return None;
        }
        self.checkpoint_in_flight = true;
        self.applies_since_checkpoint = 0;
        // Applies since the last recompute may have added vertices the
        // rank vector does not cover yet; a snapshot must be internally
        // consistent (one rank per vertex), so extend with the same
        // teleport-level defaults a recompute would use.
        self.extend_ranks_for_new_vertices();
        Some(CheckpointJob {
            dir,
            keep: self.dur_keep,
            image: self.capture_image(window, false),
            faults: self.faults.clone(),
            stats: Arc::clone(&self.durability),
        })
    }

    /// Integrate a finished checkpoint: clear the in-flight flag and,
    /// on success, drop WAL segments the snapshot made redundant.
    pub fn finish_checkpoint(&mut self, outcome: CheckpointOutcome) {
        self.checkpoint_in_flight = false;
        if outcome.ok {
            if let Some(wal) = self.wal.as_mut() {
                wal.prune_up_to(outcome.wal_seq);
            }
        } else if let Some(e) = outcome.err {
            eprintln!("[veilgraph] checkpoint failed: {e}");
        }
    }

    /// Graceful-shutdown persistence: flush pending updates through the
    /// WAL + apply path, fsync the log, then write a final checkpoint
    /// marked clean, synchronously — recovery after this replays
    /// nothing. No-op without durability.
    pub fn shutdown_durable(&mut self, window: Option<WindowState>) {
        let Some(dir) = self.dur_dir.clone() else { return };
        self.flush_pending();
        self.extend_ranks_for_new_vertices();
        if let Some(wal) = self.wal.as_mut() {
            if let Err(e) = wal.sync() {
                eprintln!("[veilgraph] final wal sync failed: {e}");
            }
        }
        let job = CheckpointJob {
            dir,
            keep: self.dur_keep,
            image: self.capture_image(window, true),
            faults: self.faults.clone(),
            stats: Arc::clone(&self.durability),
        };
        let out = job.run();
        if let Some(e) = out.err {
            eprintln!("[veilgraph] final checkpoint failed: {e}");
        }
        if out.ok {
            if let Some(wal) = self.wal.as_mut() {
                wal.prune_up_to(out.wal_seq);
            }
        }
        self.checkpoint_in_flight = false;
    }

    /// Freeze everything one checkpoint captures (cheap clones on the
    /// engine thread; the dump itself runs off-thread).
    fn capture_image(&self, window: Option<WindowState>, clean: bool) -> CheckpointImage {
        CheckpointImage {
            graph: self.graph.clone(),
            ranks: self.ranks.clone(),
            query_count: self.query_count,
            graph_version: self.graph.version(),
            wal_seq: self.wal.as_ref().map(|w| w.next_seq() - 1).unwrap_or(0),
            clean_shutdown: clean,
            window,
            durable_subs: self.published.subscriptions().durable_records(),
        }
    }

    /// Persist graph + ranks + query counter (see
    /// [`crate::coordinator::checkpoint`]); pending (unapplied) updates
    /// are NOT captured — drain them with a query first or re-ingest
    /// after restore.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        if !self.buffer.is_empty() {
            return Err(Error::Engine(format!(
                "{} pending updates not applied — query() before checkpointing",
                self.buffer.len()
            )));
        }
        crate::coordinator::checkpoint::save(path, &self.graph, &self.ranks, self.query_count)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policies::{AlwaysExact, PeriodicExactPolicy};
    use crate::metrics::rbo::rbo_ext;

    fn ring(n: u64) -> Vec<(u64, u64)> {
        (0..n).map(|i| (i, (i + 1) % n)).collect()
    }

    #[test]
    fn builder_runs_initial_exact() {
        let e = EngineBuilder::new().build_from_edges(ring(10)).unwrap();
        assert_eq!(e.ranks().len(), 10);
        // Unnormalized (Gelly) variant: a symmetric ring converges to 1.0
        // per vertex (teleport (1-β) + β·1 = 1).
        for &r in e.ranks() {
            assert!((r - 1.0).abs() < 1e-6, "ring rank {r}");
        }
        assert!(!e.has_xla());
    }

    #[test]
    fn query_without_updates_returns_same_ranks() {
        let mut e = EngineBuilder::new().build_from_edges(ring(10)).unwrap();
        let before = e.ranks().to_vec();
        let r = e.query().unwrap();
        assert_eq!(r.action, Action::ComputeApproximate);
        assert_eq!(r.exec.summary_vertices, 0, "no updates ⇒ empty hot set");
        assert_eq!(r.ranks(), &before[..]);
    }

    #[test]
    fn approximate_query_tracks_exact_closely() {
        // Skewed (preferential-attachment) graph so the ranking is
        // meaningful (a ring is all-ties and RBO is noise); stream in a
        // handful of edges and compare against the exact ground truth.
        let base = crate::graph::generate::barabasi_albert(300, 3, 0.3, 42);
        let mut approx = EngineBuilder::new()
            .params(SummaryParams::new(0.1, 1, 0.1))
            .build_from_edges(base.iter().copied())
            .unwrap();
        let mut exact = EngineBuilder::new()
            .udf(Box::new(AlwaysExact))
            .build_from_edges(base.iter().copied())
            .unwrap();
        let updates: Vec<EdgeOp> =
            (0..15u64).map(|i| EdgeOp::add(200 + i, (i * 7 + 3) % 50)).collect();
        approx.ingest_many(updates.clone());
        exact.ingest_many(updates);
        let ra = approx.query().unwrap();
        let re = exact.query().unwrap();
        assert_eq!(ra.action, Action::ComputeApproximate);
        assert!(ra.exec.summary_vertices > 0);
        assert!(
            ra.exec.summary_vertices < approx.graph().num_vertices(),
            "summary must be a strict subset"
        );
        let rbo = rbo_ext(&ra.top_ids(50), &re.top_ids(50), 0.98);
        assert!(rbo > 0.9, "rbo {rbo}");
    }

    #[test]
    fn new_vertices_get_ranks() {
        let mut e = EngineBuilder::new().build_from_edges(ring(5)).unwrap();
        e.ingest(EdgeOp::add(100, 0));
        e.ingest(EdgeOp::add(101, 100));
        let r = e.query().unwrap();
        assert_eq!(r.ids().len(), 7);
        assert_eq!(r.ranks().len(), 7);
        assert!(r.ranks().iter().all(|&x| x > 0.0));
    }

    #[test]
    fn periodic_policy_resets_exact_counter() {
        let mut e = EngineBuilder::new()
            .udf(Box::new(PeriodicExactPolicy::new(2)))
            .build_from_edges(ring(10))
            .unwrap();
        let mut actions = Vec::new();
        for i in 0..4 {
            e.ingest(EdgeOp::add(i, (i + 5) % 10));
            actions.push(e.query().unwrap().action);
        }
        assert_eq!(
            actions,
            vec![
                Action::ComputeApproximate,
                Action::ComputeExact,
                Action::ComputeApproximate,
                Action::ComputeExact
            ]
        );
    }

    #[test]
    fn repeat_last_preserves_degree_baseline_for_next_query() {
        // Policy: repeat on first query, approximate on second. The degree
        // baseline from query 1's applied updates must still be visible at
        // query 2, otherwise the hot set is empty and accuracy collapses.
        struct RepeatOnce(u32);
        impl UdfSuite for RepeatOnce {
            fn on_query(&mut self, _: &QueryContext) -> Action {
                self.0 += 1;
                if self.0 == 1 {
                    Action::RepeatLast
                } else {
                    Action::ComputeApproximate
                }
            }
        }
        let mut e = EngineBuilder::new()
            .params(SummaryParams::new(0.1, 0, 9.0))
            .udf(Box::new(RepeatOnce(0)))
            .build_from_edges(ring(20))
            .unwrap();
        e.ingest(EdgeOp::add(0, 10)); // changes degrees of 0 and 10
        let r1 = e.query().unwrap();
        assert_eq!(r1.action, Action::RepeatLast);
        // no new updates before the second query
        let r2 = e.query().unwrap();
        assert_eq!(r2.action, Action::ComputeApproximate);
        assert!(r2.exec.summary_vertices > 0, "carry-over baseline must trigger K_r");
    }

    #[test]
    fn exact_clears_carry_state() {
        let mut e = EngineBuilder::new()
            .udf(Box::new(AlwaysExact))
            .build_from_edges(ring(10))
            .unwrap();
        e.ingest(EdgeOp::add(0, 5));
        let _ = e.query().unwrap();
        // Next approximate-style summary would be empty — verify via metrics
        assert_eq!(e.metrics().counter("action_exact"), 1);
        assert_eq!(e.queries_since_exact, 0);
    }

    #[test]
    fn run_stream_serves_all_queries() {
        let mut e = EngineBuilder::new().build_from_edges(ring(20)).unwrap();
        let events = vec![
            UpdateEvent::Op(EdgeOp::add(0, 7)),
            UpdateEvent::Query,
            UpdateEvent::Op(EdgeOp::add(3, 11)),
            UpdateEvent::Op(EdgeOp::add(4, 12)),
            UpdateEvent::Query,
            UpdateEvent::Stop,
        ];
        let results = e.run_stream(events).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[1].query_id, 2);
        assert_eq!(e.metrics().counter("queries"), 2);
    }

    #[test]
    fn stopped_engine_rejects_queries() {
        let mut e = EngineBuilder::new().build_from_edges(ring(5)).unwrap();
        e.stop();
        assert!(e.query().is_err());
    }

    #[test]
    fn checkpoint_roundtrip_resumes_identically() {
        let p = std::env::temp_dir().join(format!("vg-engine-ckpt-{}", std::process::id()));
        let mut e = EngineBuilder::new().build_from_edges(ring(30)).unwrap();
        e.ingest(EdgeOp::add(0, 15));
        let r1 = e.query().unwrap();
        e.save_checkpoint(&p).unwrap();
        let mut resumed = EngineBuilder::new().build_from_checkpoint(&p).unwrap();
        assert_eq!(resumed.query_count(), e.query_count());
        assert_eq!(resumed.ranks(), e.ranks());
        // both engines serve the same next query
        resumed.ingest(EdgeOp::add(1, 16));
        e.ingest(EdgeOp::add(1, 16));
        let a = resumed.query().unwrap();
        let b = e.query().unwrap();
        assert_eq!(a.query_id, b.query_id);
        assert_eq!(a.ranks(), b.ranks());
        let _ = r1;
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn checkpoint_refuses_pending_updates() {
        let p = std::env::temp_dir().join(format!("vg-engine-ckpt2-{}", std::process::id()));
        let mut e = EngineBuilder::new().build_from_edges(ring(5)).unwrap();
        e.ingest(EdgeOp::add(0, 3));
        assert!(e.save_checkpoint(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn parallel_engine_matches_serial_engine() {
        // Same stream through a serial and a 4-shard engine: every query
        // must produce identical actions and matching ranks — the sharded
        // executors change the schedule, never the numbers. (Tolerance
        // 1e-12: the per-iteration values are bit-identical, but the L1
        // convergence delta reduces in a different order, so the stopping
        // iteration may differ by one right at the epsilon boundary.)
        fn assert_close(a: &[f64], b: &[f64], what: &str) {
            assert_eq!(a.len(), b.len(), "{what}");
            let linf = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
            assert!(linf < 1e-12, "{what}: L∞ {linf}");
        }
        let base = crate::graph::generate::barabasi_albert(200, 3, 0.3, 17);
        // Fixed iteration budget (epsilon = 0) ⇒ serial and parallel run
        // the same iteration count, so ranks are bit-identical and the
        // tolerance below is belt-and-suspenders.
        let cfg0 = PageRankConfig { epsilon: 0.0, max_iters: 60, ..Default::default() };
        let mut serial = EngineBuilder::new()
            .params(SummaryParams::new(0.1, 1, 0.1))
            .pagerank(cfg0)
            .build_from_edges(base.iter().copied())
            .unwrap();
        let mut parallel = EngineBuilder::new()
            .params(SummaryParams::new(0.1, 1, 0.1))
            .pagerank(cfg0)
            .parallelism(4)
            .build_from_edges(base.iter().copied())
            .unwrap();
        assert_close(serial.ranks(), parallel.ranks(), "initial exact run");
        for round in 0..3u64 {
            let ops: Vec<EdgeOp> =
                (0..12).map(|i| EdgeOp::add(150 + round * 12 + i, (i * 11 + round) % 60)).collect();
            serial.ingest_many(ops.clone());
            parallel.ingest_many(ops);
            let rs = serial.query().unwrap();
            let rp = parallel.query().unwrap();
            assert_eq!(rs.action, rp.action, "round {round}");
            assert_close(rs.ranks(), rp.ranks(), &format!("round {round}"));
        }
        // Exact recomputation (warm-started) also goes through the pool.
        let mut exact_parallel = EngineBuilder::new()
            .udf(Box::new(AlwaysExact))
            .pagerank(cfg0)
            .parallelism(0) // auto-size
            .build_from_edges(base.iter().copied())
            .unwrap();
        let mut exact_serial = EngineBuilder::new()
            .udf(Box::new(AlwaysExact))
            .pagerank(cfg0)
            .build_from_edges(base.iter().copied())
            .unwrap();
        exact_parallel.ingest(EdgeOp::add(3, 141));
        exact_serial.ingest(EdgeOp::add(3, 141));
        let a = exact_parallel.query().unwrap();
        let b = exact_serial.query().unwrap();
        assert_close(a.ranks(), b.ranks(), "warm-started exact");
    }

    #[test]
    fn snapshot_cache_serves_repeated_exact_queries() {
        let mut e = EngineBuilder::new()
            .udf(Box::new(AlwaysExact))
            .build_from_edges(ring(12))
            .unwrap();
        // the initial complete execution built the snapshot once
        assert_eq!(e.snapshot_stats().full, 1);
        let _ = e.query().unwrap(); // no pending updates ⇒ cache hit
        let _ = e.query().unwrap();
        let s = e.snapshot_stats();
        assert_eq!((s.full, s.incremental, s.hits), (1, 0, 2));
        assert_eq!(e.metrics().counter("snapshot_cache_hits"), 2);
        e.ingest(EdgeOp::add(0, 6));
        let _ = e.query().unwrap(); // mutation ⇒ incremental rebuild
        let s = e.snapshot_stats();
        assert_eq!((s.full, s.incremental, s.hits), (1, 1, 2));
        assert_eq!(e.metrics().counter("snapshot_builds_incremental"), 1);
        assert_eq!(e.metrics().counter("snapshot_builds_full"), 1);
    }

    #[test]
    fn summary_metrics_and_stats_surface() {
        let mut e = EngineBuilder::new()
            .params(SummaryParams::new(0.1, 1, 9.0))
            .build_from_edges(ring(12))
            .unwrap();
        assert_eq!(e.summary_stats().builds, 0, "initial exact run builds no summary");
        e.ingest(EdgeOp::add(0, 6));
        let r = e.query().unwrap();
        assert_eq!(r.action, Action::ComputeApproximate);
        assert!(r.exec.summary_vertices > 0);
        let s = e.summary_stats();
        assert_eq!(s.builds, 1);
        assert_eq!(s.last_hot_vertices, r.exec.summary_vertices);
        assert_eq!(s.last_internal_edges + s.last_boundary_edges, r.exec.summary_edges);
        assert!(e.metrics().timing("summary_hot_set_secs").is_some());
        assert!(e.metrics().timing("summary_build_secs").is_some());
        assert!(e.metrics().timing("summary_merge_secs").is_some());
        assert_eq!(e.metrics().gauge("last_hot_set_size"), Some(s.last_hot_vertices as f64));
        assert_eq!(
            e.metrics().gauge("last_summary_internal_edges"),
            Some(s.last_internal_edges as f64)
        );
        assert_eq!(
            e.metrics().gauge("last_summary_boundary_edges"),
            Some(s.last_boundary_edges as f64)
        );
    }

    #[test]
    fn scratch_reuse_is_allocation_free_after_first_summarized_query() {
        let mut e = EngineBuilder::new()
            .params(SummaryParams::new(0.1, 1, 0.5))
            .build_from_edges(ring(16))
            .unwrap();
        // Query 1 with updates among EXISTING vertices sizes the scratch.
        e.ingest(EdgeOp::add(0, 8));
        let _ = e.query().unwrap();
        let after_first = e.summary_stats().scratch;
        assert!(after_first.grown > 0, "first query must size the scratch");
        // Steady state: more mutations + queries over the same vertex
        // set reuse every buffer — `grown` must not move.
        for i in 0..4u64 {
            e.ingest(EdgeOp::add(i + 1, (i + 9) % 16));
            let _ = e.query().unwrap();
        }
        // A query on an unchanged graph (empty hot set) reuses too.
        let _ = e.query().unwrap();
        let s = e.summary_stats().scratch;
        assert_eq!(s.grown, after_first.grown, "steady state must not allocate");
        assert!(s.reused > after_first.reused);
        // New vertices grow the graph — and only then may the scratch grow.
        e.ingest(EdgeOp::add(100, 0));
        let _ = e.query().unwrap();
        assert!(e.summary_stats().scratch.grown > after_first.grown);
    }

    #[test]
    fn shared_pool_engine_matches_owned_pool_engine() {
        // One pool driven by two engines (sequentially here; the harness
        // does it concurrently) must not change any numbers vs an engine
        // that owns its pool.
        let pool = std::sync::Arc::new(ThreadPool::new(4));
        let cfg0 = PageRankConfig { epsilon: 0.0, max_iters: 40, ..Default::default() };
        let base = crate::graph::generate::barabasi_albert(150, 3, 0.3, 5);
        let mut shared = EngineBuilder::new()
            .pagerank(cfg0)
            .parallelism(4)
            .shared_pool(std::sync::Arc::clone(&pool))
            .build_from_edges(base.iter().copied())
            .unwrap();
        let mut owned = EngineBuilder::new()
            .pagerank(cfg0)
            .parallelism(4)
            .build_from_edges(base.iter().copied())
            .unwrap();
        assert_eq!(shared.ranks(), owned.ranks());
        for i in 0..3u64 {
            shared.ingest(EdgeOp::add(200 + i, i * 13 % 50));
            owned.ingest(EdgeOp::add(200 + i, i * 13 % 50));
            let a = shared.query().unwrap();
            let b = owned.query().unwrap();
            assert_eq!(a.action, b.action);
            assert_eq!(a.ranks(), b.ranks(), "query {i}");
        }
        // a serial-config engine may still carry a shared pool: snapshot
        // and executors stay serial (shards resolve to 1)
        let serial = EngineBuilder::new()
            .shared_pool(std::sync::Arc::clone(&pool))
            .build_from_edges(ring(8))
            .unwrap();
        assert_eq!(serial.parallelism(), 1);
        assert_eq!(serial.snapshot_stats().full, 1);
    }

    #[test]
    fn parallelism_survives_pagerank_builder_order() {
        // .parallelism() must not be clobbered by a later .pagerank()
        // replacing the whole config.
        let e = EngineBuilder::new()
            .parallelism(4)
            .pagerank(PageRankConfig::default())
            .build_from_edges(ring(5))
            .unwrap();
        assert_eq!(e.parallelism(), 4);
        let e = EngineBuilder::new()
            .pagerank(PageRankConfig::default())
            .parallelism(3)
            .build_from_edges(ring(5))
            .unwrap();
        assert_eq!(e.parallelism(), 3);
        // Without the builder knob, the pagerank config's own value wins.
        let cfg = PageRankConfig { parallelism: 2, ..Default::default() };
        let e = EngineBuilder::new().pagerank(cfg).build_from_edges(ring(5)).unwrap();
        assert_eq!(e.parallelism(), 2);
    }

    #[test]
    fn top_returns_sorted_pairs() {
        let mut e =
            EngineBuilder::new().build_from_edges(vec![(0, 1), (2, 1), (3, 1), (1, 0)]).unwrap();
        let r = e.query().unwrap();
        let top = r.top(2);
        assert_eq!(top.len(), 2);
        assert!(top[0].1 >= top[1].1);
        assert_eq!(top[0].0, 1, "vertex 1 receives from everyone");
    }

    #[test]
    fn noop_queries_share_the_published_snapshot() {
        let mut e = EngineBuilder::new().build_from_edges(ring(10)).unwrap();
        let initial = e.latest_snapshot();
        assert_eq!(initial.version, 1, "initial exact run publishes version 1");
        assert_eq!(initial.ranks, e.ranks());
        // Queries that leave ranking and graph untouched reuse the Arc —
        // zero O(|V|) clones per served query.
        let r1 = e.query().unwrap();
        let r2 = e.query().unwrap();
        assert!(Arc::ptr_eq(&r1.snapshot, &initial));
        assert!(Arc::ptr_eq(&r1.snapshot, &r2.snapshot));
        assert_eq!(e.latest_snapshot().version, 1);
        // A mutation forces a fresh publish with a bumped version.
        e.ingest(EdgeOp::add(0, 5));
        let r3 = e.query().unwrap();
        assert!(!Arc::ptr_eq(&r3.snapshot, &r2.snapshot));
        assert_eq!(r3.snapshot.version, 2);
        assert_eq!(r3.snapshot.graph_version, e.graph().version());
        assert_eq!(r3.snapshot.query_id, r3.query_id);
        assert_eq!(r3.snapshot.ranks, e.ranks());
    }

    #[test]
    fn published_top_k_precomputation_and_fallback_agree() {
        let base = crate::graph::generate::barabasi_albert(120, 3, 0.4, 11);
        let mut e = EngineBuilder::new()
            .published_top_k(5)
            .build_from_edges(base.iter().copied())
            .unwrap();
        e.ingest(EdgeOp::add(0, 60));
        let r = e.query().unwrap();
        assert_eq!(r.snapshot.top_k_cap(), 5);
        let full = crate::metrics::ranking::top_k_ids(r.ids(), r.ranks(), 30);
        assert_eq!(r.top_ids(3), &full[..3], "precomputed path");
        assert_eq!(r.top_ids(30), full, "fallback path");
        let (v, score) = r.top(1)[0];
        assert_eq!(r.rank_of(v), Some(score));
        assert_eq!(r.rank_of(u64::MAX), None);
    }

    #[test]
    fn reader_serves_current_snapshot_without_engine_access() {
        let mut e = EngineBuilder::new().build_from_edges(ring(8)).unwrap();
        let reader = e.reader();
        assert_eq!(reader.version(), 1);
        e.ingest(EdgeOp::add(0, 4));
        let r = e.query().unwrap();
        assert_eq!(reader.version(), r.snapshot.version);
        assert_eq!(reader.top(3), r.top(3));
        assert_eq!(reader.rank(0), r.rank_of(0));
        let stats = reader.read_stats();
        assert_eq!((stats.top, stats.rank), (1, 1));
    }

    #[test]
    fn checkpoint_restore_publishes_for_readers() {
        let p = std::env::temp_dir().join(format!("vg-engine-ckpt3-{}", std::process::id()));
        let mut e = EngineBuilder::new().build_from_edges(ring(12)).unwrap();
        let _ = e.query().unwrap();
        e.save_checkpoint(&p).unwrap();
        let resumed = EngineBuilder::new().build_from_checkpoint(&p).unwrap();
        let snap = resumed.latest_snapshot();
        assert_eq!(snap.ranks, resumed.ranks());
        assert_eq!(snap.query_id, resumed.query_count());
        assert!(snap.version > 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn batch_ingest_matches_per_op_ingest() {
        let base = crate::graph::generate::barabasi_albert(150, 3, 0.3, 9);
        let mut a = EngineBuilder::new().build_from_edges(base.iter().copied()).unwrap();
        let mut b = EngineBuilder::new().build_from_edges(base.iter().copied()).unwrap();
        let ops: Vec<EdgeOp> = (0..40u64)
            .map(|i| {
                if i % 4 == 3 {
                    EdgeOp::remove(i % 10, (i + 1) % 10)
                } else {
                    EdgeOp::add(200 + i, i % 50)
                }
            })
            .collect();
        for op in ops.clone() {
            a.ingest(op);
        }
        b.ingest_batch(ops);
        let ra = a.query().unwrap();
        let rb = b.query().unwrap();
        assert_eq!(ra.action, rb.action);
        assert_eq!(ra.ranks(), rb.ranks());
        assert_eq!(a.graph().num_edges(), b.graph().num_edges());
        assert_eq!(b.metrics().counter("batches_ingested"), 1);
        assert_eq!(b.metrics().counter("ops_ingested"), 40);
    }

    #[test]
    fn batch_apply_surfaces_coalescing_metrics() {
        let mut e = EngineBuilder::new().build_from_edges(ring(10)).unwrap();
        e.ingest(EdgeOp::add(0, 5));
        e.ingest(EdgeOp::add(0, 5)); // duplicate: collapses
        e.ingest(EdgeOp::add(7, 3));
        e.ingest(EdgeOp::remove(7, 3)); // cancels outright (7, 3 both exist)
        let _ = e.query().unwrap();
        assert_eq!(e.metrics().counter("batch_raw_ops"), 4);
        assert_eq!(e.metrics().counter("batch_effective_ops"), 1, "only add(0,5) survives");
        assert_eq!(e.metrics().gauge("last_batch_raw_ops"), Some(4.0));
        assert_eq!(e.metrics().gauge("last_batch_effective_ops"), Some(1.0));
        assert!(e.metrics().timing("ingest_coalesce_secs").is_some());
        assert!(e.metrics().timing("ingest_apply_secs").is_some());
        assert!(e.graph().has_edge(0, 5));
        assert!(!e.graph().has_edge(7, 3));
    }

    #[test]
    fn staleness_context_tracks_age_and_updates() {
        let log = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        struct Capture(std::sync::Arc<std::sync::Mutex<Vec<(u64, f64, u64)>>>);
        impl UdfSuite for Capture {
            fn on_query(&mut self, ctx: &QueryContext) -> Action {
                self.0.lock().unwrap().push((
                    ctx.snapshot_age_queries,
                    ctx.snapshot_age_secs,
                    ctx.updates_since_refresh,
                ));
                if ctx.updates_since_refresh > 0 {
                    Action::ComputeApproximate
                } else {
                    Action::RepeatLast
                }
            }
        }
        let mut e = EngineBuilder::new()
            .udf(Box::new(Capture(Arc::clone(&log))))
            .build_from_edges(ring(12))
            .unwrap();
        let _ = e.query().unwrap(); // repeat-last: no publish, snapshot ages
        let _ = e.query().unwrap();
        e.ingest(EdgeOp::add(0, 6));
        let _ = e.query().unwrap(); // approximate: publishes, resets the age
        let _ = e.query().unwrap();
        let v: Vec<(u64, f64, u64)> = log.lock().unwrap().clone();
        assert_eq!(v[0].0, 0, "initial publish just happened");
        assert_eq!(v[1].0, 1, "one repeat-last query aged the snapshot");
        assert_eq!((v[2].0, v[2].2), (2, 1), "applied batch counts toward staleness");
        assert_eq!((v[3].0, v[3].2), (0, 0), "approximate publish reset age and updates");
        assert!(v.iter().all(|x| x.1 >= 0.0));
        assert!(e.metrics().gauge("snapshot_age_queries").is_some());
        assert!(e.metrics().gauge("snapshot_age_secs").is_some());
    }

    #[test]
    fn stale_republish_keeps_the_age_anchor() {
        // A repeat-last query right after an applied batch republishes
        // (readers must see the new topology) but the ranking was NOT
        // recomputed — the staleness anchors must keep growing, or a
        // steady update trickle would pin the age gauges at zero.
        struct AlwaysRepeat;
        impl UdfSuite for AlwaysRepeat {
            fn on_query(&mut self, _: &QueryContext) -> Action {
                Action::RepeatLast
            }
        }
        let mut e = EngineBuilder::new()
            .udf(Box::new(AlwaysRepeat))
            .build_from_edges(ring(10))
            .unwrap();
        let t0 = e.latest_snapshot().published_at;
        e.ingest(EdgeOp::add(0, 5)); // existing vertices: ranks length stays
        let r1 = e.query().unwrap();
        assert_eq!(r1.snapshot.version, 2, "topology moved: fresh snapshot version");
        assert_eq!(r1.snapshot.published_at, t0, "stale ranking keeps its age anchor");
        e.ingest(EdgeOp::add(1, 6));
        let r2 = e.query().unwrap();
        assert_eq!(r2.snapshot.published_at, t0, "anchor survives repeated republishes");
        // A NEW vertex extends the rank vector — a publish, not a
        // recompute: the anchor must survive that too.
        e.ingest(EdgeOp::add(50, 0));
        let r3 = e.query().unwrap();
        assert_eq!(r3.ranks().len(), 11);
        assert!(r3.snapshot.version > r2.snapshot.version, "extension republishes");
        assert_eq!(r3.snapshot.published_at, t0, "extension is not a recompute");
        let _ = e.query().unwrap();
        // Gauge set at query start: three republishing queries, no reset.
        assert_eq!(e.metrics().gauge("snapshot_age_queries"), Some(3.0));
    }

    #[test]
    fn empty_summary_approximate_keeps_accumulating_staleness() {
        // Sub-threshold updates (degree deltas below r = 0.99) produce an
        // empty hot set: the "approximation" corrects nothing, so the
        // accumulated-updates staleness signal must keep growing instead
        // of being zeroed by the no-op recompute.
        let log = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        struct Cap(std::sync::Arc<std::sync::Mutex<Vec<u64>>>);
        impl UdfSuite for Cap {
            fn on_query(&mut self, ctx: &QueryContext) -> Action {
                self.0.lock().unwrap().push(ctx.updates_since_refresh);
                Action::ComputeApproximate
            }
        }
        let mut e = EngineBuilder::new()
            .params(SummaryParams::new(0.99, 0, 0.001))
            .udf(Box::new(Cap(Arc::clone(&log))))
            .build_from_edges(ring(20))
            .unwrap();
        for i in 0..3u64 {
            e.ingest(EdgeOp::add(i, i + 10));
            let r = e.query().unwrap();
            assert_eq!(r.exec.summary_vertices, 0, "sub-threshold update stays cold");
        }
        assert_eq!(*log.lock().unwrap(), vec![1, 2, 3], "updates accumulate across no-ops");
        // The d_{t-1} baselines survive the no-op recomputes too: vertex
        // 0's kept baseline is its original degree 2, so one more edge
        // (degree 4) is a 100% cumulative change — it finally goes hot.
        e.ingest(EdgeOp::add(0, 11));
        let r = e.query().unwrap();
        assert!(r.exec.summary_vertices > 0, "accumulated drift crosses the threshold");
        assert_eq!(*log.lock().unwrap().last().unwrap(), 4);
    }

    #[test]
    fn async_query_schedules_and_finishes_off_thread_recompute() {
        let mut e = EngineBuilder::new().build_from_edges(ring(12)).unwrap();
        let policy = StalenessPolicy::default();
        // Clean snapshot: repeat-last, nothing scheduled.
        let (a, job) = e.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        assert_eq!(a.decision, Action::RepeatLast);
        assert!(!a.scheduled && job.is_none());
        // One update escalates; the reply is served from the absorbed
        // (republished) snapshot while the job runs elsewhere.
        e.ingest(EdgeOp::add(3, 7));
        let (a, job) = e.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        assert_ne!(a.decision, Action::RepeatLast);
        assert!(a.scheduled);
        assert_eq!(a.snapshot.graph_version, e.graph().version(), "reply sees the write");
        let job = job.unwrap();
        assert_eq!(job.graph_version(), e.graph().version());
        let res = std::thread::spawn(move || job.run()).join().unwrap();
        let before = e.latest_snapshot().version;
        assert!(e.finish_recompute(res).fence_ok, "fence must hold on an unmutated graph");
        let snap = e.latest_snapshot();
        assert!(snap.version > before, "the recompute publishes");
        assert_ne!(snap.action, Action::RepeatLast);
        // The installed ranking matches what a synchronous engine computes.
        let mut sync = EngineBuilder::new().build_from_edges(ring(12)).unwrap();
        sync.ingest(EdgeOp::add(3, 7));
        let r = sync.query().unwrap();
        for (id, rank) in snap.top(12) {
            let expect = r.rank_of(id).unwrap();
            assert!((rank - expect).abs() < 1e-9, "vertex {id}: {rank} vs {expect}");
        }
    }

    #[test]
    fn fence_miss_merges_by_id_and_never_regresses_topology() {
        let mut e = EngineBuilder::new().build_from_edges(ring(12)).unwrap();
        e.set_reconcile(false);
        let policy = StalenessPolicy::default();
        e.ingest(EdgeOp::add(3, 7));
        let (_, job) = e.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        let job = job.unwrap();
        // The graph moves past the fence while the job is "running";
        // with a recompute in flight no second job is scheduled.
        e.ingest(EdgeOp::AddVertex(99));
        let (a2, job2) = e.query_async(&policy, 0.0, ScheduleMode::Never).unwrap();
        assert!(job2.is_none() && !a2.scheduled);
        assert!(a2.snapshot.rank_of(99).is_some(), "absorb republished the new vertex");
        let res = job.run();
        let out = e.finish_recompute(res);
        assert!(!out.fence_ok && !out.reconciled, "fence must miss, reconciliation is off");
        assert_eq!(e.metrics().counter("recompute_fence_misses"), 1);
        // The published result keeps the live topology: the fenced ranks
        // were merged by id, not installed wholesale.
        let snap = e.latest_snapshot();
        assert!(snap.rank_of(99).is_some(), "topology never goes backwards for readers");
        assert_eq!(snap.num_vertices(), e.graph().num_vertices());
    }

    #[test]
    fn fence_miss_reconciles_post_fence_ops_by_default() {
        let mut e = EngineBuilder::new().build_from_edges(ring(12)).unwrap();
        let policy = StalenessPolicy::default();
        e.ingest(EdgeOp::add(3, 7));
        let (_, job) = e.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        let job = job.unwrap();
        // Post-fence writes land while the job runs: the armed fence
        // log replays them instead of counting a miss.
        e.ingest(EdgeOp::add(20, 3));
        e.flush_pending();
        let out = e.finish_recompute(job.run());
        assert!(!out.fence_ok && out.reconciled);
        assert_eq!(e.metrics().counter("recomputes_reconciled"), 1);
        assert_eq!(e.metrics().counter("recompute_fence_misses"), 0);
        assert!(e.metrics().counter("reconciled_vertices") >= 2);
        let snap = e.latest_snapshot();
        // The reconciled new vertex carries a full first-order gather,
        // not the uniform-init placeholder.
        let n = e.graph().num_vertices();
        let teleport = PageRankConfig::default().teleport(n);
        let r20 = snap.rank_of(20).expect("post-fence vertex published");
        assert!(r20 >= teleport - 1e-12, "r20={r20} vs teleport floor {teleport}");
        // Vertex 3 gained an in-edge from 20 — its reconciled rank must
        // exceed what the fenced job computed for an unchanged ring slot.
        let r4 = snap.rank_of(4).unwrap();
        let r3 = snap.rank_of(3).unwrap();
        assert!(r3 > r4, "the reconciled target absorbed the new in-mass: r3={r3} r4={r4}");
    }

    #[test]
    fn vertex_removal_taints_the_single_engine_fence_log() {
        let mut e = EngineBuilder::new().build_from_edges(ring(12)).unwrap();
        let policy = StalenessPolicy::default();
        e.ingest(EdgeOp::add(3, 7));
        let (_, job) = e.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        let job = job.unwrap();
        e.ingest(EdgeOp::RemoveVertex(5));
        e.flush_pending();
        let out = e.finish_recompute(job.run());
        assert!(!out.fence_ok && !out.reconciled, "removals fall back to the plain merge");
        assert_eq!(e.metrics().counter("recompute_fence_misses"), 1);
        assert_eq!(e.metrics().counter("recomputes_reconciled"), 0);
    }

    #[test]
    fn async_query_degrades_under_pressure_without_losing_staleness() {
        let mut e = EngineBuilder::new().build_from_edges(ring(12)).unwrap();
        let policy = StalenessPolicy::default();
        e.ingest(EdgeOp::add(1, 5));
        // Saturated queue: decision degrades to repeat-last, no job.
        let (a, job) = e.query_async(&policy, 1.0, ScheduleMode::WhenDue).unwrap();
        assert_eq!(a.decision, Action::RepeatLast);
        assert!(job.is_none());
        // Pressure clears: the preserved staleness signal schedules now.
        let (a, job) = e.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        assert!(a.scheduled && job.is_some());
    }

    #[test]
    fn ingest_gauges_track_coalescing_over_the_reader() {
        let mut e = EngineBuilder::new().build_from_edges(ring(10)).unwrap();
        let reader = e.reader();
        // 3 raw ops on one pair collapse to 1 effective op.
        e.ingest(EdgeOp::add(2, 7));
        e.ingest(EdgeOp::remove(2, 7));
        e.ingest(EdgeOp::add(2, 7));
        let j = reader.stats_json();
        let ingest = j.get("ingest").unwrap();
        assert_eq!(ingest.get("pending_effective_estimate").unwrap().as_u64(), Some(1));
        let _ = e.query().unwrap();
        let j = reader.stats_json();
        let ingest = j.get("ingest").unwrap();
        assert_eq!(ingest.get("coalesced_raw_ops").unwrap().as_u64(), Some(3));
        assert_eq!(ingest.get("coalesced_effective_ops").unwrap().as_u64(), Some(1));
        assert_eq!(ingest.get("pending_effective_estimate").unwrap().as_u64(), Some(0));
    }
}
