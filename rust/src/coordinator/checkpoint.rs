//! Crash-consistent checkpoints and the recovery driver.
//!
//! A checkpoint is a versioned snapshot dump of everything the serving
//! process would otherwise lose on a crash: the graph (dense vertex ids
//! + CSR-ordered edges), the rank vector, the topology version, the WAL
//! position it is consistent with, the sliding-window admission state
//! and the durable subscription records. Checkpoints are written
//! *off-thread* from a frozen clone captured on the engine thread
//! ([`CheckpointJob`] runs on the recompute worker), so dumping a large
//! graph never blocks ingest or reads.
//!
//! Recovery ([`recover`]) is snapshot + log: load the newest snapshot
//! that verifies (falling back to older ones on corruption — the last
//! [`DurabilityConfig::keep_snapshots`] dumps are retained), then
//! replay the WAL tail (records with `seq >` the snapshot's WAL
//! position) through the ordinary batch path, republish, and warm-start
//! the first recompute from the recovered ranks — the paper's
//! RepeatLast strategy made durable: a restarted server answers
//! immediately with stale-but-valid ranks.
//!
//! Atomicity: a checkpoint is written to a temp file and renamed into
//! place, so a crash mid-dump leaves the previous snapshot untouched.
//! The trailing FNV-1a checksum (plus internal length/index
//! validation) catches the remaining ways a snapshot can lie — torn
//! renames on exotic filesystems, bit rot, or the fault injector's
//! simulated mid-checkpoint crash, which deliberately bypasses the
//! rename to exercise the fallback path.
//!
//! ## Format v2 (little-endian)
//!
//! ```text
//! magic "VGCP" | u32 version
//! u64 n_vertices | u64 n_edges | u64 query_count | u64 graph_version
//! u64 wal_seq | u8 clean_shutdown
//! n_vertices × u64 vertex id          (dense order)
//! n_edges    × (u32 src_idx, u32 dst_idx)
//! n_vertices × f64 rank
//! u8 has_window | window state        (see encode_window)
//! u64 n_subs | durable sub records    (see encode_sub)
//! u64 fnv1a-64 checksum of everything above
//! ```

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::subscription::{DurableSubRecord, SubState, Subscription};
use crate::coordinator::wal::{
    DEFAULT_SEGMENT_MAX_BYTES, DurabilityStats, SyncPolicy, Wal, WalIo, WalRecord,
};
use crate::error::{Error, Result};
use crate::graph::dynamic::DynamicGraph;
use crate::stream::window::WindowState;
use crate::testing::faults::{CrashPoint, FaultInjector};

const MAGIC: &[u8; 4] = b"VGCP";
const VERSION: u32 = 2;

/// How a server configures its durability subsystem: where state
/// lives, how eagerly the WAL syncs, and how often snapshots are cut.
pub struct DurabilityConfig {
    /// Directory holding WAL segments and checkpoint files.
    pub dir: PathBuf,
    /// WAL sync policy (`--durability none|batch|interval:MS`).
    pub sync: SyncPolicy,
    /// WAL segment rotation threshold.
    pub segment_max_bytes: u64,
    /// Cut a checkpoint every this many applied batches.
    pub checkpoint_every: u64,
    /// Snapshots retained for corruption fallback.
    pub keep_snapshots: usize,
    /// Fault injection (tests only; `None` in production).
    pub faults: Option<Arc<FaultInjector>>,
    /// WAL I/O layer override (tests only; `None` = real filesystem).
    pub io: Option<Box<dyn WalIo>>,
}

impl DurabilityConfig {
    /// Defaults: batch-sync WAL, 64 MiB segments, checkpoint every 64
    /// batches, keep 3 snapshots, no faults.
    pub fn new(dir: impl Into<PathBuf>) -> DurabilityConfig {
        DurabilityConfig {
            dir: dir.into(),
            sync: SyncPolicy::Batch,
            segment_max_bytes: DEFAULT_SEGMENT_MAX_BYTES,
            checkpoint_every: 64,
            keep_snapshots: 3,
            faults: None,
            io: None,
        }
    }

    /// Set the WAL sync policy.
    pub fn sync(mut self, policy: SyncPolicy) -> Self {
        self.sync = policy;
        self
    }

    /// Set the checkpoint cadence (applied batches between snapshots).
    pub fn checkpoint_every(mut self, batches: u64) -> Self {
        self.checkpoint_every = batches.max(1);
        self
    }

    /// Set the WAL segment rotation threshold.
    pub fn segment_max_bytes(mut self, bytes: u64) -> Self {
        self.segment_max_bytes = bytes;
        self
    }

    /// Set how many snapshots to retain.
    pub fn keep_snapshots(mut self, keep: usize) -> Self {
        self.keep_snapshots = keep.max(1);
        self
    }

    /// Attach a fault injector (tests).
    pub fn faults(mut self, inj: Arc<FaultInjector>) -> Self {
        self.faults = Some(inj);
        self
    }

    /// Substitute the WAL I/O layer (tests).
    pub fn io(mut self, io: Box<dyn WalIo>) -> Self {
        self.io = Some(io);
        self
    }
}

/// Everything one checkpoint captures. Built on the engine thread from
/// cheap clones; serialized off-thread.
#[derive(Clone, Debug)]
pub struct CheckpointImage {
    /// Frozen graph clone.
    pub graph: DynamicGraph,
    /// Rank vector aligned with the graph's dense order.
    pub ranks: Vec<f64>,
    /// Engine query counter.
    pub query_count: u64,
    /// Topology version at capture (restored so incremental-snapshot
    /// stamps stay consistent across restarts).
    pub graph_version: u64,
    /// Last WAL sequence number applied to `graph` — recovery replays
    /// strictly newer records.
    pub wal_seq: u64,
    /// True only for the final checkpoint of a graceful shutdown;
    /// recovery from a clean image with no WAL tail replays nothing.
    pub clean_shutdown: bool,
    /// Sliding-window admission state, when the server runs windowed.
    pub window: Option<WindowState>,
    /// Durable subscription records.
    pub durable_subs: Vec<DurableSubRecord>,
}

/// A deserialized legacy-shape checkpoint (graph + ranks + counter) —
/// what [`load`] returns for callers that don't care about the
/// durability extras.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub graph: DynamicGraph,
    pub ranks: Vec<f64>,
    pub query_count: u64,
}

/// FNV-1a 64-bit running hash.
#[derive(Clone, Copy)]
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
}

struct HashingWriter<W: Write> {
    inner: W,
    hash: Fnv,
}

impl<W: Write> HashingWriter<W> {
    fn put(&mut self, bytes: &[u8]) -> Result<()> {
        self.hash.update(bytes);
        self.inner.write_all(bytes)?;
        Ok(())
    }
    fn u8(&mut self, v: u8) -> Result<()> {
        self.put(&[v])
    }
    fn u32(&mut self, v: u32) -> Result<()> {
        self.put(&v.to_le_bytes())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.put(&v.to_le_bytes())
    }
    fn f64(&mut self, v: f64) -> Result<()> {
        self.put(&v.to_le_bytes())
    }
}

struct HashingReader<R: Read> {
    inner: R,
    hash: Fnv,
}

impl<R: Read> HashingReader<R> {
    fn take(&mut self, buf: &mut [u8]) -> Result<()> {
        self.inner.read_exact(buf)?;
        self.hash.update(buf);
        Ok(())
    }
    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.take(&mut b)?;
        Ok(b[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.take(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.take(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.take(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
}

fn encode_sub<W: Write>(w: &mut HashingWriter<W>, rec: &DurableSubRecord) -> Result<()> {
    w.u32(rec.token.len() as u32)?;
    w.put(rec.token.as_bytes())?;
    match rec.spec {
        Subscription::TopK { k } => {
            w.u8(0)?;
            w.u64(k as u64)?;
        }
        Subscription::RankThreshold { id, tau } => {
            w.u8(1)?;
            w.u64(id)?;
            w.f64(tau)?;
        }
        Subscription::HotSet { id } => {
            w.u8(2)?;
            w.u64(id)?;
        }
        Subscription::Community { id } => {
            w.u8(3)?;
            w.u64(id)?;
        }
    }
    w.u64(rec.last_version)?;
    match &rec.state {
        SubState::TopK(ids) => {
            w.u8(0)?;
            w.u64(ids.len() as u64)?;
            for &id in ids {
                w.u64(id)?;
            }
        }
        SubState::Above(b) => {
            w.u8(1)?;
            w.u8(*b as u8)?;
        }
        SubState::Hot(b) => {
            w.u8(2)?;
            w.u8(*b as u8)?;
        }
        SubState::Label(l) => {
            w.u8(3)?;
            match l {
                Some(label) => {
                    w.u8(1)?;
                    w.u32(*label)?;
                }
                None => w.u8(0)?,
            }
        }
    }
    Ok(())
}

fn decode_sub<R: Read>(r: &mut HashingReader<R>) -> Result<DurableSubRecord> {
    let bad = |what: &str| Error::Parse(format!("corrupt checkpoint: bad subscription {what}"));
    let token_len = r.u32()? as usize;
    if token_len > 4096 {
        return Err(bad("token length"));
    }
    let mut token = vec![0u8; token_len];
    r.take(&mut token)?;
    let token = String::from_utf8(token).map_err(|_| bad("token bytes"))?;
    let spec = match r.u8()? {
        0 => Subscription::TopK { k: r.u64()? as usize },
        1 => Subscription::RankThreshold { id: r.u64()?, tau: r.f64()? },
        2 => Subscription::HotSet { id: r.u64()? },
        3 => Subscription::Community { id: r.u64()? },
        _ => return Err(bad("spec tag")),
    };
    let last_version = r.u64()?;
    let state = match r.u8()? {
        0 => {
            let n = r.u64()? as usize;
            if n > 1 << 24 {
                return Err(bad("state length"));
            }
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(r.u64()?);
            }
            SubState::TopK(ids)
        }
        1 => SubState::Above(r.u8()? != 0),
        2 => SubState::Hot(r.u8()? != 0),
        3 => {
            if r.u8()? != 0 {
                SubState::Label(Some(r.u32()?))
            } else {
                SubState::Label(None)
            }
        }
        _ => return Err(bad("state tag")),
    };
    Ok(DurableSubRecord { token, spec, state, last_version })
}

fn encode_window<W: Write>(w: &mut HashingWriter<W>, ws: &WindowState) -> Result<()> {
    w.u64(ws.window_nanos)?;
    w.u64(ws.next_stamp)?;
    w.u64(ws.live.len() as u64)?;
    for &(src, dst, count, stamp) in &ws.live {
        w.u64(src)?;
        w.u64(dst)?;
        w.u64(count)?;
        w.u64(stamp)?;
    }
    w.u64(ws.entries.len() as u64)?;
    for &(remaining, src, dst, stamp) in &ws.entries {
        w.u64(remaining)?;
        w.u64(src)?;
        w.u64(dst)?;
        w.u64(stamp)?;
    }
    Ok(())
}

fn decode_window<R: Read>(r: &mut HashingReader<R>) -> Result<WindowState> {
    let window_nanos = r.u64()?;
    let next_stamp = r.u64()?;
    let n_live = r.u64()? as usize;
    let mut live = Vec::with_capacity(n_live.min(1 << 20));
    for _ in 0..n_live {
        live.push((r.u64()?, r.u64()?, r.u64()?, r.u64()?));
    }
    let n_entries = r.u64()? as usize;
    let mut entries = Vec::with_capacity(n_entries.min(1 << 20));
    for _ in 0..n_entries {
        entries.push((r.u64()?, r.u64()?, r.u64()?, r.u64()?));
    }
    Ok(WindowState { window_nanos, next_stamp, live, entries })
}

/// Serialize an image to its full on-disk byte form (checksum
/// included).
fn encode_image(image: &CheckpointImage) -> Result<Vec<u8>> {
    if image.ranks.len() != image.graph.num_vertices() {
        return Err(Error::Engine(format!(
            "checkpoint: ranks {} != vertices {}",
            image.ranks.len(),
            image.graph.num_vertices()
        )));
    }
    let mut w = HashingWriter { inner: Vec::new(), hash: Fnv::new() };
    w.put(MAGIC)?;
    w.u32(VERSION)?;
    w.u64(image.graph.num_vertices() as u64)?;
    w.u64(image.graph.num_edges() as u64)?;
    w.u64(image.query_count)?;
    w.u64(image.graph_version)?;
    w.u64(image.wal_seq)?;
    w.u8(image.clean_shutdown as u8)?;
    for &id in image.graph.ids() {
        w.u64(id)?;
    }
    for (s, d) in image.graph.edges() {
        w.u32(s)?;
        w.u32(d)?;
    }
    for &r in &image.ranks {
        w.f64(r)?;
    }
    match &image.window {
        Some(ws) => {
            w.u8(1)?;
            encode_window(&mut w, ws)?;
        }
        None => w.u8(0)?,
    }
    w.u64(image.durable_subs.len() as u64)?;
    for rec in &image.durable_subs {
        encode_sub(&mut w, rec)?;
    }
    let digest = w.hash.0;
    let mut bytes = w.inner;
    bytes.extend_from_slice(&digest.to_le_bytes());
    Ok(bytes)
}

/// Write an image to `path` atomically (temp file + rename). With a
/// fault injector arming [`CrashPoint::MidCheckpoint`], only half the
/// bytes land — at the *final* path, as a non-atomic writer dying
/// would leave them — and an error is returned; recovery must then
/// fall back to the previous snapshot.
pub fn write_image(
    path: impl AsRef<Path>,
    image: &CheckpointImage,
    faults: Option<&FaultInjector>,
) -> Result<()> {
    let path = path.as_ref();
    let bytes = encode_image(image)?;
    if let Some(inj) = faults {
        if inj.take_crash(CrashPoint::MidCheckpoint) {
            std::fs::write(path, &bytes[..bytes.len() / 2])?;
            return Err(Error::Engine("injected crash: mid-checkpoint".into()));
        }
    }
    let tmp = path.with_extension("vgcp.tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load and fully verify one checkpoint file.
pub fn load_image(path: impl AsRef<Path>) -> Result<CheckpointImage> {
    let f = std::fs::File::open(path)?;
    let mut r = HashingReader { inner: std::io::BufReader::new(f), hash: Fnv::new() };
    let mut magic = [0u8; 4];
    r.take(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Parse("not a VeilGraph checkpoint".into()));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(Error::Parse(format!("unsupported checkpoint version {version}")));
    }
    let n = r.u64()? as usize;
    let m = r.u64()? as usize;
    let query_count = r.u64()?;
    let graph_version = r.u64()?;
    let wal_seq = r.u64()?;
    let clean_shutdown = r.u8()? != 0;
    let mut ids = Vec::with_capacity(n.min(1 << 24));
    for _ in 0..n {
        ids.push(r.u64()?);
    }
    let mut graph = DynamicGraph::new();
    for &id in &ids {
        graph.add_vertex(id);
    }
    for _ in 0..m {
        let s = r.u32()? as usize;
        let d = r.u32()? as usize;
        if s >= n || d >= n {
            return Err(Error::Parse("checkpoint edge index out of range".into()));
        }
        graph
            .add_edge(ids[s], ids[d])
            .map_err(|e| Error::Parse(format!("corrupt checkpoint: {e}")))?;
    }
    let mut ranks = Vec::with_capacity(n.min(1 << 24));
    for _ in 0..n {
        ranks.push(r.f64()?);
    }
    let window = if r.u8()? != 0 { Some(decode_window(&mut r)?) } else { None };
    let n_subs = r.u64()? as usize;
    if n_subs > 1 << 20 {
        return Err(Error::Parse("corrupt checkpoint: implausible subscription count".into()));
    }
    let mut durable_subs = Vec::with_capacity(n_subs);
    for _ in 0..n_subs {
        durable_subs.push(decode_sub(&mut r)?);
    }
    let expect = r.hash.0;
    let mut tail = [0u8; 8];
    r.inner.read_exact(&mut tail)?;
    if u64::from_le_bytes(tail) != expect {
        return Err(Error::Parse("checkpoint checksum mismatch".into()));
    }
    Ok(CheckpointImage {
        graph,
        ranks,
        query_count,
        graph_version,
        wal_seq,
        clean_shutdown,
        window,
        durable_subs,
    })
}

/// Serialize graph + ranks + query counter to `path` (legacy-shape
/// convenience; durability extras default to empty).
pub fn save(
    path: impl AsRef<Path>,
    graph: &DynamicGraph,
    ranks: &[f64],
    query_count: u64,
) -> Result<()> {
    let image = CheckpointImage {
        graph: graph.clone(),
        ranks: ranks.to_vec(),
        query_count,
        graph_version: graph.version(),
        wal_seq: 0,
        clean_shutdown: true,
        window: None,
        durable_subs: Vec::new(),
    };
    write_image(path, &image, None)
}

/// Load a checkpoint, verifying magic/version/checksum (legacy shape).
pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let image = load_image(path)?;
    Ok(Checkpoint { graph: image.graph, ranks: image.ranks, query_count: image.query_count })
}

/// Where the snapshot covering WAL position `wal_seq` lives.
pub fn snapshot_path(dir: &Path, wal_seq: u64) -> PathBuf {
    dir.join(format!("ckpt-{wal_seq:020}.vgcp"))
}

/// All snapshot files in `dir`, sorted by WAL position ascending.
fn list_snapshots(dir: &Path) -> Vec<(u64, PathBuf)> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else { return out };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(num) = name.strip_prefix("ckpt-").and_then(|n| n.strip_suffix(".vgcp")) {
            if let Ok(seq) = num.parse::<u64>() {
                out.push((seq, entry.path()));
            }
        }
    }
    out.sort();
    out
}

/// Delete all but the newest `keep` snapshots.
fn prune_snapshots(dir: &Path, keep: usize) {
    let snaps = list_snapshots(dir);
    if snaps.len() > keep {
        for (_, path) in &snaps[..snaps.len() - keep] {
            std::fs::remove_file(path).ok();
        }
    }
}

/// One off-thread checkpoint dump: built on the engine thread, run on
/// the recompute worker, result returned through the command queue.
pub struct CheckpointJob {
    /// Durability directory.
    pub dir: PathBuf,
    /// Snapshots to retain after this one lands.
    pub keep: usize,
    /// The frozen state to dump.
    pub image: CheckpointImage,
    /// Fault injection (tests).
    pub faults: Option<Arc<FaultInjector>>,
    /// Shared gauges to update.
    pub stats: Arc<DurabilityStats>,
}

/// What a finished checkpoint job reports back to the engine thread.
#[derive(Clone, Debug)]
pub struct CheckpointOutcome {
    /// Whether the snapshot landed (atomically) on disk.
    pub ok: bool,
    /// The WAL position the snapshot covers.
    pub wal_seq: u64,
    /// The failure, if any.
    pub err: Option<String>,
}

impl CheckpointJob {
    /// Dump the image, prune old snapshots on success, update gauges.
    pub fn run(self) -> CheckpointOutcome {
        let wal_seq = self.image.wal_seq;
        let path = snapshot_path(&self.dir, wal_seq);
        match write_image(&path, &self.image, self.faults.as_deref()) {
            Ok(()) => {
                prune_snapshots(&self.dir, self.keep);
                self.stats.note_checkpoint(true, wal_seq);
                CheckpointOutcome { ok: true, wal_seq, err: None }
            }
            Err(e) => {
                self.stats.note_checkpoint(false, wal_seq);
                CheckpointOutcome { ok: false, wal_seq, err: Some(e.to_string()) }
            }
        }
    }
}

/// What [`recover`] found on disk.
pub struct Recovered {
    /// The newest snapshot that verified, if any.
    pub image: Option<CheckpointImage>,
    /// WAL records newer than the snapshot, in order — replay these
    /// through the ordinary batch path.
    pub tail: Vec<WalRecord>,
    /// Where the reopened WAL should continue.
    pub next_seq: u64,
    /// Recovery accounting.
    pub report: RecoveryReport,
}

/// Recovery accounting, printed by the CLI and surfaced in stats.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// WAL position of the loaded snapshot (None = no usable snapshot).
    pub snapshot_loaded: Option<u64>,
    /// Corrupt/unreadable snapshots skipped before one verified.
    pub snapshots_skipped: usize,
    /// WAL batches replayed.
    pub replayed_batches: usize,
    /// Effective ops inside those batches.
    pub replayed_ops: usize,
    /// A torn WAL tail was detected and discarded.
    pub torn_tail_discarded: bool,
    /// True when the previous run shut down cleanly (final checkpoint,
    /// empty tail) — recovery then replays nothing.
    pub clean_shutdown: bool,
}

/// Inspect a durability directory: newest valid snapshot (older ones
/// tried on corruption) plus the WAL tail past it. Pure read — call
/// before opening the WAL for append.
pub fn recover(dir: &Path) -> Result<Recovered> {
    let mut report = RecoveryReport::default();
    let mut image = None;
    let snaps = list_snapshots(dir);
    for (seq, path) in snaps.iter().rev() {
        match load_image(path) {
            Ok(img) => {
                report.snapshot_loaded = Some(*seq);
                image = Some(img);
                break;
            }
            Err(e) => {
                eprintln!(
                    "[veilgraph] skipping corrupt checkpoint {}: {e}",
                    path.display()
                );
                report.snapshots_skipped += 1;
            }
        }
    }
    let scan = Wal::scan(dir)?;
    report.torn_tail_discarded = scan.torn_tail_discarded;
    let base_seq = image.as_ref().map(|i: &CheckpointImage| i.wal_seq).unwrap_or(0);
    let tail: Vec<WalRecord> =
        scan.records.into_iter().filter(|r| r.seq > base_seq).collect();
    report.replayed_batches = tail.len();
    report.replayed_ops = tail.iter().map(|r| r.ops.len()).sum();
    report.clean_shutdown =
        image.as_ref().map(|i| i.clean_shutdown).unwrap_or(false) && tail.is_empty();
    let next_seq = scan.next_seq.max(base_seq + 1);
    Ok(Recovered { image, tail, next_seq, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::stream::event::EdgeOp;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "vg-ckpt-{name}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    fn image(g: &DynamicGraph, wal_seq: u64) -> CheckpointImage {
        CheckpointImage {
            graph: g.clone(),
            ranks: (0..g.num_vertices()).map(|i| i as f64 * 0.01).collect(),
            query_count: 42,
            graph_version: g.version(),
            wal_seq,
            clean_shutdown: false,
            window: Some(WindowState {
                window_nanos: 1_000,
                next_stamp: 9,
                live: vec![(1, 2, 1, 3)],
                entries: vec![(500, 1, 2, 3)],
            }),
            durable_subs: vec![
                DurableSubRecord {
                    token: "client-a".into(),
                    spec: Subscription::TopK { k: 3 },
                    state: SubState::TopK(vec![4, 7, 9]),
                    last_version: 11,
                },
                DurableSubRecord {
                    token: "client-b".into(),
                    spec: Subscription::RankThreshold { id: 5, tau: 0.25 },
                    state: SubState::Above(true),
                    last_version: 12,
                },
                DurableSubRecord {
                    token: "client-c".into(),
                    spec: Subscription::Community { id: 8 },
                    state: SubState::Label(Some(3)),
                    last_version: 13,
                },
            ],
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let edges = generate::barabasi_albert(200, 3, 0.5, 3);
        let (g, _) = DynamicGraph::from_edges(edges);
        let p = tmp("roundtrip");
        std::fs::create_dir_all(&p).unwrap();
        let path = snapshot_path(&p, 7);
        let img = image(&g, 7);
        write_image(&path, &img, None).unwrap();
        let c = load_image(&path).unwrap();
        assert_eq!(c.query_count, 42);
        assert_eq!(c.graph_version, img.graph_version);
        assert_eq!(c.wal_seq, 7);
        assert!(!c.clean_shutdown);
        assert_eq!(c.graph.num_vertices(), g.num_vertices());
        assert_eq!(c.graph.num_edges(), g.num_edges());
        assert_eq!(c.ranks, img.ranks);
        assert_eq!(c.graph.ids(), g.ids());
        for (s, d) in g.edges() {
            assert!(c.graph.has_edge(g.id(s), g.id(d)));
        }
        assert_eq!(c.window, img.window);
        assert_eq!(c.durable_subs, img.durable_subs);
        std::fs::remove_dir_all(&p).ok();
    }

    #[test]
    fn legacy_save_load_shape_still_works() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 3)]);
        let p = tmp("legacy");
        std::fs::create_dir_all(&p).unwrap();
        let path = p.join("ck.vgcp");
        save(&path, &g, &[0.1, 0.2, 0.3], 5).unwrap();
        let c = load(&path).unwrap();
        assert_eq!(c.query_count, 5);
        assert_eq!(c.ranks, vec![0.1, 0.2, 0.3]);
        assert_eq!(c.graph.num_edges(), 2);
        std::fs::remove_dir_all(&p).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 3)]);
        let p = tmp("corrupt");
        std::fs::create_dir_all(&p).unwrap();
        let path = p.join("ck.vgcp");
        save(&path, &g, &[0.1, 0.2, 0.3], 1).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err(), "flipped byte must fail checksum or parse");
        std::fs::remove_dir_all(&p).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("magic");
        std::fs::create_dir_all(&p).unwrap();
        let path = p.join("ck.vgcp");
        std::fs::write(&path, b"NOPE....xxxxxxxxxxxx").unwrap();
        let e = load(&path).unwrap_err();
        assert!(e.to_string().contains("not a VeilGraph checkpoint"));
        std::fs::remove_dir_all(&p).ok();
    }

    #[test]
    fn rank_length_mismatch_rejected_on_save() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let p = tmp("mismatch");
        std::fs::create_dir_all(&p).unwrap();
        assert!(save(p.join("ck.vgcp"), &g, &[0.1], 0).is_err());
        std::fs::remove_dir_all(&p).ok();
    }

    #[test]
    fn recover_falls_back_to_older_snapshot_on_corruption() {
        let dir = tmp("fallback");
        std::fs::create_dir_all(&dir).unwrap();
        let (g1, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let (g2, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 3)]);
        let mut img1 = image(&g1, 3);
        img1.ranks = vec![0.5, 0.5];
        let mut img2 = image(&g2, 8);
        img2.ranks = vec![0.3, 0.3, 0.4];
        write_image(snapshot_path(&dir, 3), &img1, None).unwrap();
        write_image(snapshot_path(&dir, 8), &img2, None).unwrap();
        // Corrupt the newest.
        let newest = snapshot_path(&dir, 8);
        let mut bytes = std::fs::read(&newest).unwrap();
        let len = bytes.len();
        bytes[len - 3] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.report.snapshots_skipped, 1);
        assert_eq!(rec.report.snapshot_loaded, Some(3));
        assert_eq!(rec.image.unwrap().ranks, vec![0.5, 0.5]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mid_checkpoint_crash_leaves_recoverable_directory() {
        use crate::testing::faults::FaultInjector;
        let dir = tmp("midcrash");
        std::fs::create_dir_all(&dir).unwrap();
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let mut good = image(&g, 2);
        good.ranks = vec![0.5, 0.5];
        write_image(snapshot_path(&dir, 2), &good, None).unwrap();
        // Second checkpoint dies halfway, through the injector.
        let inj = FaultInjector::new();
        inj.arm_crash(CrashPoint::MidCheckpoint);
        let stats = DurabilityStats::new();
        let job = CheckpointJob {
            dir: dir.clone(),
            keep: 3,
            image: image(&g, 6),
            faults: Some(std::sync::Arc::clone(&inj)),
            stats: std::sync::Arc::clone(&stats),
        };
        let out = job.run();
        assert!(!out.ok);
        assert_eq!(inj.trips(), 1);
        // The torn file exists at the final path, yet recovery lands on
        // the older good snapshot.
        assert!(snapshot_path(&dir, 6).exists());
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.report.snapshot_loaded, Some(2));
        assert_eq!(rec.report.snapshots_skipped, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_job_prunes_old_snapshots() {
        let dir = tmp("prune");
        std::fs::create_dir_all(&dir).unwrap();
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let stats = DurabilityStats::new();
        for seq in 1..=5u64 {
            let mut img = image(&g, seq);
            img.ranks = vec![0.5, 0.5];
            let job = CheckpointJob {
                dir: dir.clone(),
                keep: 2,
                image: img,
                faults: None,
                stats: std::sync::Arc::clone(&stats),
            };
            assert!(job.run().ok);
        }
        let snaps = list_snapshots(&dir);
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].0, 4);
        assert_eq!(snaps[1].0, 5);
        assert_eq!(stats.checkpoints_written(), 5);
        assert_eq!(stats.last_checkpoint_seq(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recover_combines_snapshot_and_wal_tail() {
        use crate::coordinator::wal::FsIo;
        let dir = tmp("combine");
        std::fs::create_dir_all(&dir).unwrap();
        let mut wal = Wal::open(
            &dir,
            1,
            SyncPolicy::Batch,
            DEFAULT_SEGMENT_MAX_BYTES,
            Box::new(FsIo),
            DurabilityStats::new(),
            None,
        )
        .unwrap();
        for i in 0..4u64 {
            wal.append_batch(&[EdgeOp::add(i, i + 1)]).unwrap();
        }
        drop(wal);
        // Snapshot covers through seq 2; tail = seqs 3 and 4.
        let (g, _) = DynamicGraph::from_edges(vec![(0, 1), (1, 2)]);
        let mut img = image(&g, 2);
        img.ranks = vec![0.3; g.num_vertices()];
        write_image(snapshot_path(&dir, 2), &img, None).unwrap();
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.report.snapshot_loaded, Some(2));
        assert_eq!(rec.report.replayed_batches, 2);
        assert_eq!(rec.tail[0].seq, 3);
        assert_eq!(rec.tail[1].seq, 4);
        assert_eq!(rec.next_seq, 5);
        assert!(!rec.report.clean_shutdown);
        std::fs::remove_dir_all(&dir).ok();
    }
}
