//! Engine checkpointing: save/restore of the graph + rank state in a
//! compact binary format, so a long-lived VeilGraph job can restart
//! without replaying its whole stream (operational requirement for the
//! serving deployment of Fig. 2; the paper's `OnStart`/`OnStop` UDFs are
//! the natural hook points).
//!
//! Format (little-endian):
//! ```text
//! magic "VGCP" | u32 version | u64 n_vertices | u64 n_edges | u64 query_count
//! n_vertices × u64 vertex id          (dense order)
//! n_edges    × (u32 src_idx, u32 dst_idx)
//! n_vertices × f64 rank
//! u64 fnv1a-64 checksum of everything above
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::graph::dynamic::DynamicGraph;

const MAGIC: &[u8; 4] = b"VGCP";
const VERSION: u32 = 1;

/// A deserialized checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub graph: DynamicGraph,
    pub ranks: Vec<f64>,
    pub query_count: u64,
}

/// FNV-1a 64-bit running hash.
#[derive(Clone, Copy)]
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
}

struct HashingWriter<W: Write> {
    inner: W,
    hash: Fnv,
}

impl<W: Write> HashingWriter<W> {
    fn put(&mut self, bytes: &[u8]) -> Result<()> {
        self.hash.update(bytes);
        self.inner.write_all(bytes)?;
        Ok(())
    }
    fn u32(&mut self, v: u32) -> Result<()> {
        self.put(&v.to_le_bytes())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.put(&v.to_le_bytes())
    }
    fn f64(&mut self, v: f64) -> Result<()> {
        self.put(&v.to_le_bytes())
    }
}

struct HashingReader<R: Read> {
    inner: R,
    hash: Fnv,
}

impl<R: Read> HashingReader<R> {
    fn take(&mut self, buf: &mut [u8]) -> Result<()> {
        self.inner.read_exact(buf)?;
        self.hash.update(buf);
        Ok(())
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.take(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.take(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.take(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
}

/// Serialize graph + ranks + query counter to `path`.
pub fn save(
    path: impl AsRef<Path>,
    graph: &DynamicGraph,
    ranks: &[f64],
    query_count: u64,
) -> Result<()> {
    if ranks.len() != graph.num_vertices() {
        return Err(Error::Engine(format!(
            "checkpoint: ranks {} != vertices {}",
            ranks.len(),
            graph.num_vertices()
        )));
    }
    let f = std::fs::File::create(path)?;
    let mut w = HashingWriter { inner: BufWriter::new(f), hash: Fnv::new() };
    w.put(MAGIC)?;
    w.u32(VERSION)?;
    w.u64(graph.num_vertices() as u64)?;
    w.u64(graph.num_edges() as u64)?;
    w.u64(query_count)?;
    for &id in graph.ids() {
        w.u64(id)?;
    }
    for (s, d) in graph.edges() {
        w.u32(s)?;
        w.u32(d)?;
    }
    for &r in ranks {
        w.f64(r)?;
    }
    let digest = w.hash.0;
    w.inner.write_all(&digest.to_le_bytes())?;
    w.inner.flush()?;
    Ok(())
}

/// Load a checkpoint, verifying magic/version/checksum.
pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let f = std::fs::File::open(path)?;
    let mut r = HashingReader { inner: BufReader::new(f), hash: Fnv::new() };
    let mut magic = [0u8; 4];
    r.take(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Parse("not a VeilGraph checkpoint".into()));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(Error::Parse(format!("unsupported checkpoint version {version}")));
    }
    let n = r.u64()? as usize;
    let m = r.u64()? as usize;
    let query_count = r.u64()?;
    let mut ids = Vec::with_capacity(n);
    for _ in 0..n {
        ids.push(r.u64()?);
    }
    let mut graph = DynamicGraph::new();
    for &id in &ids {
        graph.add_vertex(id);
    }
    for _ in 0..m {
        let s = r.u32()? as usize;
        let d = r.u32()? as usize;
        if s >= n || d >= n {
            return Err(Error::Parse("checkpoint edge index out of range".into()));
        }
        graph
            .add_edge(ids[s], ids[d])
            .map_err(|e| Error::Parse(format!("corrupt checkpoint: {e}")))?;
    }
    let mut ranks = Vec::with_capacity(n);
    for _ in 0..n {
        ranks.push(r.f64()?);
    }
    let expect = r.hash.0;
    let mut tail = [0u8; 8];
    r.inner.read_exact(&mut tail)?;
    if u64::from_le_bytes(tail) != expect {
        return Err(Error::Parse("checkpoint checksum mismatch".into()));
    }
    Ok(Checkpoint { graph, ranks, query_count })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("vg-ckpt-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let edges = generate::barabasi_albert(200, 3, 0.5, 3);
        let (g, _) = DynamicGraph::from_edges(edges);
        let ranks: Vec<f64> = (0..g.num_vertices()).map(|i| i as f64 * 0.01).collect();
        let p = tmp("roundtrip");
        save(&p, &g, &ranks, 42).unwrap();
        let c = load(&p).unwrap();
        assert_eq!(c.query_count, 42);
        assert_eq!(c.graph.num_vertices(), g.num_vertices());
        assert_eq!(c.graph.num_edges(), g.num_edges());
        assert_eq!(c.ranks, ranks);
        assert_eq!(c.graph.ids(), g.ids());
        for (s, d) in g.edges() {
            assert!(c.graph.has_edge(g.id(s), g.id(d)));
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2), (2, 3)]);
        let p = tmp("corrupt");
        save(&p, &g, &[0.1, 0.2, 0.3], 1).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err(), "flipped byte must fail checksum or parse");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOPE....xxxxxxxxxxxx").unwrap();
        let e = load(&p).unwrap_err();
        assert!(e.to_string().contains("not a VeilGraph checkpoint"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rank_length_mismatch_rejected_on_save() {
        let (g, _) = DynamicGraph::from_edges(vec![(1, 2)]);
        let p = tmp("mismatch");
        assert!(save(&p, &g, &[0.1], 0).is_err());
        std::fs::remove_file(&p).ok();
    }
}
