//! The typed wire protocol: one `Request`/`Response` enum pair shared by
//! the TCP line protocol, library callers and the subscription plane.
//!
//! Before this module the protocol existed only as string plumbing
//! inside the server's dispatch loop — every op hand-parsed its own
//! fields and hand-assembled its own response object. Now parsing
//! (`Envelope::parse` + `Request::parse`) and rendering
//! ([`Response::to_json`]) are data-first: dispatch is one `match` over
//! [`Request`], and anything that can answer a request — the readiness
//! loop, [`handle_request`](crate::coordinator::server::handle_request),
//! tests — speaks the same types.
//!
//! Two protocol versions share the wire:
//!
//! * **v1** (requests with `"v":1` or no `"v"` at all): strictly
//!   in-order request/response. A pending wire query pauses the
//!   connection's reads, so pipelined responses keep request order.
//! * **v2** (`"v":2`): every request may carry an `"id"` (any JSON
//!   value), every response echoes it, and responses may arrive out of
//!   order — the readiness loop keeps reading while wire queries are in
//!   flight. Push notifications from standing queries
//!   ([`crate::coordinator::subscription`]) are frames of their own,
//!   tagged `{"v":2,"sub":<id>,"notify":{...}}`, and only exist on v2
//!   connections.
//!
//! Version negotiation is per-request: a v1 and a v2 client can share a
//! server, and one client may mix versions line by line (each response
//! echoes the version of the request it answers).

use crate::coordinator::subscription::Subscription;
use crate::coordinator::udf::Action;
use crate::error::Error;
use crate::graph::VertexId;
use crate::stream::event::EdgeOp;
use crate::util::json::Json;

/// Newest protocol version this server speaks (and the version the
/// `stats` server section reports).
pub const WIRE_PROTOCOL_VERSION: u64 = 2;

/// The legacy in-order protocol; requests without a `"v"` field parse
/// as v1.
pub const WIRE_PROTOCOL_V1: u64 = 1;

/// Upper bound on ops per wire `batch` request. A batch occupies ONE
/// engine-queue slot regardless of size, so without a cap a fast writer
/// pipelining huge batches could buffer `queue_capacity x batch_size`
/// ops before backpressure engages; with the cap, queued memory stays
/// bounded. Clients with more ops send more batch lines.
pub const MAX_WIRE_BATCH_OPS: usize = 4096;

/// Per-request protocol framing: the negotiated version plus the
/// client's request id (v2 only), echoed verbatim on the response.
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub version: u64,
    pub id: Option<Json>,
}

impl Envelope {
    /// The legacy framing (v1, no id) — what server-originated lines
    /// that answer no particular request use.
    pub fn v1() -> Envelope {
        Envelope { version: WIRE_PROTOCOL_V1, id: None }
    }

    /// Negotiate the request's framing. Absent `"v"` parses as v1;
    /// versions other than 1 and 2 (or non-numeric ones) are refused.
    /// The `"id"` field is v2 surface and ignored on v1 requests.
    pub fn parse(req: &Json) -> Result<Envelope, String> {
        let version = match req.get("v") {
            None => WIRE_PROTOCOL_V1,
            Some(v) => match v.as_u64() {
                Some(n) if n == WIRE_PROTOCOL_V1 || n == WIRE_PROTOCOL_VERSION => n,
                _ => {
                    return Err(format!(
                        "unsupported protocol version {}; this server speaks \
                         v{WIRE_PROTOCOL_V1} and v{WIRE_PROTOCOL_VERSION}",
                        v.to_string_compact()
                    ))
                }
            },
        };
        let id = if version >= WIRE_PROTOCOL_VERSION { req.get("id").cloned() } else { None };
        Ok(Envelope { version, id })
    }

    /// True for requests under out-of-order (v2) semantics.
    pub fn is_v2(&self) -> bool {
        self.version >= WIRE_PROTOCOL_VERSION
    }
}

/// Every operation a client can ask of the server, parsed from one
/// request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// A single graph mutation (`add`/`remove`/`add_vertex`/
    /// `remove_vertex`), registered through the bounded engine queue.
    Write(EdgeOp),
    /// A pre-validated all-or-nothing batch of mutations (one queue
    /// slot).
    Batch(Vec<EdgeOp>),
    /// A wire query: answered from the published snapshot, recompute
    /// scheduled off-thread per the staleness policy.
    Query { k: usize },
    /// Read the top-`k` ranking off the published snapshot (never
    /// queued).
    Top { k: usize },
    /// Read one vertex's rank off the published snapshot.
    Rank { id: VertexId },
    /// Serving + engine + server gauges.
    Stats,
    /// Register a standing query (v2 connections only). A `token`
    /// makes the subscription durable: it survives restarts in the
    /// server's checkpoints, and a re-subscribe under the same token
    /// replays the diff missed while disconnected.
    Subscribe { spec: Subscription, token: Option<String> },
    /// Drop a standing query owned by this connection.
    Unsubscribe { sub: u64 },
    /// Stop the server.
    Shutdown,
}

impl Request {
    /// Parse the `"op"` surface of one request object.
    pub fn parse(req: &Json) -> Result<Request, String> {
        let op = req.get("op").and_then(Json::as_str).unwrap_or("");
        match op {
            "add" | "remove" | "add_vertex" | "remove_vertex" => {
                parse_write_op(op, req).map(Request::Write)
            }
            "batch" => {
                let items =
                    req.get("ops").and_then(Json::as_arr).ok_or("batch needs an ops array")?;
                if items.len() > MAX_WIRE_BATCH_OPS {
                    return Err(format!(
                        "batch of {} ops exceeds the {MAX_WIRE_BATCH_OPS}-op cap; split it",
                        items.len()
                    ));
                }
                // Validate everything before registering anything: a
                // batch is all-or-nothing.
                let mut ops = Vec::with_capacity(items.len());
                for (i, item) in items.iter().enumerate() {
                    let kind = item.get("op").and_then(Json::as_str).unwrap_or("");
                    match parse_write_op(kind, item) {
                        Ok(e) => ops.push(e),
                        Err(msg) => return Err(format!("batch op {i}: {msg}; nothing registered")),
                    }
                }
                Ok(Request::Batch(ops))
            }
            "query" => {
                let k = req.get("top").and_then(Json::as_u64).unwrap_or(10) as usize;
                Ok(Request::Query { k })
            }
            "top" => {
                let k = req
                    .get("k")
                    .or_else(|| req.get("top"))
                    .and_then(Json::as_u64)
                    .unwrap_or(10) as usize;
                Ok(Request::Top { k })
            }
            "rank" => match req.get("id").and_then(Json::as_u64) {
                Some(id) => Ok(Request::Rank { id }),
                None => Err("rank needs a numeric id".into()),
            },
            "stats" => Ok(Request::Stats),
            "subscribe" => Subscription::parse(req).map(|spec| Request::Subscribe {
                spec,
                token: req.get("token").and_then(Json::as_str).map(str::to_string),
            }),
            "unsubscribe" => match req.get("sub").and_then(Json::as_u64) {
                Some(sub) => Ok(Request::Unsubscribe { sub }),
                None => Err("unsubscribe needs a numeric sub id".into()),
            },
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown op {other:?}")),
        }
    }

    /// The off-queue read ops — the one classification both the
    /// rate-limit guard and dispatch consult, so a new read op cannot be
    /// added to one and silently bypass the other.
    pub fn is_read(&self) -> bool {
        matches!(self, Request::Top { .. } | Request::Rank { .. } | Request::Stats)
    }
}

/// Parse one write op object (shared by the single-op requests and the
/// elements of a `batch`).
fn parse_write_op(op: &str, req: &Json) -> Result<EdgeOp, String> {
    match op {
        "add" | "remove" => {
            match (req.get("src").and_then(Json::as_u64), req.get("dst").and_then(Json::as_u64)) {
                (Some(s), Some(d)) => {
                    Ok(if op == "add" { EdgeOp::add(s, d) } else { EdgeOp::remove(s, d) })
                }
                _ => Err("add/remove need numeric src and dst".into()),
            }
        }
        "add_vertex" | "remove_vertex" => match req.get("id").and_then(Json::as_u64) {
            Some(id) => Ok(if op == "add_vertex" {
                EdgeOp::AddVertex(id)
            } else {
                EdgeOp::RemoveVertex(id)
            }),
            None => Err("add_vertex/remove_vertex need a numeric id".into()),
        },
        other => Err(format!("unknown write op {other:?}")),
    }
}

/// Every answer the server gives, rendered against the [`Envelope`] of
/// the request it answers (so the response carries the request's
/// protocol version and echoes its id).
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// A write (or shutdown) acknowledged.
    Ok,
    /// A batch registered whole.
    Registered { n: usize },
    /// A wire query answered from the published snapshot; `action` is
    /// the staleness decision, `scheduled` whether a recompute was
    /// handed off-thread.
    Query {
        query_id: u64,
        version: u64,
        action: Action,
        scheduled: bool,
        age_secs: f64,
        top: Vec<(VertexId, f64)>,
    },
    /// The `top` read.
    Top { version: u64, query_id: u64, action: Action, top: Vec<(VertexId, f64)> },
    /// The `rank` read (`None` = vertex unknown, rendered as null).
    Rank { version: u64, id: VertexId, rank: Option<f64> },
    /// The assembled `stats` sections.
    Stats(Json),
    /// A standing query registered. `replayed` is true when a durable
    /// re-subscribe delivered the diff missed while disconnected.
    Subscribed { sub: u64, replayed: bool },
    /// A standing query dropped.
    Unsubscribed { sub: u64 },
    /// A structured error. The codes are stable protocol surface:
    /// `rate_limited`, `conn_cap`, `bad_op`, `overload`, `shutdown`.
    /// `extra` carries additional top-level fields (e.g. the degraded
    /// snapshot answer alongside an `overload`).
    Error { code: String, msg: String, extra: Vec<(String, Json)> },
}

impl Response {
    /// A plain error with no extra payload.
    pub fn error(code: &str, msg: &str) -> Response {
        Response::Error { code: code.into(), msg: msg.into(), extra: Vec::new() }
    }

    /// Map an internal error onto its stable wire code.
    pub fn from_error(e: &Error) -> Response {
        Response::error(error_code(e), &e.to_string())
    }

    /// Render one response line: `{"v":<req version>,"ok":…,…}` plus
    /// the echoed `"id"` when the request carried one.
    pub fn to_json(&self, env: &Envelope) -> Json {
        let mut map = std::collections::BTreeMap::new();
        map.insert("v".to_string(), Json::Num(env.version as f64));
        map.insert("ok".to_string(), Json::Bool(!matches!(self, Response::Error { .. })));
        if let Some(id) = &env.id {
            map.insert("id".to_string(), id.clone());
        }
        match self {
            Response::Ok => {}
            Response::Registered { n } => {
                map.insert("registered".into(), Json::Num(*n as f64));
            }
            Response::Query { query_id, version, action, scheduled, age_secs, top } => {
                map.insert("query_id".into(), Json::Num(*query_id as f64));
                map.insert("version".into(), Json::Num(*version as f64));
                map.insert("action".into(), Json::Str(action.to_string()));
                map.insert("scheduled".into(), Json::Bool(*scheduled));
                map.insert("age_secs".into(), Json::Num(*age_secs));
                map.insert("top".into(), top_pairs(top));
            }
            Response::Top { version, query_id, action, top } => {
                map.insert("version".into(), Json::Num(*version as f64));
                map.insert("query_id".into(), Json::Num(*query_id as f64));
                map.insert("action".into(), Json::Str(action.to_string()));
                map.insert("top".into(), top_pairs(top));
            }
            Response::Rank { version, id, rank } => {
                map.insert("version".into(), Json::Num(*version as f64));
                map.insert("id".into(), Json::Num(*id as f64));
                map.insert("rank".into(), rank.map(Json::Num).unwrap_or(Json::Null));
            }
            Response::Stats(stats) => {
                map.insert("stats".into(), stats.clone());
            }
            Response::Subscribed { sub, replayed } => {
                map.insert("sub".into(), Json::Num(*sub as f64));
                map.insert("replayed".into(), Json::Bool(*replayed));
            }
            Response::Unsubscribed { sub } => {
                map.insert("sub".into(), Json::Num(*sub as f64));
            }
            Response::Error { code, msg, extra } => {
                map.insert(
                    "error".into(),
                    Json::obj(vec![
                        ("code", Json::Str(code.clone())),
                        ("msg", Json::Str(msg.clone())),
                    ]),
                );
                for (key, value) in extra {
                    map.insert(key.clone(), value.clone());
                }
            }
        }
        Json::Obj(map)
    }
}

/// Map an internal error onto its stable wire code.
pub fn error_code(e: &Error) -> &'static str {
    match e {
        Error::Backpressure(_) => "overload",
        Error::Engine(msg)
            if msg.contains("closed") || msg.contains("stopped") || msg.contains("gone") =>
        {
            "shutdown"
        }
        _ => "bad_op",
    }
}

/// Render a top-k ranking as the wire's `[[id,score],…]` array.
fn top_pairs(pairs: &[(u64, f64)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|&(id, score)| Json::Arr(vec![Json::Num(id as f64), Json::Num(score)]))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_negotiates_versions() {
        let p = |s: &str| Envelope::parse(&Json::parse(s).unwrap());
        assert_eq!(p(r#"{"op":"top"}"#), Ok(Envelope::v1()));
        assert_eq!(p(r#"{"v":1,"op":"top"}"#), Ok(Envelope::v1()));
        assert_eq!(
            p(r#"{"v":2,"id":7,"op":"top"}"#),
            Ok(Envelope { version: 2, id: Some(Json::Num(7.0)) })
        );
        // v1 requests have no id surface.
        assert_eq!(p(r#"{"v":1,"id":7,"op":"top"}"#), Ok(Envelope::v1()));
        // Ids can be any JSON value, echoed verbatim.
        assert_eq!(
            p(r#"{"v":2,"id":"abc","op":"top"}"#).unwrap().id,
            Some(Json::Str("abc".into()))
        );
        assert!(p(r#"{"v":3,"op":"top"}"#).is_err());
        assert!(p(r#"{"v":"two","op":"top"}"#).is_err());
    }

    #[test]
    fn requests_parse_into_typed_ops() {
        let p = |s: &str| Request::parse(&Json::parse(s).unwrap());
        assert_eq!(p(r#"{"op":"add","src":1,"dst":2}"#), Ok(Request::Write(EdgeOp::add(1, 2))));
        assert_eq!(p(r#"{"op":"query","top":3}"#), Ok(Request::Query { k: 3 }));
        assert_eq!(p(r#"{"op":"top","k":4}"#), Ok(Request::Top { k: 4 }));
        assert_eq!(p(r#"{"op":"top","top":4}"#), Ok(Request::Top { k: 4 }));
        assert_eq!(p(r#"{"op":"rank","id":9}"#), Ok(Request::Rank { id: 9 }));
        assert_eq!(p(r#"{"op":"unsubscribe","sub":3}"#), Ok(Request::Unsubscribe { sub: 3 }));
        assert!(p(r#"{"op":"rank"}"#).is_err());
        assert!(p(r#"{"op":"fly"}"#).is_err());
        assert!(p(r#"{"op":"batch"}"#).is_err());
        assert!(Request::parse(&Json::parse(r#"{"op":"top"}"#).unwrap()).unwrap().is_read());
        assert!(!p(r#"{"op":"query"}"#).unwrap().is_read());
    }

    #[test]
    fn responses_echo_the_request_envelope() {
        let v2 = Envelope { version: 2, id: Some(Json::Num(42.0)) };
        let j = Response::Ok.to_json(&v2);
        assert_eq!(j.get("v").and_then(Json::as_u64), Some(2));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("id").and_then(Json::as_u64), Some(42));
        // v1 responses carry no id key at all.
        let j1 = Response::Ok.to_json(&Envelope::v1());
        assert_eq!(j1.get("v").and_then(Json::as_u64), Some(1));
        assert!(j1.get("id").is_none());
        let err = Response::error("bad_op", "nope").to_json(&Envelope::v1());
        assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            err.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("bad_op")
        );
    }
}
