//! Sharded multi-engine scale-out: one in-process cluster of worker
//! shards behind the same serving surface as a single [`Engine`].
//!
//! ```text
//!             writes (EdgeOp)                reads (top / rank / stats)
//!                   │                                   │
//!            ┌──────▼──────┐            ┌───────────────▼─────────────┐
//!            │ Partitioner │            │ combined RankSnapshot (Arc) │
//!            │  (by src)   │            │   k-way merged top-K index  │
//!            └──┬───┬───┬──┘            └───────────────▲─────────────┘
//!               │   │   │                               │
//!          ┌────▼┐ ┌▼──┐ ┌▼───┐   boundary-rank   ┌─────┴─────┐
//!          │shard│ │...│ │shrd│ ◄── exchange ────► │ publish_all│
//!          │  0  │ │   │ │ N-1│   per iteration    └───────────┘
//!          └─────┘ └───┘ └────┘
//! ```
//!
//! Each shard owns a full write stack: its own [`DynamicGraph`]
//! slice of the vertex space (source-routed hash partition,
//! [`Partitioner`]), its own coalescing [`UpdateBuffer`], its own rank
//! vector, its own [`SnapshotPublisher`] and (optionally) its own worker
//! pool. Writes route by owner and coalesce per shard; PageRank runs as
//! the cross-shard boundary-rank exchange
//! ([`crate::pagerank::sharded::run_exchange_pooled`]), which converges
//! to the same fixed point as the single engine (same teleport /
//! dangling / `scaled_epsilon(n_total)` semantics — only floating-point
//! summation order differs, hence the documented `L1 < 1e-6`
//! equivalence tolerance). Reads never fan out at request time: every
//! publish freezes per-shard owned-only snapshots *and* one combined
//! snapshot whose global top-K is a k-way merge of the per-shard top-K
//! indexes ([`RankSnapshot::merged`]), so `top`/`rank`/`stats` stay
//! O(k) / O(log n) off-queue lookups.
//!
//! Three pieces keep the recompute plane off the critical path:
//!
//! - **Pooled exchange.** The per-shard halves of every iteration run
//!   on a cluster-level [`ThreadPool`] with fixed-shard-order
//!   reductions, so pooled output is bit-identical to the serial
//!   exchange at every worker count.
//! - **Plan cache.** [`ShardPlan`] is cached keyed on the per-shard
//!   graph versions and rebuilt incrementally — only shards whose
//!   version moved pay the O(E_s) rebuild (`plan_reused` /
//!   `plan_rebuilt` counters).
//! - **Fence reconciliation.** A fence-missed off-thread exchange is no
//!   longer discarded: the effective ops applied after the fence are
//!   replayed as a first-order rank correction over the touched
//!   vertices, so the published ranking absorbs the race without a
//!   second full exchange (`recomputes_reconciled`).
//!
//! The server-facing surface deliberately mirrors [`Engine`]:
//! `ingest` / `ingest_batch` / `query` / `query_async` /
//! `finish_recompute` / `reader`, so
//! [`crate::coordinator::server::ServerHandle`] drives either engine
//! behind the unchanged wire protocol. Durable serving (WAL +
//! checkpoints) is single-engine-only for now — a crash-consistent cut
//! across shards needs coordinated checkpointing (see ROADMAP).
//!
//! [`Engine`]: crate::coordinator::engine::Engine

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::engine::{
    AsyncQueryResult, QueryResult, RecomputeOutcome, ScheduleMode, FENCE_LOG_CAP,
};
use crate::coordinator::policies::StalenessPolicy;
use crate::coordinator::serving::{
    RankSnapshot, SnapshotPublisher, SnapshotReader, DEFAULT_PUBLISHED_TOP_K,
};
use crate::coordinator::udf::{Action, ExecStats};
use crate::error::{Error, Result};
use crate::graph::dynamic::DynamicGraph;
use crate::graph::partition::Partitioner;
use crate::graph::{VertexId, VertexIdx};
use crate::metrics::registry::MetricsRegistry;
use crate::pagerank::power::PageRankConfig;
use crate::pagerank::sharded::{run_exchange_pooled, ExchangeResult, ExchangeScratch, ShardPlan};
use crate::stream::buffer::UpdateBuffer;
use crate::stream::event::EdgeOp;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use crate::util::timer::Stopwatch;

/// One worker shard: a full write stack over its slice of the vertex
/// space. The graph holds every edge whose *source* this shard owns
/// (destinations may be ghosts); `ranks` is dense in the shard-local
/// index order, ghost slots carrying whatever the exchange last wrote
/// (they are never published — `publish_all` projects owned vertices
/// only).
struct Shard {
    graph: DynamicGraph,
    buffer: UpdateBuffer,
    ranks: Vec<f64>,
    publisher: SnapshotPublisher,
    pool: Option<Arc<ThreadPool>>,
    /// Graph version as of this shard's latest published snapshot —
    /// the republish trigger for topology-only changes.
    published_graph_version: u64,
}

/// Per-shard worker pool matching the config's `parallelism` knob
/// (the per-engine `pool_for` rule of `engine.rs`, applied
/// shard-locally: the default serial config spawns no threads at all).
fn pool_for_shard(pr: &PageRankConfig) -> Option<Arc<ThreadPool>> {
    match pr.parallelism {
        1 => None,
        0 => Some(Arc::new(ThreadPool::with_default_size())),
        k => Some(Arc::new(ThreadPool::new(k))),
    }
}

impl Shard {
    fn new(pr: &PageRankConfig) -> Self {
        Self {
            graph: DynamicGraph::new(),
            buffer: UpdateBuffer::new(),
            ranks: Vec::new(),
            publisher: SnapshotPublisher::new(),
            pool: pool_for_shard(pr),
            published_graph_version: 0,
        }
    }

    /// Drain + coalesce this shard's buffer and apply the effective ops.
    /// Returns the number of effective ops applied plus (when `log` is
    /// set, i.e. a recompute fence is armed) the effective ops
    /// themselves for the cluster fence log.
    fn apply_now(&mut self, pr: &PageRankConfig, log: bool) -> (usize, Vec<EdgeOp>) {
        if self.buffer.is_empty() {
            return (0, Vec::new());
        }
        let batch = self.buffer.take_batch(&self.graph);
        if batch.is_empty() {
            return (0, Vec::new());
        }
        let logged = if log { batch.ops().to_vec() } else { Vec::new() };
        let shards = match self.pool.as_deref() {
            Some(pool) => pr.effective_shards(pool),
            None => 1,
        };
        let applied = self.graph.apply_batch(batch.ops(), self.pool.as_deref(), shards).applied;
        (applied, logged)
    }
}

/// Effective ops applied after a recompute fence was captured — the
/// reconciliation input that turns a fence miss into a cheap
/// first-order correction instead of a discarded exchange. Tainted
/// (and emptied) by vertex removals — reconciliation needs pre-removal
/// adjacency the live graphs no longer have — and by growth past
/// [`FENCE_LOG_CAP`], where replay would approach recompute cost.
struct ShardedFenceLog {
    /// Per-shard graph versions the paired recompute was fenced at; the
    /// log only reconciles the job it was armed for.
    from_versions: Vec<u64>,
    ops: Vec<EdgeOp>,
    tainted: bool,
}

impl ShardedFenceLog {
    fn append(&mut self, ops: &[EdgeOp]) {
        if self.tainted {
            return;
        }
        let removes = ops.iter().any(|op| matches!(op, EdgeOp::RemoveVertex(_)));
        if removes || self.ops.len() + ops.len() > FENCE_LOG_CAP {
            self.tainted = true;
            self.ops.clear();
            return;
        }
        self.ops.extend_from_slice(ops);
    }
}

/// Builder for [`ShardedEngine`].
pub struct ShardedEngineBuilder {
    shards: usize,
    pr_config: PageRankConfig,
    published_top_k: usize,
}

impl ShardedEngineBuilder {
    /// A cluster of `shards` workers (clamped to ≥ 1) with the default
    /// PageRank configuration.
    pub fn new(shards: usize) -> Self {
        Self {
            shards: shards.max(1),
            pr_config: PageRankConfig::default(),
            published_top_k: DEFAULT_PUBLISHED_TOP_K,
        }
    }

    /// Set the PageRank configuration (shared by every shard; its
    /// `parallelism` knob sizes each shard's *own* pool and the
    /// cluster-level exchange pool).
    pub fn pagerank(mut self, c: PageRankConfig) -> Self {
        self.pr_config = c;
        self
    }

    /// Top-K entries pre-ranked per published snapshot — per shard *and*
    /// for the combined merge (the merge is valid to exactly this cap).
    pub fn published_top_k(mut self, k: usize) -> Self {
        self.published_top_k = k;
        self
    }

    /// Build the cluster over an initial edge list and run the initial
    /// complete exchange (the paper's setup — "each execution will begin
    /// with a complete PageRank execution" — per shard).
    pub fn build_from_edges(
        self,
        edges: impl IntoIterator<Item = (VertexId, VertexId)>,
    ) -> Result<ShardedEngine> {
        let parts = Partitioner::new(self.shards);
        let shards: Vec<Shard> = (0..parts.shards()).map(|_| Shard::new(&self.pr_config)).collect();
        let exchange_pool = pool_for_shard(&self.pr_config);
        let mut engine = ShardedEngine {
            parts,
            shards,
            pr_config: self.pr_config,
            published_top_k: self.published_top_k,
            combined: SnapshotPublisher::new(),
            metrics: MetricsRegistry::new(),
            query_count: 0,
            queries_since_publish: 0,
            updates_since_refresh: 0,
            last_publish: Instant::now(),
            last_cut_edges: 0,
            plan_cache: None,
            scratch: None,
            exchange_pool,
            fence_log: None,
            reconcile: true,
            stopped: false,
        };
        engine.metrics.set("shards", engine.parts.shards() as f64);
        engine.ingest_batch(edges.into_iter().map(|(s, d)| EdgeOp::AddEdge(s, d)));
        engine.apply_pending();
        engine.updates_since_refresh = 0;
        engine.extend_ranks();
        let sw = Stopwatch::start();
        let (ex, cut_edges) = engine.run_exchange_now();
        let secs = sw.secs();
        engine.metrics.time("initial_exact_secs", secs);
        engine.install_exchange(0, ex, cut_edges, secs);
        Ok(engine)
    }
}

/// A version-fenced cross-shard recompute: the frozen exchange plan
/// plus per-shard id and warm rank vectors, captured at scheduling time
/// so the exchange runs on a worker thread while the cluster keeps
/// absorbing writes and serving reads — the sharded twin of
/// [`crate::coordinator::engine::RecomputeJob`]. The job carries the
/// engine's exchange scratch with it (returned via the result), so
/// iteration buffers are reused across recomputes instead of
/// reallocated; [`Self::run_with`] accepts a dedicated pool so the
/// per-shard halves of each iteration run in parallel off-thread too.
pub struct ShardedRecomputeJob {
    decision: Action,
    query_id: u64,
    graph_versions: Vec<u64>,
    accounted_updates: u64,
    plan: Arc<ShardPlan>,
    ids: Vec<Vec<VertexId>>,
    warm: Vec<Vec<f64>>,
    pr_config: PageRankConfig,
    scratch: Option<ExchangeScratch>,
}

/// One shard's recomputed ranking, keyed by external id so a fence miss
/// can merge by id into the moved graph.
struct ShardRanks {
    ids: Vec<VertexId>,
    ranks: Vec<f64>,
}

/// The outcome of a [`ShardedRecomputeJob`], handed back to the engine
/// thread via [`ShardedEngine::finish_recompute`].
pub struct ShardedRecomputeResult {
    query_id: u64,
    graph_versions: Vec<u64>,
    accounted_updates: u64,
    per_shard: Vec<ShardRanks>,
    iterations: usize,
    cut_edges: usize,
    elapsed_secs: f64,
    scratch: ExchangeScratch,
}

impl ShardedRecomputeJob {
    /// The accuracy tier the policy asked for. The exchange always runs
    /// the full cross-shard power method (there is no summarized sharded
    /// path yet), so both escalations produce an exact refresh.
    pub fn decision(&self) -> Action {
        self.decision
    }

    /// Measurement point that scheduled this job.
    pub fn query_id(&self) -> u64 {
        self.query_id
    }

    /// Run the boundary-rank exchange over the fenced plan, serially
    /// across shards. Pure compute — safe on any thread.
    pub fn run(self) -> ShardedRecomputeResult {
        self.run_with(None)
    }

    /// Run the boundary-rank exchange over the fenced plan, dispatching
    /// the per-shard halves of each iteration onto `pool` (bit-identical
    /// to [`Self::run`] at every worker count). Pure compute — safe on
    /// any thread, as long as it is not one of `pool`'s own workers.
    pub fn run_with(self, pool: Option<&ThreadPool>) -> ShardedRecomputeResult {
        let sw = Stopwatch::start();
        let mut scratch = self.scratch.unwrap_or_default();
        let ExchangeResult { ranks, iterations, .. } =
            run_exchange_pooled(&self.plan, &self.pr_config, Some(self.warm), pool, &mut scratch);
        let per_shard = self
            .ids
            .into_iter()
            .zip(ranks)
            .map(|(ids, ranks)| ShardRanks { ids, ranks })
            .collect();
        ShardedRecomputeResult {
            query_id: self.query_id,
            graph_versions: self.graph_versions,
            accounted_updates: self.accounted_updates,
            per_shard,
            iterations,
            cut_edges: self.plan.cut_edges(),
            elapsed_secs: sw.secs(),
            scratch,
        }
    }
}

impl ShardedRecomputeResult {
    /// An exchange always refreshes every owned vertex (mirror of
    /// [`crate::coordinator::engine::RecomputeResult::refreshed`], which
    /// can be false for empty-summary approximate jobs).
    pub fn refreshed(&self) -> bool {
        true
    }

    /// `updates_since_refresh` this job accounted for at its fence.
    pub fn accounted_updates(&self) -> u64 {
        self.accounted_updates
    }
}

/// An in-process sharded cluster behind the single-engine serving
/// surface. See the module docs for the architecture; see
/// [`crate::coordinator::server::ServerHandle::spawn_sharded`] for the
/// threaded wire-protocol wrapper.
pub struct ShardedEngine {
    parts: Partitioner,
    shards: Vec<Shard>,
    pr_config: PageRankConfig,
    published_top_k: usize,
    /// The merged union snapshot readers answer from.
    combined: SnapshotPublisher,
    metrics: MetricsRegistry,
    query_count: u64,
    queries_since_publish: u64,
    /// Effective ops applied across all shards since the last exchange
    /// was fenced — the staleness policies' accumulated-error proxy.
    updates_since_refresh: u64,
    last_publish: Instant,
    /// Cut edges of the most recent exchange (the boundary-exchange
    /// volume gauge).
    last_cut_edges: usize,
    /// Cached exchange plan keyed on the per-shard graph versions it
    /// was built from — reused verbatim while no shard's topology
    /// moves, incrementally rebuilt (dirty shards only) otherwise.
    plan_cache: Option<(Arc<ShardPlan>, Vec<u64>)>,
    /// Exchange working memory (contribution / accumulator / inbox
    /// buffers) carried across recomputes — the sharded analogue of
    /// `SummaryScratch`. Taken by off-thread jobs and handed back
    /// through their results.
    scratch: Option<ExchangeScratch>,
    /// Cluster-level pool the pooled exchange dispatches per-shard
    /// halves onto (sized by `pr_config.parallelism`, like the
    /// per-shard apply pools).
    exchange_pool: Option<Arc<ThreadPool>>,
    /// Post-fence effective ops, armed per recompute while
    /// reconciliation is on.
    fence_log: Option<ShardedFenceLog>,
    /// Reconcile fence-missed recomputes instead of discarding their
    /// staleness accounting to a plain merge.
    reconcile: bool,
    stopped: bool,
}

impl ShardedEngine {
    // ---- write path ----------------------------------------------------

    /// Ingest one graph operation, routed to the shard(s) it concerns.
    pub fn ingest(&mut self, op: EdgeOp) {
        let parts = self.parts;
        parts.for_each_route(op, |s, op| self.shards[s].buffer.register(op));
        self.metrics.inc("ops_ingested", 1);
        self.refresh_ingest_gauges();
    }

    /// Ingest a batch: route every op, then one metrics update. Per-shard
    /// order preserves the caller's order, so each shard's coalescer
    /// replays exactly the subsequence that concerns it.
    pub fn ingest_batch(&mut self, ops: impl IntoIterator<Item = EdgeOp>) {
        let parts = self.parts;
        let mut n = 0u64;
        for op in ops {
            n += 1;
            parts.for_each_route(op, |s, op| self.shards[s].buffer.register(op));
        }
        self.metrics.inc("ops_ingested", n);
        self.metrics.inc("batches_ingested", 1);
        self.refresh_ingest_gauges();
    }

    /// Drain + apply every shard's pending buffer. Shards apply
    /// independently (scoped threads when more than one shard has work —
    /// the scale-out of the write path), the per-shard effective-op
    /// counts sum into the cluster staleness signal, and — while a
    /// recompute fence is armed — the effective ops append to the fence
    /// log in shard order for deterministic reconciliation.
    fn apply_pending(&mut self) {
        let with_work = self.shards.iter().filter(|s| !s.buffer.is_empty()).count();
        if with_work == 0 {
            return;
        }
        let sw = Stopwatch::start();
        let pr = self.pr_config;
        let log = self.fence_log.is_some();
        let results: Vec<(usize, Vec<EdgeOp>)> = if with_work == 1 {
            self.shards.iter_mut().map(|sh| sh.apply_now(&pr, log)).collect()
        } else {
            std::thread::scope(|sc| {
                let handles: Vec<_> = self
                    .shards
                    .iter_mut()
                    .map(|sh| sc.spawn(move || sh.apply_now(&pr, log)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("shard apply panicked")).collect()
            })
        };
        let applied: u64 = results.iter().map(|(a, _)| *a as u64).sum();
        if let Some(flog) = &mut self.fence_log {
            for (_, ops) in results {
                flog.append(&ops);
            }
        }
        self.metrics.time("ingest_apply_secs", sw.secs());
        self.metrics.inc("applies", 1);
        self.updates_since_refresh += applied;
        self.refresh_ingest_gauges();
    }

    /// Mirror the summed per-shard coalescing counters into the combined
    /// publisher's live gauges (the wire `stats.ingest` section).
    fn refresh_ingest_gauges(&self) {
        use std::sync::atomic::Ordering;
        let (mut raw, mut eff, mut pending) = (0u64, 0u64, 0u64);
        for sh in &self.shards {
            let (r, e) = sh.buffer.coalesce_totals();
            raw += r as u64;
            eff += e as u64;
            pending += sh.buffer.pending_effective_estimate() as u64;
        }
        let g = self.combined.ingest_gauges();
        g.coalesced_raw_ops.store(raw, Ordering::Relaxed);
        g.coalesced_effective_ops.store(eff, Ordering::Relaxed);
        g.pending_effective_estimate.store(pending, Ordering::Relaxed);
    }

    /// Extend every shard's rank vector for vertices that appeared since
    /// the last exchange (new and ghost slots get the uniform init).
    fn extend_ranks(&mut self) {
        let n: usize = self.shards.iter().map(|s| s.graph.num_vertices()).sum();
        let init = self.pr_config.init_rank(n.max(1));
        for sh in &mut self.shards {
            let l = sh.graph.num_vertices();
            if sh.ranks.len() < l {
                sh.ranks.resize(l, init);
            }
        }
    }

    // ---- compute -------------------------------------------------------

    /// The exchange plan for the current per-shard topology, from the
    /// cache when no shard's graph version moved, otherwise rebuilt
    /// incrementally (clean shards keep their scatter/gather tables —
    /// sound because [`DynamicGraph`] never reassigns a live vertex's
    /// dense index).
    fn ensure_plan(&mut self) -> Arc<ShardPlan> {
        let versions: Vec<u64> = self.shards.iter().map(|s| s.graph.version()).collect();
        if let Some((plan, cached)) = &self.plan_cache {
            if *cached == versions {
                self.metrics.inc("plan_reused", 1);
                return Arc::clone(plan);
            }
        }
        let graphs: Vec<&DynamicGraph> = self.shards.iter().map(|s| &s.graph).collect();
        let plan = match self.plan_cache.take() {
            Some((mut plan, cached)) => {
                let dirty: Vec<bool> =
                    cached.iter().zip(&versions).map(|(a, b)| a != b).collect();
                Arc::make_mut(&mut plan).rebuild_shards(&graphs, &self.parts, &dirty);
                plan
            }
            None => Arc::new(ShardPlan::build(&graphs, &self.parts)),
        };
        self.metrics.inc("plan_rebuilt", 1);
        self.plan_cache = Some((Arc::clone(&plan), versions));
        plan
    }

    /// Freeze the exchange topology from the live shard graphs (via the
    /// plan cache) and run the pooled boundary exchange inline,
    /// warm-started from the current per-shard rank vectors. Returns
    /// the result plus the cut-edge count of the frozen plan.
    fn run_exchange_now(&mut self) -> (ExchangeResult, usize) {
        let plan = self.ensure_plan();
        let cut = plan.cut_edges();
        let warm: Vec<Vec<f64>> = self.shards.iter().map(|s| s.ranks.clone()).collect();
        let mut scratch = self.scratch.take().unwrap_or_default();
        let ex = run_exchange_pooled(
            &plan,
            &self.pr_config,
            Some(warm),
            self.exchange_pool.as_deref(),
            &mut scratch,
        );
        self.scratch = Some(scratch);
        (ex, cut)
    }

    /// Install exchange output as the live per-shard rankings and publish
    /// (fresh: staleness anchors reset).
    fn install_exchange(&mut self, query_id: u64, ex: ExchangeResult, cut: usize, secs: f64) {
        for (sh, r) in self.shards.iter_mut().zip(ex.ranks) {
            sh.ranks = r;
        }
        self.note_exchange(ex.iterations, cut);
        let exec =
            ExecStats { iterations: ex.iterations, elapsed_secs: secs, ..ExecStats::default() };
        self.metrics.inc("action_exact", 1);
        self.publish_all(query_id, Action::ComputeExact, exec, true);
    }

    fn note_exchange(&mut self, iterations: usize, cut_edges: usize) {
        self.last_cut_edges = cut_edges;
        self.metrics.set("exchange_iterations", iterations as f64);
        self.metrics.set("cut_edges", cut_edges as f64);
    }

    // ---- query path ----------------------------------------------------

    /// Apply pending routed updates on every shard now, without serving
    /// a query — the server flushes before deciding whether an in-flight
    /// recompute is stale enough to supersede.
    pub fn flush_pending(&mut self) {
        self.apply_pending();
        self.extend_ranks();
    }

    /// Serve one query synchronously: absorb pending writes, run the
    /// exchange inline, publish, answer. The blocking twin of
    /// [`Self::query_async`] (used by tests and batch replays; the server
    /// rides the async path).
    pub fn query(&mut self) -> Result<QueryResult> {
        if self.stopped {
            return Err(Error::Engine("sharded engine is stopped".into()));
        }
        self.query_count += 1;
        let query_id = self.query_count;
        self.apply_pending();
        self.extend_ranks();
        let sw = Stopwatch::start();
        let (ex, cut) = self.run_exchange_now();
        let secs = sw.secs();
        self.updates_since_refresh = 0;
        self.metrics.inc("queries", 1);
        let exec =
            ExecStats { iterations: ex.iterations, elapsed_secs: secs, ..ExecStats::default() };
        self.install_exchange(query_id, ex, cut, secs);
        let snapshot = self.combined.latest();
        Ok(QueryResult { query_id, action: Action::ComputeExact, exec, snapshot })
    }

    /// The asynchronous serving path, mirroring
    /// [`Engine::query_async`]: absorb pending writes, answer from the
    /// (republished) combined snapshot immediately, and — when the
    /// staleness policy escalates and `mode` allows — hand back a
    /// version-fenced [`ShardedRecomputeJob`] for a worker thread.
    ///
    /// [`Engine::query_async`]: crate::coordinator::engine::Engine::query_async
    pub fn query_async(
        &mut self,
        policy: &StalenessPolicy,
        pressure: f64,
        mode: ScheduleMode,
    ) -> Result<(AsyncQueryResult, Option<ShardedRecomputeJob>)> {
        if self.stopped {
            return Err(Error::Engine("sharded engine is stopped".into()));
        }
        self.query_count += 1;
        let query_id = self.query_count;
        self.apply_pending();
        self.extend_ranks();
        let age_secs = self.last_publish.elapsed().as_secs_f64();
        self.metrics.set("snapshot_age_secs", age_secs);
        self.metrics.set("snapshot_age_queries", self.queries_since_publish as f64);
        let decision = policy.decide_under_pressure(
            self.updates_since_refresh,
            self.queries_since_publish,
            age_secs,
            pressure,
        );
        self.metrics.inc("queries", 1);
        self.metrics.inc("async_queries", 1);
        self.metrics.inc(
            match decision {
                Action::RepeatLast => "decision_repeat-last",
                Action::ComputeApproximate => "decision_approximate",
                Action::ComputeExact => "decision_exact",
            },
            1,
        );
        self.queries_since_publish += 1;
        let may_schedule = match mode {
            ScheduleMode::Never => false,
            ScheduleMode::WhenDue => decision != Action::RepeatLast,
            ScheduleMode::ExactOnly => decision == Action::ComputeExact,
        };
        let job = if may_schedule { Some(self.begin_recompute(decision, query_id)) } else { None };
        // Readers must see absorbed topology even though the ranking is
        // unchanged — republish carrying the age anchor forward.
        if self.shards.iter().any(|s| s.graph.version() != s.published_graph_version) {
            self.publish_all(query_id, Action::RepeatLast, ExecStats::default(), false);
        }
        let snapshot = self.combined.latest();
        Ok((AsyncQueryResult { query_id, decision, scheduled: job.is_some(), snapshot }, job))
    }

    /// Capture a version-fenced [`ShardedRecomputeJob`], taking ownership
    /// of the accumulated-updates signal it accounts for. Arms the fence
    /// log when reconciliation is on, so writes landing while the job is
    /// in flight stay replayable.
    fn begin_recompute(&mut self, decision: Action, query_id: u64) -> ShardedRecomputeJob {
        let accounted_updates = self.updates_since_refresh;
        self.updates_since_refresh = 0;
        self.metrics.inc("recomputes_scheduled", 1);
        let plan = self.ensure_plan();
        let graph_versions: Vec<u64> = self.shards.iter().map(|s| s.graph.version()).collect();
        if self.reconcile {
            self.fence_log = Some(ShardedFenceLog {
                from_versions: graph_versions.clone(),
                ops: Vec::new(),
                tainted: false,
            });
        }
        ShardedRecomputeJob {
            decision,
            query_id,
            graph_versions,
            accounted_updates,
            plan,
            ids: self.shards.iter().map(|s| s.graph.ids().to_vec()).collect(),
            warm: self.shards.iter().map(|s| s.ranks.clone()).collect(),
            pr_config: self.pr_config,
            scratch: self.scratch.take(),
        }
    }

    /// Integrate an off-thread exchange back into the cluster and
    /// publish. `fence_ok` reports whether the fence held on *every*
    /// shard; on a miss the fenced rankings merge by vertex id into the
    /// moved shard graphs and — when the armed fence log is clean — the
    /// post-fence ops replay as a first-order rank correction
    /// (`reconciled`), so the miss does not cost a second exchange.
    pub fn finish_recompute(&mut self, res: ShardedRecomputeResult) -> RecomputeOutcome {
        self.metrics.inc("recomputes_offthread", 1);
        self.metrics.time("recompute_offthread_secs", res.elapsed_secs);
        self.scratch = Some(res.scratch);
        let log = self.fence_log.take();
        let fence_ok = res.graph_versions.len() == self.shards.len()
            && res.graph_versions.iter().zip(&self.shards).all(|(&v, sh)| v == sh.graph.version());
        let mut reconciled = false;
        if fence_ok {
            for (sh, sr) in self.shards.iter_mut().zip(res.per_shard) {
                sh.ranks = sr.ranks;
            }
        } else {
            self.extend_ranks();
            for (sh, sr) in self.shards.iter_mut().zip(res.per_shard) {
                for (id, r) in sr.ids.iter().zip(&sr.ranks) {
                    if let Some(idx) = sh.graph.index(*id) {
                        sh.ranks[idx as usize] = *r;
                    }
                }
            }
            match log {
                Some(log)
                    if self.reconcile
                        && !log.tainted
                        && log.from_versions == res.graph_versions =>
                {
                    self.reconcile_touched(&log.ops);
                    self.metrics.inc("recomputes_reconciled", 1);
                    reconciled = true;
                }
                _ => {
                    self.metrics.inc("recompute_fence_misses", 1);
                }
            }
        }
        self.metrics.inc("action_exact", 1);
        self.note_exchange(res.iterations, res.cut_edges);
        let exec = ExecStats {
            iterations: res.iterations,
            elapsed_secs: res.elapsed_secs,
            ..ExecStats::default()
        };
        self.publish_all(res.query_id, Action::ComputeExact, exec, true);
        RecomputeOutcome { fence_ok, reconciled }
    }

    /// Replay post-fence ops as a first-order rank correction: every
    /// vertex whose in-mass an op changed (endpoints plus the source's
    /// current out-neighbors, whose per-edge share moved with the
    /// out-degree) gets one gather
    /// `teleport + β·Σ_{w∈in(v)} r_w / d_out(w) + dangling-share`
    /// from a frozen base, writes applied after the sweep so the pass
    /// is order-independent. In-neighbors in any shard are always that
    /// shard's owned sources (edges live at their source's owner), so
    /// summing across the shards that know `v` counts each in-edge
    /// exactly once.
    fn reconcile_touched(&mut self, ops: &[EdgeOp]) {
        use std::collections::BTreeSet;
        let parts = self.parts;
        let mut touched: BTreeSet<VertexId> = BTreeSet::new();
        for op in ops {
            match *op {
                EdgeOp::AddEdge(u, d) | EdgeOp::RemoveEdge(u, d) => {
                    touched.insert(u);
                    touched.insert(d);
                    let g = &self.shards[parts.shard_of(u)].graph;
                    if let Some(ui) = g.index(u) {
                        for &w in g.out_neighbors(ui) {
                            touched.insert(g.id(w));
                        }
                    }
                }
                EdgeOp::AddVertex(v) => {
                    touched.insert(v);
                }
                EdgeOp::RemoveVertex(_) => unreachable!("tainted fence log reached reconciliation"),
            }
        }
        if touched.is_empty() {
            return;
        }
        // Global owned count + dangling mass over the merged base ranks.
        let mut n = 0usize;
        let mut dangling_mass = 0.0;
        for (s, sh) in self.shards.iter().enumerate() {
            for u in 0..sh.graph.num_vertices() as VertexIdx {
                if parts.shard_of(sh.graph.id(u)) != s {
                    continue;
                }
                n += 1;
                if sh.graph.out_degree(u) == 0 {
                    dangling_mass += sh.ranks[u as usize];
                }
            }
        }
        if n == 0 {
            return;
        }
        let cfg = &self.pr_config;
        let teleport = cfg.teleport(n);
        let share =
            if cfg.dangling_redistribution { cfg.beta * dangling_mass / n as f64 } else { 0.0 };
        let mut fixes: Vec<(usize, VertexIdx, f64)> = Vec::with_capacity(touched.len());
        for &vid in &touched {
            let owner = parts.shard_of(vid);
            let Some(idx) = self.shards[owner].graph.index(vid) else {
                continue; // coalesced away before the fence resolved
            };
            let mut in_mass = 0.0;
            for sh in &self.shards {
                if let Some(li) = sh.graph.index(vid) {
                    for &w in sh.graph.in_neighbors(li) {
                        let d = sh.graph.out_degree(w);
                        if d > 0 {
                            in_mass += sh.ranks[w as usize] / d as f64;
                        }
                    }
                }
            }
            fixes.push((owner, idx, teleport + cfg.beta * in_mass + share));
        }
        let fixed = fixes.len() as u64;
        for (owner, idx, x) in fixes {
            self.shards[owner].ranks[idx as usize] = x;
        }
        self.metrics.inc("reconciled_vertices", fixed);
    }

    // ---- publish -------------------------------------------------------

    /// The one publish path: freeze per-shard owned-only snapshots (ghost
    /// slots never leave the shard), then the combined union snapshot via
    /// the k-way top-K merge — all under one shared version counter.
    /// `fresh` distinguishes a genuine exchange (staleness anchors reset)
    /// from a topology-only republish (the age anchor carries forward,
    /// exactly as in the single engine's `publish_snapshot`).
    fn publish_all(&mut self, query_id: u64, action: Action, exec: ExecStats, fresh: bool) {
        let latest = self.combined.latest();
        let version = latest.version + 1;
        let carry = if fresh || latest.version == 0 { None } else { Some(latest.published_at) };
        let parts = self.parts;
        let cap = self.published_top_k;
        let mut shard_snaps: Vec<Arc<RankSnapshot>> = Vec::with_capacity(self.shards.len());
        for (i, sh) in self.shards.iter_mut().enumerate() {
            let n = sh.graph.num_vertices();
            let mut ids = Vec::with_capacity(n);
            let mut ranks = Vec::with_capacity(n);
            for u in 0..n as VertexIdx {
                let id = sh.graph.id(u);
                if parts.shard_of(id) == i {
                    ids.push(id);
                    ranks.push(sh.ranks[u as usize]);
                }
            }
            let mut snap = RankSnapshot::new(
                version,
                sh.graph.version(),
                query_id,
                action,
                exec.clone(),
                ids,
                ranks,
                cap,
                Json::Null,
            );
            if let Some(at) = carry {
                snap.published_at = at;
            }
            let snap = Arc::new(snap);
            sh.publisher.publish(Arc::clone(&snap));
            sh.published_graph_version = sh.graph.version();
            shard_snaps.push(snap);
        }
        let refs: Vec<&RankSnapshot> = shard_snaps.iter().map(|s| s.as_ref()).collect();
        let combined = RankSnapshot::merged(
            version,
            self.version_token(),
            query_id,
            action,
            exec,
            &refs,
            cap,
            self.metrics.to_json(),
            carry,
        );
        let combined = Arc::new(combined);
        self.last_publish = combined.published_at;
        self.combined.publish(combined);
        if fresh {
            self.queries_since_publish = 0;
        }
    }

    // ---- accessors -----------------------------------------------------

    /// Number of worker shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The vertex→shard assignment.
    pub fn partitioner(&self) -> Partitioner {
        self.parts
    }

    /// One shard's live graph (tests; the server reads snapshots).
    pub fn shard_graph(&self, shard: usize) -> &DynamicGraph {
        &self.shards[shard].graph
    }

    /// Read handle over the combined union snapshot — what the server's
    /// `top` / `rank` / `stats` ops answer from.
    pub fn reader(&self) -> SnapshotReader {
        self.combined.reader()
    }

    /// Per-shard read handles (owned-only snapshots) — the server's
    /// partition-routed `rank` path and per-shard stats gauges.
    pub fn shard_readers(&self) -> Vec<SnapshotReader> {
        self.shards.iter().map(|s| s.publisher.reader()).collect()
    }

    /// The latest combined snapshot.
    pub fn latest_snapshot(&self) -> Arc<RankSnapshot> {
        self.combined.latest()
    }

    /// Cluster metrics registry.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Cut edges of the most recent exchange.
    pub fn cut_edges(&self) -> usize {
        self.last_cut_edges
    }

    /// Plan-cache effectiveness counters: `(reused, rebuilt)`.
    pub fn plan_counters(&self) -> (u64, u64) {
        (self.metrics.counter("plan_reused"), self.metrics.counter("plan_rebuilt"))
    }

    /// Toggle fence reconciliation (on by default). Off restores the
    /// PR-9 behavior: a fence miss merges by id and counts a
    /// `recompute_fence_misses`.
    pub fn set_reconcile(&mut self, on: bool) {
        self.reconcile = on;
        if !on {
            self.fence_log = None;
        }
    }

    /// A cheap monotone token over the whole cluster's topology (sum of
    /// per-shard graph versions) — moves whenever any shard's graph
    /// moves. The sharded analogue of `graph().version()` for the
    /// server's supersession fence.
    pub fn version_token(&self) -> u64 {
        self.shards.iter().map(|s| s.graph.version()).sum()
    }

    /// Stop serving (subsequent queries error).
    pub fn stop(&mut self) {
        self.stopped = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pagerank::power::PageRank;

    fn test_edges() -> Vec<(u64, u64)> {
        let mut edges: Vec<(u64, u64)> = (0..30u64).map(|i| (i, (i + 1) % 30)).collect();
        edges.extend((0..10u64).map(|i| (3 * i, (i * 7 + 3) % 30)));
        edges
    }

    /// L1 distance between the cluster's combined snapshot and a
    /// single-engine exact PageRank over the same edges.
    fn l1_vs_single(engine: &ShardedEngine, single: &DynamicGraph) -> f64 {
        let exact = PageRank::new(PageRankConfig::default()).run(&single.snapshot());
        let snap = engine.latest_snapshot();
        assert_eq!(snap.ids.len(), single.num_vertices(), "owned union != single vertex set");
        let mut l1 = 0.0;
        for (idx, &id) in single.ids().iter().enumerate() {
            let r = snap.rank_of(id).expect("combined snapshot misses a vertex");
            l1 += (r - exact.ranks[idx]).abs();
        }
        l1
    }

    #[test]
    fn initial_build_matches_single_engine() {
        let edges = test_edges();
        let (single, _) = DynamicGraph::from_edges(edges.clone());
        for shards in [1usize, 2, 4] {
            let engine = ShardedEngineBuilder::new(shards).build_from_edges(edges.clone()).unwrap();
            let l1 = l1_vs_single(&engine, &single);
            assert!(l1 < 1e-6, "shards={shards}: L1={l1}");
        }
    }

    #[test]
    fn sync_query_tracks_mutations() {
        let edges = test_edges();
        let mut engine = ShardedEngineBuilder::new(3).build_from_edges(edges.clone()).unwrap();
        let (mut single, _) = DynamicGraph::from_edges(edges);
        for (s, d) in [(30u64, 0u64), (31, 30), (5, 31)] {
            engine.ingest(EdgeOp::AddEdge(s, d));
            single.add_edge(s, d).unwrap();
        }
        engine.ingest(EdgeOp::RemoveEdge(0, 1));
        single.remove_edge(0, 1).unwrap();
        let res = engine.query().unwrap();
        assert_eq!(res.action, Action::ComputeExact);
        let l1 = l1_vs_single(&engine, &single);
        assert!(l1 < 1e-6, "post-mutation L1={l1}");
    }

    #[test]
    fn async_schedule_run_finish_round_trip() {
        let mut engine = ShardedEngineBuilder::new(2).build_from_edges(test_edges()).unwrap();
        let policy = StalenessPolicy::default();
        engine.ingest(EdgeOp::AddEdge(40, 0));
        let (a, job) = engine.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        assert!(a.scheduled, "an applied update must escalate past RepeatLast");
        // The immediate answer already sees the absorbed topology.
        assert!(a.snapshot.rank_of(40).is_some());
        let before = engine.latest_snapshot().version;
        let res = job.unwrap().run();
        assert!(engine.finish_recompute(res).fence_ok, "no writes moved the fence");
        assert!(engine.latest_snapshot().version > before);
        // Never mode records the decision but schedules nothing.
        engine.ingest(EdgeOp::AddEdge(41, 40));
        let (a, job) = engine.query_async(&policy, 0.0, ScheduleMode::Never).unwrap();
        assert!(!a.scheduled && job.is_none());
        assert_ne!(a.decision, Action::RepeatLast);
    }

    #[test]
    fn fence_miss_merges_by_id() {
        let mut engine = ShardedEngineBuilder::new(2).build_from_edges(test_edges()).unwrap();
        engine.set_reconcile(false);
        let policy = StalenessPolicy::default();
        engine.ingest(EdgeOp::AddEdge(50, 1));
        let (_, job) = engine.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        let job = job.unwrap();
        // The graph moves while the job is in flight: fence must miss,
        // fenced ranks merge by id, new vertex keeps a rank.
        engine.ingest(EdgeOp::AddEdge(51, 50));
        engine.apply_pending();
        let res = job.run();
        assert!(!engine.finish_recompute(res).fence_ok);
        assert_eq!(engine.metrics().counter("recompute_fence_misses"), 1);
        let snap = engine.latest_snapshot();
        assert!(snap.rank_of(50).is_some());
        assert!(snap.rank_of(51).is_some());
    }

    #[test]
    fn fence_miss_reconciles_instead_of_discarding() {
        let mut engine = ShardedEngineBuilder::new(2).build_from_edges(test_edges()).unwrap();
        let policy = StalenessPolicy::default();
        engine.ingest(EdgeOp::AddEdge(50, 1));
        let (_, job) = engine.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        let job = job.unwrap();
        // Post-fence writes land while the job runs: with the fence log
        // armed, the miss reconciles instead of counting as a miss.
        engine.ingest(EdgeOp::AddEdge(51, 50));
        engine.flush_pending();
        let out = engine.finish_recompute(job.run());
        assert!(!out.fence_ok && out.reconciled);
        assert_eq!(engine.metrics().counter("recomputes_reconciled"), 1);
        assert_eq!(engine.metrics().counter("recompute_fence_misses"), 0);
        assert!(engine.metrics().counter("reconciled_vertices") >= 2);
        let snap = engine.latest_snapshot();
        let r50 = snap.rank_of(50).expect("fenced vertex kept its rank");
        let r51 = snap.rank_of(51).expect("post-fence vertex got a reconciled rank");
        // A reconciled rank is a full first-order gather: at least the
        // teleport floor, not the uniform-init placeholder semantics.
        let teleport = PageRankConfig::default().teleport(snap.ids.len());
        assert!(r50 > 0.0 && r51 >= teleport - 1e-12, "r50={r50} r51={r51}");
    }

    #[test]
    fn vertex_removal_taints_the_fence_log() {
        let mut engine = ShardedEngineBuilder::new(2).build_from_edges(test_edges()).unwrap();
        let policy = StalenessPolicy::default();
        engine.ingest(EdgeOp::AddEdge(50, 1));
        let (_, job) = engine.query_async(&policy, 0.0, ScheduleMode::WhenDue).unwrap();
        let job = job.unwrap();
        // Removals need pre-removal adjacency the live graphs no longer
        // have — the log taints and the miss falls back to the merge.
        engine.ingest(EdgeOp::RemoveVertex(5));
        engine.flush_pending();
        let out = engine.finish_recompute(job.run());
        assert!(!out.fence_ok && !out.reconciled, "removals must fall back to the plain merge");
        assert_eq!(engine.metrics().counter("recompute_fence_misses"), 1);
        assert_eq!(engine.metrics().counter("recomputes_reconciled"), 0);
    }

    #[test]
    fn plan_cache_reuses_until_topology_moves() {
        let mut engine = ShardedEngineBuilder::new(3).build_from_edges(test_edges()).unwrap();
        assert_eq!(engine.plan_counters(), (0, 1), "initial exchange builds the plan");
        engine.query().unwrap();
        assert_eq!(engine.plan_counters(), (1, 1), "unchanged topology reuses the plan");
        engine.ingest(EdgeOp::AddEdge(60, 0));
        engine.query().unwrap();
        assert_eq!(engine.plan_counters(), (1, 2), "a moved shard version rebuilds");
    }
}
