//! Read/write-split serving: immutable published rank snapshots.
//!
//! VeilGraph's model (Fig. 2, Alg. 1) separates the update stream from
//! query answering. The engine thread is the single *writer*: it ingests
//! mutations, recomputes ranks, and after every recompute publishes an
//! immutable, versioned [`RankSnapshot`] behind an `Arc`. Any number of
//! *readers* ([`SnapshotReader`], cloneable across threads) answer
//! `top` / `rank` / `stats` requests from the latest published snapshot
//! without ever entering the engine command queue — the standard
//! read/write split of streaming graph systems (Besta et al., *Practice
//! of Streaming Processing of Dynamic Graphs*), and the way
//! approximate-PageRank servers amortize one recompute across many cheap
//! reads (FrogWild!).
//!
//! Synchronization budget: the snapshot slot is a pointer-sized
//! `RwLock<Arc<..>>` held only for the load/store of the `Arc` itself —
//! a reader's critical section is one refcount increment, and the writer
//! swap is O(1) *after* the recompute finished. A reader therefore never
//! waits on a recompute in progress, no matter how slow the writer is.
//! Snapshots are immutable once published, so torn reads are impossible
//! by construction: version, ids, ranks and the top-K index travel in
//! one allocation.
//!
//! A publish can come from three producers — an inline blocking query,
//! an off-thread recompute whose version fence held, or a fence-missed
//! recompute salvaged by reconciliation (the post-fence ops replayed
//! onto its ranks before the swap; see the `recomputes_reconciled` /
//! `plan_reused` / `plan_rebuilt` / `recompute_pool_size` gauges in the
//! wire `stats.server` section). Readers cannot tell the difference:
//! every snapshot is equally immutable and carries the [`Action`] and
//! [`ExecStats`] of whatever produced it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::coordinator::subscription::SubscriptionRegistry;
use crate::coordinator::udf::{Action, ExecStats};
use crate::graph::VertexId;
use crate::metrics::ranking::top_k_indices;
use crate::util::json::Json;

/// How many top entries each published snapshot pre-ranks. `top(k)` with
/// `k` at or below this cap is an O(k) copy off the snapshot; larger `k`
/// falls back to an O(n + k log k) selection (still off-queue). Tunable
/// per engine via
/// [`crate::coordinator::engine::EngineBuilder::published_top_k`].
pub const DEFAULT_PUBLISHED_TOP_K: usize = 128;

/// One immutable published ranking: everything a read-only client can ask
/// for, frozen at a measurement point. Shared as `Arc<RankSnapshot>`; no
/// per-query O(|V|) clones anywhere on the read path.
#[derive(Clone, Debug)]
pub struct RankSnapshot {
    /// Publish counter: 0 for the placeholder before the initial
    /// computation, then strictly increasing per published recompute.
    pub version: u64,
    /// [`crate::graph::dynamic::DynamicGraph::version`] at publish time.
    pub graph_version: u64,
    /// Measurement point that produced this ranking (0 = initial).
    pub query_id: u64,
    /// How the ranking was produced.
    pub action: Action,
    /// Execution statistics of the producing query.
    pub exec: ExecStats,
    /// Vertex ids in dense order, aligned with `ranks`.
    pub ids: Vec<VertexId>,
    /// PageRank scores (full graph).
    pub ranks: Vec<f64>,
    /// Engine metrics as of publish time (serves off-queue `stats`).
    pub engine_metrics: Json,
    /// When this snapshot was produced — the staleness anchor behind
    /// [`Self::age_secs`] and the `age_secs` gauge in
    /// [`SnapshotReader::stats_json`], so SLA clients can see result
    /// freshness, not just the version counter.
    pub published_at: Instant,
    /// Dense positions of the top `top_k_cap` entries, pre-sorted by
    /// (score desc, id asc) — the deterministic tie-break used everywhere.
    top_index: Vec<u32>,
    /// Dense positions sorted by vertex id — O(log n) `rank_of` lookups.
    by_id: Vec<u32>,
    /// The engine's hot set |K| at the recompute that produced this
    /// ranking, as sorted external ids (empty after an exact run, where
    /// no summary was built). Lets hot-set standing queries diff
    /// membership between consecutive snapshots in O(log |K|).
    hot: Vec<VertexId>,
}

impl RankSnapshot {
    /// The placeholder published before the initial computation.
    pub fn empty() -> Self {
        Self {
            version: 0,
            graph_version: 0,
            query_id: 0,
            action: Action::RepeatLast,
            exec: ExecStats::default(),
            ids: Vec::new(),
            ranks: Vec::new(),
            engine_metrics: Json::Null,
            published_at: Instant::now(),
            top_index: Vec::new(),
            by_id: Vec::new(),
            hot: Vec::new(),
        }
    }

    /// Freeze a ranking, precomputing the deterministic top-K index and
    /// the id-order permutation. O(n log n) once per publish — never on
    /// the read path.
    pub fn new(
        version: u64,
        graph_version: u64,
        query_id: u64,
        action: Action,
        exec: ExecStats,
        ids: Vec<VertexId>,
        ranks: Vec<f64>,
        top_k_cap: usize,
        engine_metrics: Json,
    ) -> Self {
        assert_eq!(ids.len(), ranks.len());
        let top_index: Vec<u32> =
            top_k_indices(&ids, &ranks, top_k_cap).into_iter().map(|i| i as u32).collect();
        let mut by_id: Vec<u32> = (0..ids.len() as u32).collect();
        by_id.sort_unstable_by_key(|&i| ids[i as usize]);
        Self {
            version,
            graph_version,
            query_id,
            action,
            exec,
            ids,
            ranks,
            engine_metrics,
            published_at: Instant::now(),
            top_index,
            by_id,
            hot: Vec::new(),
        }
    }

    /// Freeze the union of per-shard snapshots into one combined
    /// ranking, **reusing** each shard's precomputed deterministic top-K
    /// index instead of re-selecting over the union: the global top-K is
    /// a k-way merge of the per-shard indexes under the same
    /// (score desc, id asc) order, valid to `top_k_cap` entries because
    /// every globally-top entry is top-`cap` within its own shard (each
    /// shard's index holds ≥ `min(cap, |shard|)` entries). Ids and ranks
    /// concatenate in shard order; `ids` must be disjoint across shards
    /// (each vertex owned by exactly one shard).
    ///
    /// `published_at` carries the staleness anchor forward on
    /// topology-only republishes (`None` = a fresh recompute, anchored
    /// now).
    #[allow(clippy::too_many_arguments)]
    pub fn merged(
        version: u64,
        graph_version: u64,
        query_id: u64,
        action: Action,
        exec: ExecStats,
        shards: &[&RankSnapshot],
        top_k_cap: usize,
        engine_metrics: Json,
        published_at: Option<Instant>,
    ) -> Self {
        let n: usize = shards.iter().map(|s| s.ids.len()).sum();
        let mut ids = Vec::with_capacity(n);
        let mut ranks = Vec::with_capacity(n);
        let mut offsets = Vec::with_capacity(shards.len());
        let mut hot = Vec::new();
        for s in shards {
            offsets.push(ids.len() as u32);
            ids.extend_from_slice(&s.ids);
            ranks.extend_from_slice(&s.ranks);
            hot.extend_from_slice(&s.hot);
        }
        // K-way merge over per-shard cursors: at every step take the
        // (score desc, id asc)-smallest head — the shard indexes are
        // each already sorted under that order.
        let cap = top_k_cap.min(n);
        let mut cursors = vec![0usize; shards.len()];
        let mut top_index = Vec::with_capacity(cap);
        while top_index.len() < cap {
            let mut best: Option<(usize, f64, VertexId)> = None;
            for (si, s) in shards.iter().enumerate() {
                if let Some(&p) = s.top_index.get(cursors[si]) {
                    let (score, id) = (s.ranks[p as usize], s.ids[p as usize]);
                    let better = match best {
                        None => true,
                        Some((_, bs, bid)) => score > bs || (score == bs && id < bid),
                    };
                    if better {
                        best = Some((si, score, id));
                    }
                }
            }
            let Some((si, _, _)) = best else {
                break; // every shard index exhausted below the cap
            };
            top_index.push(offsets[si] + shards[si].top_index[cursors[si]]);
            cursors[si] += 1;
        }
        let mut by_id: Vec<u32> = (0..ids.len() as u32).collect();
        by_id.sort_unstable_by_key(|&i| ids[i as usize]);
        let mut snap = Self {
            version,
            graph_version,
            query_id,
            action,
            exec,
            ids,
            ranks,
            engine_metrics,
            published_at: published_at.unwrap_or_else(Instant::now),
            top_index,
            by_id,
            hot: Vec::new(),
        };
        snap.set_hot_set(hot);
        snap
    }

    /// Attach the hot-set membership the producing recompute used
    /// (called by the engine before publishing; sorted + deduped here so
    /// [`Self::is_hot`] can binary-search).
    pub fn set_hot_set(&mut self, mut hot: Vec<VertexId>) {
        hot.sort_unstable();
        hot.dedup();
        self.hot = hot;
    }

    /// The hot set |K| behind this snapshot, as sorted external ids.
    pub fn hot_set(&self) -> &[VertexId] {
        &self.hot
    }

    /// Whether `id` was in the hot set at this snapshot's recompute.
    pub fn is_hot(&self, id: VertexId) -> bool {
        self.hot.binary_search(&id).is_ok()
    }

    /// Number of ranked vertices.
    pub fn num_vertices(&self) -> usize {
        self.ids.len()
    }

    /// Wall seconds since this snapshot was produced — the snapshot-age
    /// (staleness) gauge.
    pub fn age_secs(&self) -> f64 {
        self.published_at.elapsed().as_secs_f64()
    }

    /// How many entries the precomputed top-K index holds.
    pub fn top_k_cap(&self) -> usize {
        self.top_index.len()
    }

    /// Top-k `(vertex, score)` pairs, descending (ties: ascending id).
    /// `k ≤ top_k_cap()` is an O(k) copy of the precomputed index; larger
    /// `k` re-selects in O(n + k log k) — identical ordering either way.
    pub fn top(&self, k: usize) -> Vec<(VertexId, f64)> {
        let k = k.min(self.ids.len());
        if k <= self.top_index.len() {
            self.top_index[..k]
                .iter()
                .map(|&i| (self.ids[i as usize], self.ranks[i as usize]))
                .collect()
        } else {
            top_k_indices(&self.ids, &self.ranks, k)
                .into_iter()
                .map(|i| (self.ids[i], self.ranks[i]))
                .collect()
        }
    }

    /// Top-k ids only (for RBO comparisons).
    pub fn top_ids(&self, k: usize) -> Vec<VertexId> {
        self.top(k).into_iter().map(|(v, _)| v).collect()
    }

    /// Rank of one vertex by external id — O(log n) binary search, no
    /// maps built per query.
    pub fn rank_of(&self, id: VertexId) -> Option<f64> {
        self.by_id
            .binary_search_by(|&i| self.ids[i as usize].cmp(&id))
            .ok()
            .map(|pos| self.ranks[self.by_id[pos] as usize])
    }
}

/// Cumulative read-path counters (shared by every reader handle).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReadStats {
    /// `top(k)` requests served off-snapshot.
    pub top: u64,
    /// `rank_of` requests served off-snapshot.
    pub rank: u64,
    /// `stats` requests served off-snapshot.
    pub stats: u64,
}

/// Which read-path request a counted snapshot fetch serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadKind {
    /// A top-k ranking request.
    Top,
    /// A single-vertex rank lookup.
    Rank,
    /// A serving-stats request.
    Stats,
}

/// Live write-path coalescing gauges, published by the engine thread as
/// it ingests and applies batches and read lock-free by every
/// [`SnapshotReader`]. Unlike the snapshot's frozen `engine_metrics`,
/// these stay current between publishes, so the wire `stats` op can show
/// queue pressure and coalescing effectiveness in real time.
#[derive(Debug, Default)]
pub struct IngestGauges {
    /// Raw ops drained from the update buffer so far (cumulative).
    pub coalesced_raw_ops: AtomicU64,
    /// Effective ops those drains collapsed to (cumulative).
    pub coalesced_effective_ops: AtomicU64,
    /// O(1) estimate of effective ops currently pending in the buffer.
    pub pending_effective_estimate: AtomicU64,
}

impl IngestGauges {
    /// Current values as a JSON object (the `stats` op's `ingest` section).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "coalesced_raw_ops",
                Json::Num(self.coalesced_raw_ops.load(Ordering::Relaxed) as f64),
            ),
            (
                "coalesced_effective_ops",
                Json::Num(self.coalesced_effective_ops.load(Ordering::Relaxed) as f64),
            ),
            (
                "pending_effective_estimate",
                Json::Num(self.pending_effective_estimate.load(Ordering::Relaxed) as f64),
            ),
        ])
    }
}

/// State shared between the one publisher and all readers.
struct Shared {
    latest: RwLock<Arc<RankSnapshot>>,
    reads_top: AtomicU64,
    reads_rank: AtomicU64,
    reads_stats: AtomicU64,
    ingest: IngestGauges,
    /// Standing queries (the push plane), evaluated on every publish.
    subs: SubscriptionRegistry,
}

/// Writer-side handle: owned by the engine, swaps the published snapshot
/// after each recompute.
pub struct SnapshotPublisher {
    shared: Arc<Shared>,
}

impl Default for SnapshotPublisher {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotPublisher {
    /// Start with the version-0 placeholder.
    pub fn new() -> Self {
        Self {
            shared: Arc::new(Shared {
                latest: RwLock::new(Arc::new(RankSnapshot::empty())),
                reads_top: AtomicU64::new(0),
                reads_rank: AtomicU64::new(0),
                reads_stats: AtomicU64::new(0),
                ingest: IngestGauges::default(),
                subs: SubscriptionRegistry::default(),
            }),
        }
    }

    /// The live write-path gauges; the engine updates these as it
    /// coalesces and applies batches.
    pub fn ingest_gauges(&self) -> &IngestGauges {
        &self.shared.ingest
    }

    /// Atomically replace the published snapshot (an `Arc` store; readers
    /// holding the previous snapshot keep it alive until they drop it),
    /// then evaluate every standing query against the transition. The
    /// diff runs *outside* the lock — readers are never blocked on
    /// notification fan-out — and costs one atomic load when nothing is
    /// subscribed.
    pub fn publish(&self, snapshot: Arc<RankSnapshot>) {
        let prev = {
            let mut slot = self.shared.latest.write().unwrap();
            std::mem::replace(&mut *slot, Arc::clone(&snapshot))
        };
        self.shared.subs.notify_publish(&prev, &snapshot);
    }

    /// The standing-query registry this publisher notifies.
    pub fn subscriptions(&self) -> &SubscriptionRegistry {
        &self.shared.subs
    }

    /// The latest published snapshot.
    pub fn latest(&self) -> Arc<RankSnapshot> {
        Arc::clone(&self.shared.latest.read().unwrap())
    }

    /// A read-only handle, cloneable across any number of reader threads.
    pub fn reader(&self) -> SnapshotReader {
        SnapshotReader { shared: Arc::clone(&self.shared) }
    }
}

/// Reader-side handle: answers `top` / `rank` / `stats` from the latest
/// published snapshot without touching the engine or its command queue.
#[derive(Clone)]
pub struct SnapshotReader {
    shared: Arc<Shared>,
}

impl SnapshotReader {
    /// The latest published snapshot.
    pub fn latest(&self) -> Arc<RankSnapshot> {
        Arc::clone(&self.shared.latest.read().unwrap())
    }

    /// The standing-query registry shared with the publish path. Wire
    /// connections register subscriptions here; the engine's publishes
    /// evaluate them.
    pub fn subscriptions(&self) -> &SubscriptionRegistry {
        &self.shared.subs
    }

    /// The latest published snapshot, counted as a served read of `kind`
    /// — front ends that need snapshot metadata alongside the ranking
    /// use this so one request is one snapshot load (internally
    /// consistent response) and one counter bump.
    pub fn latest_for(&self, kind: ReadKind) -> Arc<RankSnapshot> {
        let counter = match kind {
            ReadKind::Top => &self.shared.reads_top,
            ReadKind::Rank => &self.shared.reads_rank,
            ReadKind::Stats => &self.shared.reads_stats,
        };
        counter.fetch_add(1, Ordering::Relaxed);
        self.latest()
    }

    /// Version of the latest published snapshot.
    pub fn version(&self) -> u64 {
        self.latest().version
    }

    /// Top-k off the latest snapshot (counted).
    pub fn top(&self, k: usize) -> Vec<(VertexId, f64)> {
        self.latest_for(ReadKind::Top).top(k)
    }

    /// One vertex's rank off the latest snapshot (counted).
    pub fn rank(&self, id: VertexId) -> Option<f64> {
        self.latest_for(ReadKind::Rank).rank_of(id)
    }

    /// Read-path counters so far.
    pub fn read_stats(&self) -> ReadStats {
        ReadStats {
            top: self.shared.reads_top.load(Ordering::Relaxed),
            rank: self.shared.reads_rank.load(Ordering::Relaxed),
            stats: self.shared.reads_stats.load(Ordering::Relaxed),
        }
    }

    /// Off-queue `stats` payload: serving-layer state plus the engine
    /// metrics captured at the last publish (counted).
    pub fn stats_json(&self) -> Json {
        let s = self.latest_for(ReadKind::Stats);
        let r = self.read_stats();
        Json::obj(vec![
            (
                "serving",
                Json::obj(vec![
                    ("version", Json::Num(s.version as f64)),
                    ("graph_version", Json::Num(s.graph_version as f64)),
                    ("query_id", Json::Num(s.query_id as f64)),
                    ("action", Json::Str(s.action.to_string())),
                    ("vertices", Json::Num(s.num_vertices() as f64)),
                    ("published_top_k", Json::Num(s.top_k_cap() as f64)),
                    ("age_secs", Json::Num(s.age_secs())),
                    ("reads_top", Json::Num(r.top as f64)),
                    ("reads_rank", Json::Num(r.rank as f64)),
                    ("reads_stats", Json::Num(r.stats as f64)),
                ]),
            ),
            ("ingest", self.shared.ingest.to_json()),
            ("engine", s.engine_metrics.clone()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::ranking::top_k_ids;

    fn snap(version: u64, ids: Vec<VertexId>, ranks: Vec<f64>, cap: usize) -> RankSnapshot {
        RankSnapshot::new(
            version,
            version,
            version,
            Action::ComputeExact,
            ExecStats::default(),
            ids,
            ranks,
            cap,
            Json::Null,
        )
    }

    #[test]
    fn precomputed_top_matches_full_selection() {
        let ids: Vec<u64> = vec![30, 10, 20, 40, 50];
        let ranks = vec![0.5, 0.9, 0.9, 0.1, 0.7];
        let s = snap(1, ids.clone(), ranks.clone(), 3);
        assert_eq!(s.top_k_cap(), 3);
        for k in 0..=5 {
            assert_eq!(s.top_ids(k), top_k_ids(&ids, &ranks, k), "k={k}");
        }
        // pairs carry the matching scores
        assert_eq!(s.top(2), vec![(10, 0.9), (20, 0.9)]);
    }

    #[test]
    fn rank_of_finds_every_vertex_and_only_those() {
        let ids: Vec<u64> = vec![7, 3, 99, 12];
        let ranks = vec![1.0, 2.0, 3.0, 4.0];
        let s = snap(1, ids.clone(), ranks.clone(), 2);
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(s.rank_of(id), Some(ranks[i]));
        }
        assert_eq!(s.rank_of(5), None);
        assert_eq!(s.rank_of(1000), None);
    }

    #[test]
    fn merged_snapshot_equals_union_selection() {
        // Two disjoint shards; the k-way merged top index and rank
        // lookups must match a snapshot built directly on the union.
        let a = snap(3, vec![10, 30, 50], vec![0.9, 0.1, 0.5], 3);
        let b = snap(3, vec![20, 40], vec![0.9, 0.7], 3);
        let m = RankSnapshot::merged(
            3,
            0,
            7,
            Action::ComputeExact,
            ExecStats::default(),
            &[&a, &b],
            3,
            Json::Null,
            None,
        );
        let union = snap(3, vec![10, 30, 50, 20, 40], vec![0.9, 0.1, 0.5, 0.9, 0.7], 3);
        assert_eq!(m.top_k_cap(), 3);
        for k in 0..=5 {
            assert_eq!(m.top_ids(k), union.top_ids(k), "k={k}");
        }
        // Tie at 0.9 broken by ascending id: 10 before 20.
        assert_eq!(m.top_ids(2), vec![10, 20]);
        for id in [10u64, 20, 30, 40, 50] {
            assert_eq!(m.rank_of(id), union.rank_of(id));
        }
        assert_eq!(m.rank_of(11), None);
        assert_eq!(m.num_vertices(), 5);
    }

    #[test]
    fn publisher_swaps_and_readers_observe() {
        let p = SnapshotPublisher::new();
        let r = p.reader();
        assert_eq!(r.version(), 0);
        assert!(r.top(5).is_empty());
        assert_eq!(r.rank(0), None);
        p.publish(Arc::new(snap(1, vec![1, 2], vec![0.4, 0.6], 2)));
        assert_eq!(r.version(), 1);
        assert_eq!(r.top(1), vec![(2, 0.6)]);
        assert_eq!(r.rank(1), Some(0.4));
        let held = r.latest();
        p.publish(Arc::new(snap(2, vec![1, 2], vec![0.6, 0.4], 2)));
        // the old snapshot stays alive and unchanged for its holder
        assert_eq!(held.version, 1);
        assert_eq!(held.top(1), vec![(2, 0.6)]);
        assert_eq!(r.latest().version, 2);
    }

    #[test]
    fn read_counters_accumulate_across_clones() {
        let p = SnapshotPublisher::new();
        let r1 = p.reader();
        let r2 = r1.clone();
        let _ = r1.top(3);
        let _ = r2.top(3);
        let _ = r2.rank(0);
        let _ = r1.stats_json();
        let s = r2.read_stats();
        assert_eq!((s.top, s.rank, s.stats), (2, 1, 1));
    }

    #[test]
    fn stats_json_shape() {
        let p = SnapshotPublisher::new();
        p.publish(Arc::new(snap(3, vec![5], vec![1.0], 1)));
        let j = p.reader().stats_json();
        let serving = j.get("serving").unwrap();
        assert_eq!(serving.get("version").unwrap().as_u64(), Some(3));
        assert_eq!(serving.get("vertices").unwrap().as_u64(), Some(1));
        assert!(serving.get("age_secs").unwrap().as_f64().unwrap() >= 0.0);
        assert!(j.get("engine").is_some());
        let ingest = j.get("ingest").unwrap();
        assert_eq!(ingest.get("coalesced_raw_ops").unwrap().as_u64(), Some(0));
        assert_eq!(ingest.get("pending_effective_estimate").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn ingest_gauges_flow_from_publisher_to_readers() {
        let p = SnapshotPublisher::new();
        let r = p.reader();
        p.ingest_gauges().coalesced_raw_ops.store(40, Ordering::Relaxed);
        p.ingest_gauges().coalesced_effective_ops.store(12, Ordering::Relaxed);
        p.ingest_gauges().pending_effective_estimate.store(3, Ordering::Relaxed);
        let ingest = r.stats_json();
        let ingest = ingest.get("ingest").unwrap();
        assert_eq!(ingest.get("coalesced_raw_ops").unwrap().as_u64(), Some(40));
        assert_eq!(ingest.get("coalesced_effective_ops").unwrap().as_u64(), Some(12));
        assert_eq!(ingest.get("pending_effective_estimate").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn snapshot_age_grows_monotonically() {
        let s = snap(1, vec![1], vec![1.0], 1);
        let a = s.age_secs();
        assert!(a >= 0.0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let b = s.age_secs();
        assert!(b >= a, "age must not go backwards: {a} -> {b}");
        assert!(b >= 0.005, "5ms must register in the gauge");
    }
}
