//! Threaded query server: the “GraphBolt module” of Fig. 2.
//!
//! Producers (stream sources, clients) talk to a single engine thread
//! through a bounded command queue (backpressure per
//! [`crate::stream::backpressure`]); query responses come back over
//! per-request channels. A JSON line protocol over TCP is layered on top
//! for out-of-process clients (`veilgraph serve`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::engine::{Engine, QueryResult};
use crate::error::{Error, Result};
use crate::stream::backpressure::{BoundedQueue, OverflowPolicy};
use crate::stream::event::EdgeOp;
use crate::util::json::Json;

/// Commands accepted by the engine thread.
enum Command {
    Op(EdgeOp),
    Query(Sender<Result<QueryResult>>),
    Stats(Sender<Json>),
    Shutdown,
}

/// Handle to a running engine thread.
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Command>>,
    worker: Option<JoinHandle<()>>,
    running: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Spawn the engine thread with a command queue of `queue_capacity`.
    pub fn spawn(mut engine: Engine, queue_capacity: usize, policy: OverflowPolicy) -> Self {
        let queue = Arc::new(BoundedQueue::new(queue_capacity, policy));
        let running = Arc::new(AtomicBool::new(true));
        let q2 = Arc::clone(&queue);
        let r2 = Arc::clone(&running);
        let worker = std::thread::Builder::new()
            .name("veilgraph-engine".into())
            .spawn(move || {
                while let Some(cmd) = q2.pop() {
                    match cmd {
                        Command::Op(op) => engine.ingest(op),
                        Command::Query(reply) => {
                            let _ = reply.send(engine.query());
                        }
                        Command::Stats(reply) => {
                            let _ = reply.send(engine.metrics().to_json());
                        }
                        Command::Shutdown => break,
                    }
                }
                engine.stop();
                r2.store(false, Ordering::SeqCst);
            })
            .expect("spawn engine thread");
        Self { queue, worker: Some(worker), running }
    }

    /// Enqueue a graph operation (non-blocking result; backpressure policy
    /// applies).
    pub fn ingest(&self, op: EdgeOp) -> Result<()> {
        self.queue.push(Command::Op(op))
    }

    /// Serve a query synchronously.
    pub fn query(&self) -> Result<QueryResult> {
        let (tx, rx) = channel();
        self.queue.push(Command::Query(tx))?;
        rx.recv().map_err(|_| Error::Engine("engine thread gone".into()))?
    }

    /// Engine metrics snapshot.
    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.queue.push(Command::Stats(tx))?;
        rx.recv().map_err(|_| Error::Engine("engine thread gone".into()))
    }

    /// True while the engine thread is alive.
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Stop the engine and join the thread.
    pub fn shutdown(mut self) {
        let _ = self.queue.push(Command::Shutdown);
        self.queue.close();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.queue.push(Command::Shutdown);
        self.queue.close();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// JSON line protocol: one request object per line, one response per line.
///
/// Requests:
/// * `{"op":"add","src":1,"dst":2}`      → `{"ok":true}`
/// * `{"op":"remove","src":1,"dst":2}`   → `{"ok":true}`
/// * `{"op":"query","top":10}`           → `{"ok":true,"action":…,"top":[[id,score],…]}`
/// * `{"op":"stats"}`                    → `{"ok":true,"stats":{…}}`
/// * `{"op":"shutdown"}`                 → `{"ok":true}` and closes.
pub fn handle_request(handle: &ServerHandle, line: &str) -> (Json, bool) {
    let fail = |msg: String| {
        (Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg))]), false)
    };
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return fail(e.to_string()),
    };
    let op = req.get("op").and_then(Json::as_str).unwrap_or("");
    match op {
        "add" | "remove" => {
            let (src, dst) = match (
                req.get("src").and_then(Json::as_u64),
                req.get("dst").and_then(Json::as_u64),
            ) {
                (Some(s), Some(d)) => (s, d),
                _ => return fail("add/remove need numeric src and dst".into()),
            };
            let e = if op == "add" { EdgeOp::add(src, dst) } else { EdgeOp::remove(src, dst) };
            match handle.ingest(e) {
                Ok(()) => (Json::obj(vec![("ok", Json::Bool(true))]), false),
                Err(e) => fail(e.to_string()),
            }
        }
        "query" => {
            let top = req.get("top").and_then(Json::as_u64).unwrap_or(10) as usize;
            match handle.query() {
                Ok(res) => {
                    let pairs = res
                        .top(top)
                        .into_iter()
                        .map(|(id, score)| {
                            Json::Arr(vec![Json::Num(id as f64), Json::Num(score)])
                        })
                        .collect();
                    (
                        Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("query_id", Json::Num(res.query_id as f64)),
                            ("action", Json::Str(res.action.to_string())),
                            ("elapsed_secs", Json::Num(res.exec.elapsed_secs)),
                            ("summary_vertices", Json::Num(res.exec.summary_vertices as f64)),
                            ("top", Json::Arr(pairs)),
                        ]),
                        false,
                    )
                }
                Err(e) => fail(e.to_string()),
            }
        }
        "stats" => match handle.stats() {
            Ok(stats) => {
                (Json::obj(vec![("ok", Json::Bool(true)), ("stats", stats)]), false)
            }
            Err(e) => fail(e.to_string()),
        },
        "shutdown" => (Json::obj(vec![("ok", Json::Bool(true))]), true),
        other => fail(format!("unknown op {other:?}")),
    }
}

/// Serve the line protocol over TCP until a client sends `shutdown`.
/// Returns the bound address after start (useful with port 0 in tests).
pub fn serve_tcp(handle: ServerHandle, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::log_info!("listening on {}", listener.local_addr()?);
    let mut shutdown = false;
    while !shutdown {
        let (stream, peer) = listener.accept()?;
        crate::log_debug!("client {peer}");
        shutdown = serve_connection(&handle, stream)?;
    }
    handle.shutdown();
    Ok(())
}

fn serve_connection(handle: &ServerHandle, stream: TcpStream) -> Result<bool> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp, shutdown) = handle_request(handle, &line);
        writer.write_all(resp.to_string_compact().as_bytes())?;
        writer.write_all(b"\n")?;
        if shutdown {
            return Ok(true);
        }
    }
    Ok(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineBuilder;

    fn handle() -> ServerHandle {
        let edges: Vec<(u64, u64)> = (0..20).map(|i| (i, (i + 1) % 20)).collect();
        let engine = EngineBuilder::new().build_from_edges(edges).unwrap();
        ServerHandle::spawn(engine, 64, OverflowPolicy::Block)
    }

    #[test]
    fn ingest_then_query_roundtrip() {
        let h = handle();
        h.ingest(EdgeOp::add(0, 10)).unwrap();
        let r = h.query().unwrap();
        assert_eq!(r.query_id, 1);
        assert!(!r.ranks.is_empty());
        h.shutdown();
    }

    #[test]
    fn stats_reflect_served_queries() {
        let h = handle();
        let _ = h.query().unwrap();
        let _ = h.query().unwrap();
        let stats = h.stats().unwrap();
        assert_eq!(
            stats.get("counters").unwrap().get("queries").unwrap().as_u64(),
            Some(2)
        );
        h.shutdown();
    }

    #[test]
    fn concurrent_producers_are_serialized() {
        let h = std::sync::Arc::new(handle());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h2 = std::sync::Arc::clone(&h);
            joins.push(std::thread::spawn(move || {
                for i in 0..25u64 {
                    h2.ingest(EdgeOp::add(100 + t * 100 + i, i % 20)).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let r = h.query().unwrap();
        assert_eq!(r.ids.len(), 20 + 100, "20 ring + 100 new sources");
    }

    #[test]
    fn line_protocol_add_query_stats() {
        let h = handle();
        let (resp, stop) = handle_request(&h, r#"{"op":"add","src":3,"dst":9}"#);
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":3}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 3);
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        assert!(resp.get("stats").is_some());
        let (_, stop) = handle_request(&h, r#"{"op":"shutdown"}"#);
        assert!(stop);
        h.shutdown();
    }

    #[test]
    fn line_protocol_rejects_garbage() {
        let h = handle();
        let (resp, stop) = handle_request(&h, "not json");
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":1}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let (resp, _) = handle_request(&h, r#"{"op":"fly"}"#);
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("fly"));
        h.shutdown();
    }

    #[test]
    fn tcp_server_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        let h = handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            serve_connection(&h, stream).unwrap();
            h.shutdown();
        });
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .write_all(
                b"{\"op\":\"add\",\"src\":1,\"dst\":15}\n{\"op\":\"query\",\"top\":2}\n{\"op\":\"shutdown\"}\n",
            )
            .unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(3).map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 3);
        let q = Json::parse(&lines[1]).unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true));
        server.join().unwrap();
    }
}
