//! Async readiness-loop server: four planes behind a versioned wire
//! protocol.
//!
//! The server splits Fig. 2's "GraphBolt module" into four planes that
//! overlap freely:
//!
//! * **Ingest plane** — producers talk to a single engine thread through
//!   a bounded command queue ([`crate::stream::backpressure`]); mutations
//!   coalesce in the update buffer and apply in batches. The wire path
//!   uses `try_push` only: a full queue never stalls a poll worker, it
//!   surfaces as a structured `overload` error (or sheds under
//!   `DropOldest`).
//! * **Recompute plane** — the engine thread never runs PageRank. When
//!   the staleness policy escalates, [`Engine::query_async`] hands back a
//!   version-fenced [`RecomputeJob`]; a dedicated worker runs it and
//!   returns the result through the command queue, where
//!   [`Engine::finish_recompute`] installs (fence hit) or merges (fence
//!   miss) it and publishes. While a job runs, queries are still decided
//!   and answered (degraded); if the graph has moved past the in-flight
//!   job's fence, one *exact* successor may be scheduled to supersede it
//!   — the stale result is then discarded on arrival (counted as
//!   `recomputes_cancelled`) instead of fence-miss-merged under the
//!   fresher one. Decisions degrade down the accuracy ladder under queue
//!   pressure ([`StalenessPolicy::decide_under_pressure`]).
//! * **Read plane** — every [`ServerHandle`] carries a
//!   [`SnapshotReader`] onto the published
//!   [`RankSnapshot`](crate::coordinator::serving::RankSnapshot)s;
//!   `top`/`rank`/`stats` never enter the queue, so a recompute or batch
//!   apply in progress never blocks a read.
//! * **Push plane** — standing queries
//!   ([`crate::coordinator::subscription`]) registered over wire
//!   protocol v2 are diffed against every published snapshot; fired
//!   notifications land in per-connection mailboxes the readiness loop
//!   drains into the out-buffers as `{"v":2,"sub":N,"notify":{...}}`
//!   frames.
//!
//! The TCP front end ([`serve`]) is a nonblocking readiness loop: the
//! calling thread accepts, a small fixed set of poll workers each own a
//! slice of the connections and tick them through per-connection read/
//! write buffers. Thousands of mostly-idle clients cost no threads —
//! only a vector slot and two buffers each.
//!
//! Requests and responses speak the typed protocol of
//! [`crate::coordinator::protocol`]: v1 (`"v":1` or no `"v"`) keeps
//! strict in-order request/response semantics; v2 (`"v":2`) requests may
//! carry an `"id"` echoed on the response, and responses may arrive out
//! of order because the loop keeps reading while wire queries are in
//! flight. Errors are structured objects
//! `{"error":{"code":"...","msg":"..."}}` with stable codes
//! (`rate_limited`, `conn_cap`, `bad_op`, `overload`, `shutdown`).
//!
//! Two optional standing workloads ride the engine thread:
//! [`ServeOptions::window_secs`] bounds edge lifetime by generating
//! expiry `RemoveEdge` batches through the normal write pipeline
//! ([`crate::stream::window`]), and [`ServeOptions::communities`] keeps
//! streaming label propagation warm so `subscribe community` standing
//! queries can fire.
//!
//! [`ServerHandle::spawn_sharded`] runs the same loop over a
//! [`ShardedEngine`] (`serve --shards N`): writes partition-route to
//! owning shards inside the engine, `rank` reads route to the owning
//! shard's published snapshot, `top` serves the k-way merged combined
//! snapshot, and `stats` gains a per-shard section — the wire protocol
//! is otherwise unchanged. Durability and the community workload are
//! single-engine features and are disabled when sharded.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::community::streaming::StreamingCommunities;
use crate::coordinator::checkpoint::{CheckpointJob, CheckpointOutcome};
use crate::coordinator::engine::{
    AsyncQueryResult, Engine, QueryResult, RecomputeJob, RecomputeOutcome, RecomputeResult,
    ScheduleMode,
};
use crate::coordinator::policies::StalenessPolicy;
use crate::coordinator::protocol::{Envelope, Request, Response};
use crate::coordinator::serving::{ReadKind, SnapshotReader};
use crate::coordinator::sharded::{ShardedEngine, ShardedRecomputeJob, ShardedRecomputeResult};
use crate::coordinator::subscription::{Mailbox, SubscriptionRegistry};
use crate::coordinator::udf::Action;
use crate::coordinator::wal::DurabilityStats;
use crate::error::{Error, Result};
use crate::graph::partition::Partitioner;
use crate::graph::VertexId;
use crate::stream::backpressure::{BoundedQueue, OverflowPolicy};
use crate::stream::event::EdgeOp;
use crate::stream::window::{SlidingWindow, WindowState};
use crate::summary::params::SummaryParams;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

pub use crate::coordinator::protocol::{
    MAX_WIRE_BATCH_OPS, WIRE_PROTOCOL_V1, WIRE_PROTOCOL_VERSION,
};

/// Commands accepted by the engine thread (the ingest plane).
enum Command {
    Op(EdgeOp),
    /// A pre-validated batch: registered contiguously (one queue slot,
    /// one engine call), so it is all-or-nothing with respect to other
    /// producers.
    Batch(Vec<EdgeOp>),
    /// Legacy synchronous query: applies updates and recomputes inline on
    /// the engine thread. Library callers that want one authoritative
    /// answer ([`ServerHandle::query`]) still use it; the wire path does
    /// not.
    Query(Sender<Result<QueryResult>>),
    /// Wire query: answered immediately from the published snapshot, with
    /// any recompute handed to the off-thread worker.
    WireQuery(Sender<Result<AsyncQueryResult>>),
    /// A finished off-thread recompute coming home to be installed (or
    /// discarded, when a newer exact job superseded it while it ran).
    RecomputeDone { seq: u64, res: EngineJobResult },
    /// A finished off-thread checkpoint dump reporting back (clears the
    /// in-flight flag; on success the WAL prunes covered segments).
    CheckpointDone(CheckpointOutcome),
    Stats(Sender<Json>),
    /// A timer pulse from the window ticker: wakes the engine thread so
    /// sliding-window expiry runs even when no client traffic arrives.
    Tick,
    Shutdown,
}

/// Work shipped to the off-thread worker: version-fenced recomputes and
/// checkpoint dumps share one thread — both are periodic, bounded-rate
/// background work that must never block ingest or reads, and sharing
/// keeps at most one heavy background task on the machine at a time.
enum WorkerJob {
    /// A recompute tagged with its scheduling sequence number, so the
    /// engine loop can tell a superseded result from a current one.
    Recompute { seq: u64, job: EngineJob },
    Checkpoint(CheckpointJob),
}

/// The engine behind the command loop: one process-local [`Engine`] or a
/// [`ShardedEngine`] cluster behind one router. Both speak the same
/// command vocabulary; durability and the community workload are
/// single-engine features (the sharded arms are no-ops / `None`).
enum EngineCore {
    Single(Box<Engine>),
    Sharded(Box<ShardedEngine>),
}

impl EngineCore {
    fn ingest(&mut self, op: EdgeOp) {
        match self {
            EngineCore::Single(e) => e.ingest(op),
            EngineCore::Sharded(e) => e.ingest(op),
        }
    }

    fn ingest_batch(&mut self, ops: Vec<EdgeOp>) {
        match self {
            EngineCore::Single(e) => e.ingest_batch(ops),
            EngineCore::Sharded(e) => e.ingest_batch(ops),
        }
    }

    fn query(&mut self) -> Result<QueryResult> {
        match self {
            EngineCore::Single(e) => e.query(),
            EngineCore::Sharded(e) => e.query(),
        }
    }

    /// Apply pending coalesced updates now, so [`Self::version_token`]
    /// reflects everything the next scheduled job would fence.
    fn flush_pending(&mut self) {
        match self {
            EngineCore::Single(e) => e.flush_pending(),
            EngineCore::Sharded(e) => e.flush_pending(),
        }
    }

    fn query_async(
        &mut self,
        policy: &StalenessPolicy,
        pressure: f64,
        mode: ScheduleMode,
    ) -> Result<(AsyncQueryResult, Option<EngineJob>)> {
        match self {
            EngineCore::Single(e) => {
                let (aq, job) = e.query_async(policy, pressure, mode)?;
                Ok((aq, job.map(EngineJob::Single)))
            }
            EngineCore::Sharded(e) => {
                let (aq, job) = e.query_async(policy, pressure, mode)?;
                Ok((aq, job.map(EngineJob::Sharded)))
            }
        }
    }

    /// Install (or fence-miss-merge / reconcile) a finished recompute.
    /// A result from the other engine shape cannot arise (jobs are
    /// created by this same core); it is absorbed as a fence hit.
    fn finish_recompute(&mut self, res: EngineJobResult) -> RecomputeOutcome {
        match (self, res) {
            (EngineCore::Single(e), EngineJobResult::Single(r)) => e.finish_recompute(*r),
            (EngineCore::Sharded(e), EngineJobResult::Sharded(r)) => e.finish_recompute(*r),
            _ => RecomputeOutcome { fence_ok: true, reconciled: false },
        }
    }

    /// Whether fence-missed recomputes are reconciled instead of
    /// discarded (mirrors the engines' `set_reconcile`).
    fn set_reconcile(&mut self, on: bool) {
        match self {
            EngineCore::Single(e) => e.set_reconcile(on),
            EngineCore::Sharded(e) => e.set_reconcile(on),
        }
    }

    /// Cumulative shard-plan cache counters (reused, rebuilt); the single
    /// engine has no shard plan and reports zeros.
    fn plan_counters(&self) -> (u64, u64) {
        match self {
            EngineCore::Single(_) => (0, 0),
            EngineCore::Sharded(e) => e.plan_counters(),
        }
    }

    /// A cheap monotone token over the served topology: the single
    /// engine's graph version, or the sum of shard graph versions. The
    /// supersession policy compares the token an in-flight job fenced
    /// against the current one.
    fn version_token(&self) -> u64 {
        match self {
            EngineCore::Single(e) => e.graph().version(),
            EngineCore::Sharded(e) => e.version_token(),
        }
    }

    fn metrics_json(&self) -> Json {
        match self {
            EngineCore::Single(e) => e.metrics().to_json(),
            EngineCore::Sharded(e) => e.metrics().to_json(),
        }
    }

    fn reader(&self) -> SnapshotReader {
        match self {
            EngineCore::Single(e) => e.reader(),
            EngineCore::Sharded(e) => e.reader(),
        }
    }

    fn durability_stats(&self) -> Arc<DurabilityStats> {
        match self {
            EngineCore::Single(e) => e.durability_stats(),
            // Sharded serving is memory-only: a default (disabled) gauge
            // set keeps the wire `stats.durability` section well-formed.
            EngineCore::Sharded(_) => Arc::new(DurabilityStats::default()),
        }
    }

    fn take_recovered_window(&mut self) -> Option<WindowState> {
        match self {
            EngineCore::Single(e) => e.take_recovered_window(),
            EngineCore::Sharded(_) => None,
        }
    }

    fn checkpoint_due(&self) -> bool {
        match self {
            EngineCore::Single(e) => e.checkpoint_due(),
            EngineCore::Sharded(_) => false,
        }
    }

    fn begin_checkpoint(&mut self, window: Option<WindowState>) -> Option<CheckpointJob> {
        match self {
            EngineCore::Single(e) => e.begin_checkpoint(window),
            EngineCore::Sharded(_) => None,
        }
    }

    fn finish_checkpoint(&mut self, outcome: CheckpointOutcome) {
        if let EngineCore::Single(e) = self {
            e.finish_checkpoint(outcome);
        }
    }

    fn shutdown_durable(&mut self, window: Option<WindowState>) {
        match self {
            EngineCore::Single(e) => e.shutdown_durable(window),
            EngineCore::Sharded(e) => e.stop(),
        }
    }

    fn stop(&mut self) {
        match self {
            EngineCore::Single(e) => e.stop(),
            EngineCore::Sharded(e) => e.stop(),
        }
    }

    /// Edge list + summary params seeding the streaming-communities
    /// workload; `None` when the engine shape does not support it (the
    /// sharded cluster has no single co-resident edge list).
    fn community_seed(&self) -> Option<(Vec<(VertexId, VertexId)>, SummaryParams)> {
        match self {
            EngineCore::Single(e) => {
                let g = e.graph();
                let edges = g.edges().map(|(s, d)| (g.id(s), g.id(d))).collect();
                Some((edges, e.params()))
            }
            EngineCore::Sharded(_) => None,
        }
    }
}

/// A version-fenced recompute from either engine shape, run on the
/// shared worker thread.
enum EngineJob {
    Single(RecomputeJob),
    Sharded(ShardedRecomputeJob),
}

impl EngineJob {
    /// Run on the recompute worker, optionally on its dedicated pool
    /// (`ServeOptions::recompute_workers`); `None` runs single-threaded.
    fn run_with(self, pool: Option<&ThreadPool>) -> EngineJobResult {
        match self {
            EngineJob::Single(j) => EngineJobResult::Single(Box::new(j.run_with(pool))),
            EngineJob::Sharded(j) => EngineJobResult::Sharded(Box::new(j.run_with(pool))),
        }
    }
}

enum EngineJobResult {
    Single(Box<RecomputeResult>),
    Sharded(Box<ShardedRecomputeResult>),
}

impl EngineJobResult {
    /// Whether the job refreshed every rank (an installable result, as
    /// opposed to a repeat-last no-op).
    fn refreshed(&self) -> bool {
        match self {
            EngineJobResult::Single(r) => r.refreshed(),
            EngineJobResult::Sharded(r) => r.refreshed(),
        }
    }
}

/// Live counters for the wire front end, shared between the acceptor,
/// the poll workers and the `stats` op.
#[derive(Default)]
pub struct WireStats {
    /// Currently-open client connections.
    pub connections: AtomicUsize,
    /// Poll workers serving them (0 until [`serve`] starts).
    pub workers: AtomicUsize,
    /// Requests answered with the `overload` code.
    pub overloads: AtomicU64,
    /// Whether a recompute job is currently running off-thread.
    pub recompute_in_flight: AtomicBool,
    /// Off-thread recomputes whose version fence missed (the graph moved
    /// while the job ran; the result was merged by id, not installed).
    pub recompute_fence_misses: AtomicU64,
    /// Off-thread recomputes whose result was discarded because a newer
    /// exact job superseded them while they ran.
    pub recomputes_cancelled: AtomicU64,
    /// Fence-missed recomputes salvaged by replaying the post-fence ops
    /// onto the fenced ranks before publishing (reconciliation).
    pub recomputes_reconciled: AtomicU64,
    /// Workers in the recompute worker's dedicated pool (0 = the job
    /// runs single-threaded on the worker itself).
    pub recompute_pool_size: AtomicUsize,
    /// Sharded recomputes that reused the cached shard plan unchanged.
    pub plan_reused: AtomicU64,
    /// Sharded recomputes that (re)built at least one shard's plan.
    pub plan_rebuilt: AtomicU64,
    /// Edges expired out of the sliding window so far.
    pub window_expired: AtomicU64,
    /// Unexpired admits currently tracked by the sliding window.
    pub window_tracked: AtomicU64,
    /// Last staleness decision taken by a wire query
    /// (0 = none yet, 1 = repeat-last, 2 = approximate, 3 = exact).
    last_decision: AtomicU8,
}

impl WireStats {
    fn set_last_decision(&self, a: Action) {
        let code = match a {
            Action::RepeatLast => 1,
            Action::ComputeApproximate => 2,
            Action::ComputeExact => 3,
        };
        self.last_decision.store(code, Ordering::Relaxed);
    }

    /// The most recent wire-query staleness decision, if any query ran.
    pub fn last_decision(&self) -> Option<Action> {
        match self.last_decision.load(Ordering::Relaxed) {
            1 => Some(Action::RepeatLast),
            2 => Some(Action::ComputeApproximate),
            3 => Some(Action::ComputeExact),
            _ => None,
        }
    }
}

/// Test hook: a gate the recompute worker passes through *before* running
/// each job. [`ServerHandle::hold_recompute`] parks the worker so tests
/// can prove readers and writers stay live while a recompute is pinned
/// mid-flight; [`ServerHandle::release_recompute`] lets it continue.
struct RecomputeGate {
    held: Mutex<bool>,
    cv: Condvar,
}

impl RecomputeGate {
    fn new() -> Self {
        Self { held: Mutex::new(false), cv: Condvar::new() }
    }

    fn hold(&self) {
        *self.held.lock().unwrap() = true;
    }

    fn release(&self) {
        *self.held.lock().unwrap() = false;
        self.cv.notify_all();
    }

    /// Wait until released; false means the server shut down while held.
    fn wait_released(&self, queue: &BoundedQueue<Command>) -> bool {
        let mut held = self.held.lock().unwrap();
        while *held {
            if queue.is_closed() {
                return false;
            }
            let (g, _) = self.cv.wait_timeout(held, Duration::from_millis(20)).unwrap();
            held = g;
        }
        true
    }
}

/// The read plane's routing table for a sharded server: the partitioner
/// plus one [`SnapshotReader`] per shard (owned-only snapshots), so
/// `rank` lookups go straight to the owning shard without touching the
/// combined merge.
struct ShardSet {
    parts: Partitioner,
    readers: Vec<SnapshotReader>,
}

impl ShardSet {
    /// The `shards` section of the wire `stats` op: per-shard snapshot
    /// gauges in shard order.
    fn stats_json(&self) -> Json {
        Json::Arr(
            self.readers
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    let s = r.latest();
                    Json::obj(vec![
                        ("shard", Json::Num(i as f64)),
                        ("vertices", Json::Num(s.num_vertices() as f64)),
                        ("version", Json::Num(s.version as f64)),
                        ("graph_version", Json::Num(s.graph_version as f64)),
                        ("age_secs", Json::Num(s.age_secs())),
                    ])
                })
                .collect(),
        )
    }
}

/// Handle to a running engine thread + recompute worker, plus the
/// lock-free read plane.
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Command>>,
    worker: Option<JoinHandle<()>>,
    recompute: Option<JoinHandle<()>>,
    /// The window ticker (only when `window_secs > 0`): pulses
    /// [`Command::Tick`] so expiry runs on an idle server.
    ticker: Option<JoinHandle<()>>,
    running: Arc<AtomicBool>,
    reader: SnapshotReader,
    policy: StalenessPolicy,
    wire: Arc<WireStats>,
    gate: Arc<RecomputeGate>,
    /// Durability gauges shared with the engine (the wire
    /// `stats.durability` section; reports `enabled: false` when the
    /// server runs without a data dir).
    durability: Arc<DurabilityStats>,
    /// Present on sharded servers: the partition routing table the read
    /// plane uses for `rank` and the per-shard `stats` section.
    shards: Option<Arc<ShardSet>>,
}

impl ServerHandle {
    /// Spawn the engine thread and the recompute worker with the queue,
    /// overflow and staleness knobs from `opts`.
    pub fn spawn_with(engine: Engine, opts: &ServeOptions) -> Self {
        Self::spawn_core(EngineCore::Single(Box::new(engine)), None, opts)
    }

    /// Spawn the command loop over a sharded cluster
    /// ([`crate::coordinator::sharded::ShardedEngine`], `serve --shards
    /// N`): same queue, same wire protocol, with `rank` reads
    /// partition-routed to the owning shard's snapshot and a per-shard
    /// `stats` section. Durability and the community workload are
    /// single-engine features and are unavailable in this mode.
    pub fn spawn_sharded(engine: ShardedEngine, opts: &ServeOptions) -> Self {
        let shards =
            Arc::new(ShardSet { parts: engine.partitioner(), readers: engine.shard_readers() });
        Self::spawn_core(EngineCore::Sharded(Box::new(engine)), Some(shards), opts)
    }

    fn spawn_core(
        mut engine: EngineCore,
        shards: Option<Arc<ShardSet>>,
        opts: &ServeOptions,
    ) -> Self {
        let reader = engine.reader();
        let durability = engine.durability_stats();
        let queue = Arc::new(BoundedQueue::new(opts.queue_capacity, opts.overflow));
        let running = Arc::new(AtomicBool::new(true));
        let wire = Arc::new(WireStats::default());
        let gate = Arc::new(RecomputeGate::new());
        let policy = opts.policy;
        engine.set_reconcile(opts.reconcile);
        let reconcile = opts.reconcile;
        // The recompute worker's own pool: a pool of < 2 workers would
        // only add scheduling overhead, so the job runs inline instead.
        let pool_size = if opts.recompute_workers >= 2 { opts.recompute_workers } else { 0 };
        wire.recompute_pool_size.store(pool_size, Ordering::SeqCst);

        let (job_tx, job_rx) = channel::<WorkerJob>();
        let q_jobs = Arc::clone(&queue);
        let gate2 = Arc::clone(&gate);
        let recompute = std::thread::Builder::new()
            .name("veilgraph-recompute".into())
            .spawn(move || {
                let pool = (pool_size > 0).then(|| ThreadPool::new(pool_size));
                while let Ok(job) = job_rx.recv() {
                    // Results ride the command queue ahead of capacity
                    // (control plane, at most one outstanding per kind):
                    // a full queue must not be able to strand a finished
                    // job.
                    match job {
                        WorkerJob::Recompute { seq, job } => {
                            if !gate2.wait_released(&q_jobs) {
                                break;
                            }
                            let res = job.run_with(pool.as_ref());
                            if q_jobs.force_push(Command::RecomputeDone { seq, res }).is_err() {
                                break;
                            }
                        }
                        // Checkpoint dumps skip the test gate: holding a
                        // recompute must not wedge durability.
                        WorkerJob::Checkpoint(job) => {
                            let out = job.run();
                            if q_jobs.force_push(Command::CheckpointDone(out)).is_err() {
                                break;
                            }
                        }
                    }
                }
            })
            .expect("spawn recompute thread");

        let window_nanos = (opts.window_secs.max(0.0) * 1e9) as u64;
        let communities_on = opts.communities;
        let q2 = Arc::clone(&queue);
        let r2 = Arc::clone(&running);
        let w2 = Arc::clone(&wire);
        let reader2 = reader.clone();
        let worker = std::thread::Builder::new()
            .name("veilgraph-engine".into())
            .spawn(move || {
                let cap = q2.capacity().max(1);
                // Outstanding recompute jobs as (seq, fenced version
                // token) in scheduling order. At most two exist: one
                // running plus, when the graph moved past its fence, one
                // exact successor that supersedes it — the superseded
                // result is discarded when it comes home.
                let mut outstanding: VecDeque<(u64, u64)> = VecDeque::new();
                let mut next_seq: u64 = 0;
                // The window's logical clock: wall nanoseconds since the
                // engine thread started.
                let epoch = Instant::now();
                // A recovered admission state restores under the fresh
                // epoch (remaining lifetimes preserved); otherwise the
                // window starts empty.
                let mut window = if window_nanos > 0 {
                    Some(match engine.take_recovered_window() {
                        Some(ws) => SlidingWindow::restore(&ws, 0),
                        None => SlidingWindow::new(window_nanos),
                    })
                } else {
                    None
                };
                // The second standing-analytics workload: streaming label
                // propagation, seeded from the engine's graph and kept in
                // step with every mutation (including window expiries).
                let mut communities = if communities_on {
                    match engine.community_seed() {
                        Some((edges, params)) => {
                            match StreamingCommunities::new(edges, params, 30) {
                                Ok(c) => Some(c),
                                Err(e) => {
                                    crate::log_warn!("community workload disabled: {e}");
                                    None
                                }
                            }
                        }
                        None => {
                            crate::log_warn!(
                                "community workload disabled: unsupported on a sharded engine"
                            );
                            None
                        }
                    }
                } else {
                    None
                };
                let mut community_prev: HashMap<VertexId, u32> = match &communities {
                    Some(c) => {
                        c.graph().ids().iter().copied().zip(c.labels().iter().copied()).collect()
                    }
                    None => HashMap::new(),
                };
                let mut community_dirty = false;
                while let Some(cmd) = q2.pop() {
                    // Publish points: commands after which a fresh
                    // snapshot may have appeared, so the community
                    // workload refreshes its labels for standing queries.
                    let mut publish_point = false;
                    match cmd {
                        Command::Op(op) => {
                            if let Some(w) = window.as_mut() {
                                w.admit(&op, epoch.elapsed().as_nanos() as u64);
                            }
                            if let Some(c) = communities.as_mut() {
                                c.ingest(op);
                                community_dirty = true;
                            }
                            engine.ingest(op);
                        }
                        Command::Batch(ops) => {
                            if window.is_some() || communities.is_some() {
                                let now = epoch.elapsed().as_nanos() as u64;
                                for op in &ops {
                                    if let Some(w) = window.as_mut() {
                                        w.admit(op, now);
                                    }
                                    if let Some(c) = communities.as_mut() {
                                        c.ingest(*op);
                                        community_dirty = true;
                                    }
                                }
                            }
                            engine.ingest_batch(ops);
                        }
                        Command::Query(reply) => {
                            let _ = reply.send(engine.query());
                            publish_point = true;
                        }
                        Command::WireQuery(reply) => {
                            let pressure = q2.len() as f64 / cap as f64;
                            // Flush first so the token comparison sees
                            // buffered-but-unapplied writes too (the
                            // query would apply them anyway).
                            engine.flush_pending();
                            // Supersession policy: nothing in flight →
                            // schedule whenever the policy escalates; one
                            // job fenced behind the current topology →
                            // only an exact job may supersede it; two
                            // outstanding (or one still current) → never
                            // stack more. With reconciliation on, a job
                            // behind the fence is still salvageable (the
                            // post-fence ops replay onto its result), so
                            // nothing supersedes it.
                            let mode = if outstanding.is_empty() {
                                ScheduleMode::WhenDue
                            } else if !reconcile
                                && outstanding.len() == 1
                                && outstanding[0].1 != engine.version_token()
                            {
                                ScheduleMode::ExactOnly
                            } else {
                                ScheduleMode::Never
                            };
                            match engine.query_async(&policy, pressure, mode) {
                                Ok((mut aq, job)) => {
                                    if let Some(job) = job {
                                        let seq = next_seq;
                                        next_seq += 1;
                                        if job_tx.send(WorkerJob::Recompute { seq, job }).is_ok() {
                                            // Token read *after*
                                            // query_async: pending
                                            // updates were applied, so
                                            // this is what the job fenced.
                                            outstanding.push_back((seq, engine.version_token()));
                                            w2.recompute_in_flight.store(true, Ordering::SeqCst);
                                        } else {
                                            aq.scheduled = false;
                                        }
                                    }
                                    w2.set_last_decision(aq.decision);
                                    let _ = reply.send(Ok(aq));
                                }
                                Err(e) => {
                                    let _ = reply.send(Err(e));
                                }
                            }
                            publish_point = true;
                        }
                        Command::RecomputeDone { seq, res } => {
                            // Superseded: a newer exact job is already in
                            // flight and covers strictly more of the
                            // graph's history — discard this result
                            // rather than fence-miss-merging stale ranks.
                            let superseded = outstanding.front().map(|&(s, _)| s) == Some(seq)
                                && outstanding.len() > 1;
                            outstanding.retain(|&(s, _)| s != seq);
                            w2.recompute_in_flight.store(!outstanding.is_empty(), Ordering::SeqCst);
                            if superseded {
                                w2.recomputes_cancelled.fetch_add(1, Ordering::SeqCst);
                            } else {
                                let refreshed = res.refreshed();
                                let out = engine.finish_recompute(res);
                                if !out.fence_ok && refreshed {
                                    if out.reconciled {
                                        w2.recomputes_reconciled
                                            .fetch_add(1, Ordering::SeqCst);
                                    } else {
                                        w2.recompute_fence_misses
                                            .fetch_add(1, Ordering::SeqCst);
                                    }
                                }
                                let (reused, rebuilt) = engine.plan_counters();
                                w2.plan_reused.store(reused, Ordering::Relaxed);
                                w2.plan_rebuilt.store(rebuilt, Ordering::Relaxed);
                                publish_point = true;
                            }
                        }
                        Command::CheckpointDone(out) => {
                            engine.finish_checkpoint(out);
                        }
                        Command::Stats(reply) => {
                            let _ = reply.send(engine.metrics_json());
                        }
                        Command::Tick => {}
                        Command::Shutdown => {
                            // Graceful shutdown: drain in-flight batches
                            // through the WAL, fsync, and cut a final
                            // checkpoint marked clean — restart after
                            // this replays nothing.
                            let ws = window
                                .as_ref()
                                .map(|w| w.export_state(epoch.elapsed().as_nanos() as u64));
                            engine.shutdown_durable(ws);
                            break;
                        }
                    }
                    // Sliding-window expiry runs after every command
                    // (ticks included): expired edges leave as one
                    // ordinary RemoveEdge batch through the coalescer.
                    if let Some(w) = window.as_mut() {
                        let expired = w.expire_due(epoch.elapsed().as_nanos() as u64);
                        if !expired.is_empty() {
                            w2.window_expired.fetch_add(expired.len() as u64, Ordering::SeqCst);
                            if let Some(c) = communities.as_mut() {
                                for op in &expired {
                                    c.ingest(*op);
                                }
                                community_dirty = true;
                            }
                            engine.ingest_batch(expired);
                        }
                        w2.window_tracked.store(w.tracked() as u64, Ordering::SeqCst);
                    }
                    // Community standing queries: refresh labels at
                    // publish points, but only when someone is listening
                    // and the graph moved since the last refresh.
                    if publish_point
                        && community_dirty
                        && reader2.subscriptions().has_community_subs()
                    {
                        if let Some(c) = communities.as_mut() {
                            match c.query(Action::ComputeApproximate) {
                                Ok(res) => {
                                    let g = c.graph();
                                    reader2.subscriptions().notify_community(res.query_id, |id| {
                                        let now =
                                            g.index(id).map(|i| res.labels[i as usize]);
                                        (community_prev.get(&id).copied(), now)
                                    });
                                    community_prev = g
                                        .ids()
                                        .iter()
                                        .copied()
                                        .zip(res.labels.iter().copied())
                                        .collect();
                                }
                                Err(e) => crate::log_warn!("community refresh failed: {e}"),
                            }
                            community_dirty = false;
                        }
                    }
                    // Durability: cut a checkpoint every N applied
                    // batches. The engine freezes a clone (cheap, on
                    // this thread); the dump runs on the worker so a
                    // large graph never blocks ingest.
                    if engine.checkpoint_due() {
                        let ws = window
                            .as_ref()
                            .map(|w| w.export_state(epoch.elapsed().as_nanos() as u64));
                        if let Some(job) = engine.begin_checkpoint(ws) {
                            let _ = job_tx.send(WorkerJob::Checkpoint(job));
                        }
                    }
                }
                // Dropping the job sender unblocks the recompute worker's
                // recv so it can exit.
                drop(job_tx);
                engine.stop();
                r2.store(false, Ordering::SeqCst);
            })
            .expect("spawn engine thread");

        // The ticker keeps expiry moving on an idle server; force_push
        // fails once the queue closes, which is its exit signal.
        let ticker = if window_nanos > 0 {
            let q3 = Arc::clone(&queue);
            let interval =
                Duration::from_nanos((window_nanos / 4).clamp(10_000_000, 250_000_000));
            Some(
                std::thread::Builder::new()
                    .name("veilgraph-window".into())
                    .spawn(move || loop {
                        std::thread::sleep(interval);
                        if q3.force_push(Command::Tick).is_err() {
                            break;
                        }
                    })
                    .expect("spawn window ticker"),
            )
        } else {
            None
        };

        Self {
            queue,
            worker: Some(worker),
            recompute: Some(recompute),
            ticker,
            running,
            reader,
            policy,
            wire,
            gate,
            durability,
            shards,
        }
    }

    /// Spawn with a command queue of `queue_capacity` and default
    /// staleness policy (compatibility wrapper over [`Self::spawn_with`]).
    pub fn spawn(engine: Engine, queue_capacity: usize, policy: OverflowPolicy) -> Self {
        Self::spawn_with(
            engine,
            &ServeOptions::new().queue_capacity(queue_capacity).overflow(policy),
        )
    }

    /// Enqueue a graph operation (blocking backpressure per the overflow
    /// policy — library producers that *want* to wait).
    pub fn ingest(&self, op: EdgeOp) -> Result<()> {
        self.queue.push(Command::Op(op))
    }

    /// Enqueue a whole batch atomically: one queue slot, registered in
    /// one engine call — concurrent producers can never interleave into
    /// the middle of it, and a full queue rejects it as a unit.
    pub fn ingest_batch(&self, ops: Vec<EdgeOp>) -> Result<()> {
        self.queue.push(Command::Batch(ops))
    }

    /// Non-blocking ingest for the wire path: a full queue surfaces as
    /// [`Error::Backpressure`] (the `overload` wire code) instead of
    /// stalling the poll worker.
    pub fn try_ingest(&self, op: EdgeOp) -> Result<()> {
        self.queue.try_push(Command::Op(op))
    }

    /// Non-blocking batch ingest (see [`Self::try_ingest`]).
    pub fn try_ingest_batch(&self, ops: Vec<EdgeOp>) -> Result<()> {
        self.queue.try_push(Command::Batch(ops))
    }

    /// Serve a query synchronously (applies pending updates and may
    /// recompute inline on the engine thread).
    pub fn query(&self) -> Result<QueryResult> {
        let (tx, rx) = channel();
        self.queue.push(Command::Query(tx))?;
        rx.recv().map_err(|_| Error::Engine("engine thread gone".into()))?
    }

    /// Enqueue a wire query without blocking: the engine answers from the
    /// published snapshot and schedules any recompute off-thread. Returns
    /// the receiver the response will arrive on; a full queue surfaces as
    /// [`Error::Backpressure`] so the caller can degrade.
    pub fn query_wire(&self) -> Result<Receiver<Result<AsyncQueryResult>>> {
        let (tx, rx) = channel();
        self.queue.try_push(Command::WireQuery(tx))?;
        Ok(rx)
    }

    /// Live engine metrics snapshot (round-trips through the command
    /// queue; see [`Self::reader`] for the off-queue variant).
    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.queue.push(Command::Stats(tx))?;
        rx.recv().map_err(|_| Error::Engine("engine thread gone".into()))
    }

    /// The read plane: a cloneable handle answering `top`/`rank`/`stats`
    /// from the latest published snapshot without entering the queue.
    pub fn reader(&self) -> SnapshotReader {
        self.reader.clone()
    }

    /// The staleness policy wire queries are decided under.
    pub fn policy(&self) -> &StalenessPolicy {
        &self.policy
    }

    /// Live wire front-end counters.
    pub fn wire_stats(&self) -> &WireStats {
        &self.wire
    }

    /// Live durability gauges (WAL + checkpoint state; `enabled: false`
    /// when the server runs without a data dir).
    pub fn durability_stats(&self) -> &DurabilityStats {
        &self.durability
    }

    /// The standing-query registry: register, drop and inspect
    /// subscriptions evaluated at every snapshot publish.
    pub fn subscriptions(&self) -> &SubscriptionRegistry {
        self.reader.subscriptions()
    }

    /// Test hook: park the recompute worker before its next job (readers
    /// and writers must stay live while a recompute is pinned).
    pub fn hold_recompute(&self) {
        self.gate.hold();
    }

    /// Release a held recompute worker.
    pub fn release_recompute(&self) {
        self.gate.release();
    }

    /// The `server` section of the wire `stats` op: front-end gauges,
    /// queue occupancy/shedding, and the active staleness policy with the
    /// last escalation decision.
    pub fn server_stats_json(&self) -> Json {
        let qs = self.queue.stats();
        let subs = self.reader.subscriptions();
        let last = match self.wire.last_decision() {
            Some(a) => Json::Str(a.to_string()),
            None => Json::Null,
        };
        Json::obj(vec![
            ("protocol_version", Json::Num(WIRE_PROTOCOL_VERSION as f64)),
            ("connections", Json::Num(self.wire.connections.load(Ordering::SeqCst) as f64)),
            ("workers", Json::Num(self.wire.workers.load(Ordering::SeqCst) as f64)),
            ("queue_len", Json::Num(self.queue.len() as f64)),
            ("queue_capacity", Json::Num(self.queue.capacity() as f64)),
            ("queue_pushed", Json::Num(qs.pushed as f64)),
            ("queue_popped", Json::Num(qs.popped as f64)),
            ("queue_dropped", Json::Num(qs.dropped as f64)),
            ("queue_rejected", Json::Num(qs.rejected as f64)),
            ("overloads", Json::Num(self.wire.overloads.load(Ordering::SeqCst) as f64)),
            (
                "recompute_in_flight",
                Json::Bool(self.wire.recompute_in_flight.load(Ordering::SeqCst)),
            ),
            (
                "recompute_fence_misses",
                Json::Num(self.wire.recompute_fence_misses.load(Ordering::SeqCst) as f64),
            ),
            (
                "recomputes_cancelled",
                Json::Num(self.wire.recomputes_cancelled.load(Ordering::SeqCst) as f64),
            ),
            (
                "recomputes_reconciled",
                Json::Num(self.wire.recomputes_reconciled.load(Ordering::SeqCst) as f64),
            ),
            (
                "recompute_pool_size",
                Json::Num(self.wire.recompute_pool_size.load(Ordering::SeqCst) as f64),
            ),
            ("plan_reused", Json::Num(self.wire.plan_reused.load(Ordering::Relaxed) as f64)),
            ("plan_rebuilt", Json::Num(self.wire.plan_rebuilt.load(Ordering::Relaxed) as f64)),
            (
                "window_expired",
                Json::Num(self.wire.window_expired.load(Ordering::SeqCst) as f64),
            ),
            (
                "window_tracked",
                Json::Num(self.wire.window_tracked.load(Ordering::SeqCst) as f64),
            ),
            ("subscriptions", Json::Num(subs.len() as f64)),
            ("durable_subscriptions", Json::Num(subs.durable_len() as f64)),
            ("notifications_sent", Json::Num(subs.notifications_sent() as f64)),
            ("notifications_dropped", Json::Num(subs.notifications_dropped() as f64)),
            ("notifications_merged", Json::Num(subs.notifications_merged() as f64)),
            ("sub_delivery", subs.delivery_counters_json()),
            ("policy", self.policy.to_json()),
            ("last_decision", last),
        ])
    }

    /// True while the engine thread is alive.
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Ask the engine thread to stop without joining it (used by the TCP
    /// front end, which holds the handle in an `Arc`; the final drop
    /// joins).
    pub fn request_shutdown(&self) {
        let _ = self.queue.force_push(Command::Shutdown);
        self.queue.close();
        self.gate.release();
    }

    /// Stop the engine and join both threads.
    pub fn shutdown(mut self) {
        self.request_shutdown();
        self.join();
    }

    fn join(&mut self) {
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        if let Some(h) = self.recompute.take() {
            let _ = h.join();
        }
        if let Some(h) = self.ticker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.request_shutdown();
        self.join();
    }
}

/// Upper bound on one request line's bytes, enforced WHILE buffering, so
/// an oversized line is rejected after accumulating at most this much —
/// not parsed, not fully read. Without it the batch-op cap is hollow: a
/// multi-gigabyte `batch` line would be buffered and JSON-parsed before
/// the op-count check ran. Sized so a full `MAX_WIRE_BATCH_OPS` batch of
/// maximal ops fits comfortably.
pub const MAX_WIRE_LINE_BYTES: usize = 1 << 20;

/// Per-connection token-bucket limiter over the read-path ops
/// (`top`/`rank`/`stats` — the requests that bypass the engine queue and
/// therefore see no backpressure). `rate` is ops/sec with a one-second
/// burst allowance; `rate <= 0` disables limiting.
pub struct RateLimiter {
    rate: f64,
    tokens: f64,
    last: Instant,
}

impl RateLimiter {
    /// A limiter admitting `rate` reads/sec (0 = unlimited).
    pub fn new(rate: f64) -> Self {
        Self { rate, tokens: rate.max(1.0), last: Instant::now() }
    }

    /// Take one token; false means the caller should reject the request.
    pub fn admit(&mut self) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let now = Instant::now();
        let refill = now.duration_since(self.last).as_secs_f64() * self.rate;
        self.tokens = (self.tokens + refill).min(self.rate.max(1.0));
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

// ---------------------------------------------------------------------------
// Wire dispatch (typed protocol; see crate::coordinator::protocol)
// ---------------------------------------------------------------------------

/// A v1-framed error line for server-originated failures that answer no
/// particular request (`conn_cap` rejects, oversized lines). The codes
/// are stable protocol surface: `rate_limited`, `conn_cap`, `bad_op`,
/// `overload`, `shutdown`.
pub fn err_response(code: &str, msg: &str) -> Json {
    Response::error(code, msg).to_json(&Envelope::v1())
}

/// Per-connection subscription state threaded into [`dispatch`]: the
/// mailbox push frames are delivered through, plus the subscription ids
/// this connection owns (dropped automatically when it closes).
struct ConnSubs {
    mailbox: Arc<Mailbox>,
    ids: Vec<u64>,
}

/// A wire query in flight: the receiver its answer arrives on, the
/// requested `k`, and the envelope the response renders under (v2
/// answers echo the request id and may interleave with later responses).
struct PendingQuery {
    rx: Receiver<Result<AsyncQueryResult>>,
    k: usize,
    env: Envelope,
}

/// Outcome of dispatching one request line: either a finished response
/// (plus whether it asked the server to shut down), or a wire query in
/// flight.
enum Reply {
    Done(Json, bool),
    Pending(PendingQuery),
}

/// Render a completed wire query. The answer always serves the published
/// snapshot; `action` reports the staleness decision and `scheduled`
/// whether a recompute was handed off-thread.
fn wire_query_response(res: Result<AsyncQueryResult>, k: usize, env: &Envelope) -> Json {
    let resp = match res {
        Ok(aq) => {
            let snap = &aq.snapshot;
            Response::Query {
                query_id: aq.query_id,
                version: snap.version,
                action: aq.decision,
                scheduled: aq.scheduled,
                age_secs: snap.age_secs(),
                top: snap.top(k),
            }
        }
        Err(e) => Response::from_error(&e),
    };
    resp.to_json(env)
}

/// JSON line protocol: one request object per line, one response per
/// line. Responses echo the request's version (`"v":1` by default,
/// `"v":2` when asked) and its `"id"` (v2 only); errors are
/// `{"error":{"code":…,"msg":…}}`.
///
/// Write-path requests (non-blocking; a full queue answers `overload`):
/// * `{"op":"add","src":1,"dst":2}`      → `{"v":1,"ok":true}`
/// * `{"op":"remove","src":1,"dst":2}`   → `{"v":1,"ok":true}`
/// * `{"op":"add_vertex","id":7}`        → `{"v":1,"ok":true}`
/// * `{"op":"remove_vertex","id":7}`     → `{"v":1,"ok":true}`
/// * `{"op":"batch","ops":[…]}`          → `{"v":1,"ok":true,"registered":N}`
///   — applied atomically: every element is validated first and one
///   malformed (or cap-exceeding, see [`MAX_WIRE_BATCH_OPS`]) element
///   rejects the whole batch with nothing registered.
/// * `{"op":"query","top":10}` → `{"v":1,"ok":true,"action":…,
///   "scheduled":…,"top":[[id,score],…]}` — served from the published
///   snapshot; any recompute the staleness policy demands runs
///   off-thread and publishes later. Under queue pressure the response
///   is an `overload` error that still carries the (stale but valid)
///   snapshot answer.
/// * `{"op":"shutdown"}`                 → `{"v":1,"ok":true}` and closes.
///
/// Read-path requests (served off the published snapshot, never queued;
/// subject to the per-connection `--rate-limit`):
/// * `{"op":"top","k":10}`  → `{"v":1,"ok":true,"version":…,"top":…}`
/// * `{"op":"rank","id":7}` → `{"v":1,"ok":true,"version":…,"rank":…}`
/// * `{"op":"stats"}`       → `{"v":1,"ok":true,"stats":{"serving":…,
///   "ingest":…,"engine":…,"server":…}}`
///
/// v2 surface (requests carrying `"v":2`): any request may add an
/// `"id"`, echoed verbatim; pipelined v2 queries are answered out of
/// order as they complete (v1 queries still pause the connection's
/// reads); and standing queries become available on wire connections:
/// * `{"v":2,"op":"subscribe","what":"topk","k":10}` → `{"v":2,"ok":true,
///   "sub":N}`, then push frames `{"v":2,"sub":N,"notify":{…}}` whenever
///   the watched condition fires at a snapshot publish. `what` is one of
///   `topk`, `rank` (`id` + `tau`), `hotset` (`id`), `community` (`id`;
///   needs the `--communities` workload).
/// * `{"v":2,"op":"unsubscribe","sub":N}` → `{"v":2,"ok":true,"sub":N}`.
pub fn handle_request(handle: &ServerHandle, line: &str) -> (Json, bool) {
    handle_request_limited(handle, line, None)
}

/// [`handle_request`] with an optional per-connection read limiter (what
/// the poll workers use; `None` = unlimited). Blocks on an in-flight
/// wire query — the readiness loop itself uses [`dispatch`] and polls.
/// Subscriptions need a wire connection's mailbox and are rejected here.
pub fn handle_request_limited(
    handle: &ServerHandle,
    line: &str,
    mut limiter: Option<&mut RateLimiter>,
) -> (Json, bool) {
    let mut off = RateLimiter::new(0.0);
    let l = limiter.as_deref_mut().unwrap_or(&mut off);
    match dispatch(handle, line, l, None) {
        Reply::Done(resp, stop) => (resp, stop),
        Reply::Pending(pq) => {
            let res =
                pq.rx.recv().unwrap_or_else(|_| Err(Error::Engine("engine thread gone".into())));
            (wire_query_response(res, pq.k, &pq.env), false)
        }
    }
}

/// Dispatch one request line without ever blocking: writes go through
/// `try_push`, queries return [`Reply::Pending`], reads hit the
/// snapshot, subscriptions register against `conn`'s mailbox.
fn dispatch(
    handle: &ServerHandle,
    line: &str,
    limiter: &mut RateLimiter,
    mut conn: Option<&mut ConnSubs>,
) -> Reply {
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return Reply::Done(
                Response::error("bad_op", &e.to_string()).to_json(&Envelope::v1()),
                false,
            )
        }
    };
    let env = match Envelope::parse(&req) {
        Ok(env) => env,
        Err(msg) => {
            return Reply::Done(Response::error("bad_op", &msg).to_json(&Envelope::v1()), false)
        }
    };
    let done = |resp: Response, env: &Envelope| Reply::Done(resp.to_json(env), false);
    let request = match Request::parse(&req) {
        Ok(r) => r,
        Err(msg) => return done(Response::error("bad_op", &msg), &env),
    };
    if request.is_read() && !limiter.admit() {
        return done(Response::error("rate_limited", "read rate limit exceeded"), &env);
    }
    // Count overloads where they surface, not at every error site.
    let wire_err = |e: Error, env: &Envelope| {
        if matches!(e, Error::Backpressure(_)) {
            handle.wire.overloads.fetch_add(1, Ordering::SeqCst);
        }
        Reply::Done(Response::from_error(&e).to_json(env), false)
    };
    match request {
        Request::Write(op) => match handle.try_ingest(op) {
            Ok(()) => done(Response::Ok, &env),
            Err(e) => wire_err(e, &env),
        },
        Request::Batch(ops) => {
            let n = ops.len();
            match handle.try_ingest_batch(ops) {
                Ok(()) => done(Response::Registered { n }, &env),
                Err(e) => wire_err(e, &env),
            }
        }
        Request::Query { k } => match handle.query_wire() {
            Ok(rx) => Reply::Pending(PendingQuery { rx, k, env }),
            Err(Error::Backpressure(_)) => {
                handle.wire.overloads.fetch_add(1, Ordering::SeqCst);
                // Degrade instead of queueing: answer from the published
                // snapshot, flagged as overload. The reply is stale but
                // internally consistent.
                let snap = handle.reader.latest_for(ReadKind::Top);
                done(
                    Response::Error {
                        code: "overload".into(),
                        msg: "engine queue at capacity; serving the published snapshot".into(),
                        extra: vec![
                            ("version".into(), Json::Num(snap.version as f64)),
                            ("query_id".into(), Json::Num(snap.query_id as f64)),
                            ("action".into(), Json::Str(snap.action.to_string())),
                            ("age_secs".into(), Json::Num(snap.age_secs())),
                            (
                                "top".into(),
                                Json::Arr(
                                    snap.top(k)
                                        .into_iter()
                                        .map(|(id, score)| {
                                            Json::Arr(vec![
                                                Json::Num(id as f64),
                                                Json::Num(score),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ],
                    },
                    &env,
                )
            }
            Err(e) => wire_err(e, &env),
        },
        // Read-path fast path: answered from the published snapshot.
        Request::Top { k } => {
            let snap = handle.reader.latest_for(ReadKind::Top);
            done(
                Response::Top {
                    version: snap.version,
                    query_id: snap.query_id,
                    action: snap.action,
                    top: snap.top(k),
                },
                &env,
            )
        }
        Request::Rank { id } => {
            // Partition-routed read: on a sharded server the owning
            // shard's (owned-only) snapshot answers directly; `top`
            // stays on the combined k-way merge.
            let snap = match &handle.shards {
                Some(ss) => ss.readers[ss.parts.shard_of(id)].latest_for(ReadKind::Rank),
                None => handle.reader.latest_for(ReadKind::Rank),
            };
            done(Response::Rank { version: snap.version, id, rank: snap.rank_of(id) }, &env)
        }
        Request::Stats => {
            let stats = match handle.reader.stats_json() {
                Json::Obj(mut fields) => {
                    fields.insert("server".into(), handle.server_stats_json());
                    fields.insert("durability".into(), handle.durability.to_json());
                    if let Some(ss) = &handle.shards {
                        fields.insert("shards".into(), ss.stats_json());
                    }
                    Json::Obj(fields)
                }
                other => other,
            };
            done(Response::Stats(stats), &env)
        }
        Request::Subscribe { spec, token } => {
            if !env.is_v2() {
                return done(
                    Response::error("bad_op", "subscriptions require protocol v2 (send \"v\":2)"),
                    &env,
                );
            }
            match conn.as_deref_mut() {
                Some(subs) => {
                    let registry = handle.reader.subscriptions();
                    let (sub, replayed) = match token.as_deref() {
                        // Durable: the registry remembers this token's
                        // last-notified state (checkpointed across
                        // restarts) and replays the diff missed while
                        // the client was away.
                        Some(token) => {
                            let snap = handle.reader.latest_for(ReadKind::Top);
                            registry.subscribe_durable(spec, &subs.mailbox, token, &snap)
                        }
                        None => (registry.subscribe(spec, &subs.mailbox), false),
                    };
                    subs.ids.push(sub);
                    done(Response::Subscribed { sub, replayed }, &env)
                }
                None => {
                    done(Response::error("bad_op", "subscriptions need a wire connection"), &env)
                }
            }
        }
        Request::Unsubscribe { sub } => {
            // Connections may drop only their own subscriptions.
            let owned = match conn.as_deref_mut() {
                Some(subs) => match subs.ids.iter().position(|&x| x == sub) {
                    Some(i) => {
                        subs.ids.swap_remove(i);
                        true
                    }
                    None => false,
                },
                None => false,
            };
            if owned && handle.reader.subscriptions().unsubscribe(sub) {
                done(Response::Unsubscribed { sub }, &env)
            } else {
                done(Response::error("bad_op", "unknown subscription id"), &env)
            }
        }
        Request::Shutdown => Reply::Done(Response::Ok.to_json(&env), true),
    }
}

// ---------------------------------------------------------------------------
// The readiness loop
// ---------------------------------------------------------------------------

/// Tuning knobs for the server: queue/policy knobs consumed by
/// [`ServerHandle::spawn_with`], front-end knobs by [`serve`]. Fluent
/// builder; construct with [`ServeOptions::new`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    max_connections: usize,
    rate_limit: f64,
    workers: usize,
    queue_capacity: usize,
    overflow: OverflowPolicy,
    policy: StalenessPolicy,
    window_secs: f64,
    communities: bool,
    recompute_workers: usize,
    reconcile: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_connections: 4096,
            rate_limit: 0.0,
            workers: 4,
            queue_capacity: 1 << 16,
            overflow: OverflowPolicy::Block,
            policy: StalenessPolicy::default(),
            window_secs: 0.0,
            communities: false,
            recompute_workers: 0,
            reconcile: true,
        }
    }
}

impl ServeOptions {
    /// Defaults: 4096 connections, no rate limit, 4 poll workers, a
    /// 65536-slot `Block` queue, default staleness policy, no sliding
    /// window, no community workload.
    pub fn new() -> Self {
        Self::default()
    }

    /// Simultaneous client connections; excess clients are rejected with
    /// one `conn_cap` error line and closed. Clamped to ≥ 1 so the
    /// server always admits the client that could send `shutdown`.
    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = n.max(1);
        self
    }

    /// Per-connection read-path rate limit in ops/sec (`top`/`rank`/
    /// `stats`; one-second burst allowance). Over-limit requests get a
    /// `rate_limited` error line, the connection stays open. 0 =
    /// unlimited.
    pub fn rate_limit(mut self, r: f64) -> Self {
        self.rate_limit = r;
        self
    }

    /// Poll workers ticking the connections (≥ 1). A small fixed set
    /// serves any number of mostly-idle clients.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Engine command queue slots (≥ 1).
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.queue_capacity = n.max(1);
        self
    }

    /// What a full engine queue does to blocking producers.
    pub fn overflow(mut self, p: OverflowPolicy) -> Self {
        self.overflow = p;
        self
    }

    /// Staleness policy wire queries are decided under.
    pub fn policy(mut self, p: StalenessPolicy) -> Self {
        self.policy = p;
        self
    }

    /// Sliding-window width in seconds: edges older than this are
    /// expired as server-generated `RemoveEdge` batches through the
    /// ordinary write pipeline. 0 (the default) keeps every edge.
    pub fn window_secs(mut self, secs: f64) -> Self {
        self.window_secs = secs.max(0.0);
        self
    }

    /// Run streaming label propagation beside PageRank as a second
    /// standing-analytics workload, feeding `community` subscriptions.
    pub fn communities(mut self, on: bool) -> Self {
        self.communities = on;
        self
    }

    /// Workers in the recompute worker's dedicated [`ThreadPool`]. 0 or
    /// 1 (the default) runs each job single-threaded on the worker
    /// itself; ≥ 2 gives exact and pooled-exchange jobs their own pool
    /// so they cannot starve the engine pool serving queries.
    pub fn recompute_workers(mut self, n: usize) -> Self {
        self.recompute_workers = n;
        self
    }

    /// Whether fence-missed recomputes are reconciled — the post-fence
    /// ops replayed onto the fenced ranks before publishing — instead of
    /// merged-and-recounted as misses. On by default; turning it off
    /// restores the supersession behaviour where an exact job may cancel
    /// a stale in-flight one.
    pub fn reconcile(mut self, on: bool) -> Self {
        self.reconcile = on;
        self
    }
}

/// Serve the line protocol over TCP until a client sends `shutdown`
/// (default [`ServeOptions`]).
pub fn serve_tcp(handle: ServerHandle, addr: &str) -> Result<()> {
    serve_tcp_with(handle, addr, ServeOptions::default())
}

/// [`serve_tcp`] with explicit options.
pub fn serve_tcp_with(handle: ServerHandle, addr: &str, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve(handle, listener, opts)
}

/// In-flight v2 queries one connection may pipeline before the server
/// stops reading from it (per-connection flow control; v1 connections
/// pause at one).
pub const MAX_PIPELINED_QUERIES: usize = 1024;

/// One connection owned by a poll worker: the socket plus its read/write
/// buffers and per-connection protocol state. Idle connections cost
/// exactly this struct — no thread.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet consumed as complete lines.
    buf: Vec<u8>,
    /// Response bytes not yet written to the socket.
    out: Vec<u8>,
    limiter: RateLimiter,
    /// An in-flight v1 wire query: no further requests are read until it
    /// answers, so v1 pipelined responses keep request order.
    pending: Option<PendingQuery>,
    /// In-flight v2 wire queries: reads continue and each answer is
    /// written (with its echoed id) as it completes, in completion
    /// order.
    pending_v2: Vec<PendingQuery>,
    /// Subscriptions owned by this connection and the mailbox their push
    /// frames arrive through.
    subs: ConnSubs,
    /// Close once `out` drains (EOF, protocol violation, or shutdown).
    close_after_flush: bool,
}

/// What one tick did with a connection.
enum Tick {
    /// Bytes moved or a request was dispatched — poll again immediately.
    Progress,
    Idle,
    Close,
}

enum Flush {
    Progress,
    Idle,
    Closed,
}

/// Write as much of `out` as the socket accepts right now.
fn flush_out(c: &mut Conn) -> Flush {
    let mut wrote = 0usize;
    while wrote < c.out.len() {
        match c.stream.write(&c.out[wrote..]) {
            Ok(0) => return Flush::Closed,
            Ok(n) => wrote += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::Interrupted) => break,
            Err(_) => return Flush::Closed,
        }
    }
    if wrote > 0 {
        c.out.drain(..wrote);
        Flush::Progress
    } else {
        Flush::Idle
    }
}

fn queue_line(c: &mut Conn, resp: &Json) {
    c.out.extend_from_slice(resp.to_string_compact().as_bytes());
    c.out.push(b'\n');
}

/// Reject an over-long request line and schedule the connection for
/// close (mid-line there is no way to resync).
fn reject_oversize(c: &mut Conn) {
    queue_line(
        c,
        &err_response("bad_op", &format!("request line exceeds {MAX_WIRE_LINE_BYTES} bytes")),
    );
    c.buf.clear();
    c.close_after_flush = true;
}

/// Advance one connection: flush pending output, drain push frames,
/// complete in-flight queries, read what the socket has, dispatch
/// complete lines, flush again. Never blocks.
fn tick_conn(
    handle: &ServerHandle,
    c: &mut Conn,
    scratch: &mut [u8],
    stop: &AtomicBool,
) -> Tick {
    let mut progressed = false;
    match flush_out(c) {
        Flush::Closed => return Tick::Close,
        Flush::Progress => progressed = true,
        Flush::Idle => {}
    }
    // Push plane: subscription notifications queued since the last tick.
    if !c.subs.mailbox.is_empty() {
        for frame in c.subs.mailbox.drain() {
            queue_line(c, &frame);
        }
        progressed = true;
    }
    // In-flight v2 queries answer out of order, as they complete; each
    // response carries its echoed id so the client can match them up.
    let mut i = 0;
    while i < c.pending_v2.len() {
        match c.pending_v2[i].rx.try_recv() {
            Ok(res) => {
                let pq = c.pending_v2.swap_remove(i);
                queue_line(c, &wire_query_response(res, pq.k, &pq.env));
                progressed = true;
            }
            Err(TryRecvError::Empty) => i += 1,
            Err(TryRecvError::Disconnected) => {
                let pq = c.pending_v2.swap_remove(i);
                queue_line(
                    c,
                    &Response::error("shutdown", "engine thread gone").to_json(&pq.env),
                );
                c.close_after_flush = true;
            }
        }
    }
    // An in-flight v1 wire query: deliver its answer when ready; until
    // then this connection reads nothing more (natural per-connection
    // flow control, and v1 responses stay in request order).
    if let Some(pq) = c.pending.take() {
        match pq.rx.try_recv() {
            Ok(res) => {
                queue_line(c, &wire_query_response(res, pq.k, &pq.env));
                progressed = true;
            }
            Err(TryRecvError::Empty) => {
                c.pending = Some(pq);
                let _ = flush_out(c);
                return if progressed { Tick::Progress } else { Tick::Idle };
            }
            Err(TryRecvError::Disconnected) => {
                queue_line(
                    c,
                    &Response::error("shutdown", "engine thread gone").to_json(&pq.env),
                );
                c.close_after_flush = true;
            }
        }
    }
    if c.close_after_flush {
        let _ = flush_out(c);
        return if c.out.is_empty() { Tick::Close } else { Tick::Progress };
    }
    match c.stream.read(scratch) {
        Ok(0) => {
            // EOF: the client hung up. Flush whatever is queued, then go.
            if c.out.is_empty() {
                return Tick::Close;
            }
            c.close_after_flush = true;
            return Tick::Progress;
        }
        Ok(n) => {
            c.buf.extend_from_slice(&scratch[..n]);
            progressed = true;
        }
        Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::Interrupted) => {}
        Err(_) => return Tick::Close,
    }
    loop {
        if c.pending_v2.len() >= MAX_PIPELINED_QUERIES {
            break;
        }
        match c.buf.iter().position(|&b| b == b'\n') {
            Some(pos) if pos > MAX_WIRE_LINE_BYTES => {
                reject_oversize(c);
                break;
            }
            None => {
                if c.buf.len() > MAX_WIRE_LINE_BYTES {
                    reject_oversize(c);
                }
                break;
            }
            Some(pos) => {
                let line: Vec<u8> = c.buf.drain(..=pos).collect();
                let text = String::from_utf8_lossy(&line[..pos]);
                let text = text.trim();
                if text.is_empty() {
                    continue;
                }
                progressed = true;
                match dispatch(handle, text, &mut c.limiter, Some(&mut c.subs)) {
                    Reply::Done(resp, shutdown) => {
                        queue_line(c, &resp);
                        if shutdown {
                            c.close_after_flush = true;
                            stop.store(true, Ordering::SeqCst);
                            break;
                        }
                    }
                    // A v2 query joins the pipelined set and reading
                    // continues (up to the cap); a v1 query pauses
                    // reads until it answers.
                    Reply::Pending(pq) if pq.env.is_v2() => {
                        c.pending_v2.push(pq);
                        if c.pending_v2.len() >= MAX_PIPELINED_QUERIES {
                            break;
                        }
                    }
                    Reply::Pending(pq) => {
                        c.pending = Some(pq);
                        break;
                    }
                }
            }
        }
    }
    match flush_out(c) {
        Flush::Closed => return Tick::Close,
        Flush::Progress => progressed = true,
        Flush::Idle => {}
    }
    if c.close_after_flush && c.out.is_empty() {
        return Tick::Close;
    }
    if progressed {
        Tick::Progress
    } else {
        Tick::Idle
    }
}

/// One poll worker: owns a slice of the connections, ticks each in turn,
/// sleeps briefly only when a full sweep made no progress.
fn poll_worker(
    handle: Arc<ServerHandle>,
    inject: Arc<Mutex<Vec<TcpStream>>>,
    stop: Arc<AtomicBool>,
    rate_limit: f64,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut scratch = vec![0u8; 16 * 1024];
    while !stop.load(Ordering::SeqCst) {
        {
            let mut inj = inject.lock().unwrap();
            for stream in inj.drain(..) {
                conns.push(Conn {
                    stream,
                    buf: Vec::new(),
                    out: Vec::new(),
                    limiter: RateLimiter::new(rate_limit),
                    pending: None,
                    pending_v2: Vec::new(),
                    subs: ConnSubs { mailbox: Mailbox::new(), ids: Vec::new() },
                    close_after_flush: false,
                });
            }
        }
        let mut progressed = false;
        let mut i = 0;
        while i < conns.len() {
            match tick_conn(&handle, &mut conns[i], &mut scratch, &stop) {
                Tick::Close => {
                    let c = conns.swap_remove(i);
                    // A closing connection takes its subscriptions with
                    // it; the registry also self-prunes via the weak
                    // mailbox, this just frees the slots eagerly.
                    // `disconnect` (not `unsubscribe`) so durable
                    // records survive for a later re-subscribe.
                    for id in &c.subs.ids {
                        handle.reader.subscriptions().disconnect(*id);
                    }
                    drop(c);
                    handle.wire.connections.fetch_sub(1, Ordering::SeqCst);
                }
                Tick::Progress => {
                    progressed = true;
                    i += 1;
                }
                Tick::Idle => i += 1,
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    // Stopping: flush queued responses best-effort (bounded), then drop.
    for mut c in conns {
        let _ = c.stream.set_nonblocking(false);
        let _ = c.stream.set_write_timeout(Some(Duration::from_millis(200)));
        if !c.out.is_empty() {
            let _ = c.stream.write_all(&c.out);
        }
        for id in &c.subs.ids {
            handle.reader.subscriptions().disconnect(*id);
        }
        handle.wire.connections.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Nonblocking TCP front end over a pre-bound listener (bind to port 0
/// in tests and read `listener.local_addr()` first): the calling thread
/// accepts, `opts.workers` poll threads tick the connections through
/// per-connection buffers. Read ops never enter the engine queue and
/// wire queries never block a worker, so thousands of mostly-idle
/// clients are served by this small fixed thread set even while a
/// recompute runs. Returns once a client sends `shutdown`.
pub fn serve(handle: ServerHandle, listener: TcpListener, opts: ServeOptions) -> Result<()> {
    serve_shared(Arc::new(handle), listener, opts)
}

/// [`serve`] over a pre-shared handle, for callers (tests, embedding
/// hosts) that keep their own `Arc<ServerHandle>` to drive the engine
/// directly while the front end runs.
pub fn serve_shared(
    handle: Arc<ServerHandle>,
    listener: TcpListener,
    opts: ServeOptions,
) -> Result<()> {
    let local = listener.local_addr()?;
    crate::log_info!("listening on {local}");
    listener.set_nonblocking(true)?;
    let workers = opts.workers.max(1);
    let max_connections = opts.max_connections.max(1);
    handle.wire.workers.store(workers, Ordering::SeqCst);
    let stop = Arc::new(AtomicBool::new(false));
    let mut injects: Vec<Arc<Mutex<Vec<TcpStream>>>> = Vec::with_capacity(workers);
    let mut threads: Vec<JoinHandle<()>> = Vec::with_capacity(workers);
    for w in 0..workers {
        let inject = Arc::new(Mutex::new(Vec::new()));
        injects.push(Arc::clone(&inject));
        let h2 = Arc::clone(&handle);
        let stop2 = Arc::clone(&stop);
        let rate = opts.rate_limit;
        threads.push(
            std::thread::Builder::new()
                .name(format!("veilgraph-poll-{w}"))
                .spawn(move || poll_worker(h2, inject, stop2, rate))
                .expect("spawn poll worker"),
        );
    }
    let mut next = 0usize;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                if handle.wire.connections.load(Ordering::SeqCst) >= max_connections {
                    let mut s = stream;
                    let _ = s.set_nonblocking(false);
                    let _ = s.set_write_timeout(Some(Duration::from_millis(200)));
                    let reject = err_response("conn_cap", "server at connection capacity");
                    let _ = s.write_all(reject.to_string_compact().as_bytes());
                    let _ = s.write_all(b"\n");
                    crate::log_warn!("rejected {peer}: at connection capacity");
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                crate::log_debug!("client {peer}");
                handle.wire.connections.fetch_add(1, Ordering::SeqCst);
                injects[next % workers].lock().unwrap().push(stream);
                next += 1;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                stop.store(true, Ordering::SeqCst);
                for t in threads {
                    let _ = t.join();
                }
                return Err(e.into());
            }
        }
    }
    for t in threads {
        let _ = t.join();
    }
    handle.request_shutdown();
    // Last Arc: join the engine + recompute threads before returning.
    if let Ok(h) = Arc::try_unwrap(handle) {
        h.shutdown();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineBuilder;
    use std::io::{BufRead, BufReader};

    fn handle() -> ServerHandle {
        let edges: Vec<(u64, u64)> = (0..20).map(|i| (i, (i + 1) % 20)).collect();
        let engine = EngineBuilder::new().build_from_edges(edges).unwrap();
        ServerHandle::spawn(engine, 64, OverflowPolicy::Block)
    }

    fn err_code(resp: &Json) -> &str {
        resp.get("error").unwrap().get("code").unwrap().as_str().unwrap()
    }

    fn err_msg(resp: &Json) -> &str {
        resp.get("error").unwrap().get("msg").unwrap().as_str().unwrap()
    }

    #[test]
    fn ingest_then_query_roundtrip() {
        let h = handle();
        h.ingest(EdgeOp::add(0, 10)).unwrap();
        let r = h.query().unwrap();
        assert_eq!(r.query_id, 1);
        assert!(!r.ranks().is_empty());
        h.shutdown();
    }

    #[test]
    fn stats_reflect_served_queries() {
        let h = handle();
        let _ = h.query().unwrap();
        let _ = h.query().unwrap();
        let stats = h.stats().unwrap();
        assert_eq!(
            stats.get("counters").unwrap().get("queries").unwrap().as_u64(),
            Some(2)
        );
        h.shutdown();
    }

    #[test]
    fn concurrent_producers_are_serialized() {
        let h = std::sync::Arc::new(handle());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h2 = std::sync::Arc::clone(&h);
            joins.push(std::thread::spawn(move || {
                for i in 0..25u64 {
                    h2.ingest(EdgeOp::add(100 + t * 100 + i, i % 20)).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let r = h.query().unwrap();
        assert_eq!(r.ids().len(), 20 + 100, "20 ring + 100 new sources");
    }

    #[test]
    fn line_protocol_add_query_stats() {
        let h = handle();
        let (resp, stop) = handle_request(&h, r#"{"op":"add","src":3,"dst":9}"#);
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("v").unwrap().as_u64(), Some(WIRE_PROTOCOL_V1));
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":3}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 3);
        // One effective update pending: the policy escalates and the
        // recompute is handed off-thread.
        assert_eq!(resp.get("action").unwrap().as_str(), Some("approximate"));
        assert_eq!(resp.get("scheduled").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        assert!(resp.get("stats").is_some());
        let (_, stop) = handle_request(&h, r#"{"op":"shutdown"}"#);
        assert!(stop);
        h.shutdown();
    }

    #[test]
    fn wire_query_publishes_off_thread() {
        let h = handle();
        let v0 = h.reader().latest().version;
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":5,"dst":12}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":2}"#);
        assert_eq!(resp.get("scheduled").unwrap().as_bool(), Some(true));
        // The recompute publishes asynchronously. The wire reply itself
        // may republish a repeat-last snapshot (the graph moved), so wait
        // specifically for a recompute-published one.
        let reader = h.reader();
        let mut refreshed = false;
        for _ in 0..500 {
            let s = reader.latest();
            if s.version > v0 && s.action != Action::RepeatLast {
                refreshed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(refreshed, "off-thread recompute must publish a fresh snapshot");
        h.shutdown();
    }

    #[test]
    fn superseded_recompute_is_cancelled() {
        let edges: Vec<(u64, u64)> = (0..20).map(|i| (i, (i + 1) % 20)).collect();
        let engine = EngineBuilder::new().build_from_edges(edges).unwrap();
        // Every update escalates straight to exact, so the second query
        // schedules an exact successor that supersedes the pinned job.
        // Supersession only exists with reconciliation off (on, the
        // stale job is salvaged instead of cancelled).
        let opts = ServeOptions::new()
            .queue_capacity(64)
            .reconcile(false)
            .policy(StalenessPolicy::new(1, 1, 8, 64, 5.0, 120.0));
        let h = ServerHandle::spawn_with(engine, &opts);
        h.hold_recompute();
        // Job A: fenced on the topology including edge (100, 0), then
        // pinned at the worker gate before it runs.
        h.ingest(EdgeOp::add(100, 0)).unwrap();
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":1}"#);
        assert_eq!(resp.get("scheduled").unwrap().as_bool(), Some(true));
        // The graph moves past A's fence; the next query schedules the
        // exact successor B.
        h.ingest(EdgeOp::add(101, 0)).unwrap();
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":1}"#);
        assert_eq!(resp.get("action").unwrap().as_str(), Some("exact"));
        assert_eq!(resp.get("scheduled").unwrap().as_bool(), Some(true));
        h.release_recompute();
        // A comes home first and is discarded; B installs cleanly and
        // publishes a snapshot covering both new vertices.
        let mut cancelled = 0;
        for _ in 0..500 {
            cancelled = h.wire_stats().recomputes_cancelled.load(Ordering::SeqCst);
            if cancelled == 1 && h.reader().latest().rank_of(101).is_some() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(cancelled, 1, "superseded job must be counted as cancelled");
        assert_eq!(
            h.wire_stats().recompute_fence_misses.load(Ordering::SeqCst),
            0,
            "the discarded job must not be fence-miss-merged"
        );
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        let server = resp.get("stats").unwrap().get("server").unwrap();
        assert_eq!(server.get("recomputes_cancelled").unwrap().as_u64(), Some(1));
        // B's installed snapshot ranks both new vertices.
        let snap = h.reader().latest();
        assert!(snap.rank_of(100).is_some() && snap.rank_of(101).is_some());
        h.shutdown();
    }

    #[test]
    fn fence_missed_recompute_is_reconciled_not_discarded() {
        let edges: Vec<(u64, u64)> = (0..20).map(|i| (i, (i + 1) % 20)).collect();
        let engine = EngineBuilder::new().build_from_edges(edges).unwrap();
        // Reconciliation on (the default) plus a dedicated 2-worker pool:
        // the job pinned at the gate goes stale, comes home to a fence
        // miss, and is salvaged by replaying the post-fence op — no
        // successor is scheduled and nothing is cancelled.
        let opts = ServeOptions::new()
            .queue_capacity(64)
            .recompute_workers(2)
            .policy(StalenessPolicy::new(1, 1, 8, 64, 5.0, 120.0));
        let h = ServerHandle::spawn_with(engine, &opts);
        h.hold_recompute();
        h.ingest(EdgeOp::add(100, 0)).unwrap();
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":1}"#);
        assert_eq!(resp.get("scheduled").unwrap().as_bool(), Some(true));
        // The graph moves past the fence; with reconciliation on the
        // in-flight job stays useful, so the next query stacks nothing.
        h.ingest(EdgeOp::add(101, 0)).unwrap();
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":1}"#);
        assert_eq!(resp.get("scheduled").unwrap().as_bool(), Some(false));
        h.release_recompute();
        let mut reconciled = 0;
        for _ in 0..500 {
            reconciled = h.wire_stats().recomputes_reconciled.load(Ordering::SeqCst);
            if reconciled == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(reconciled, 1, "the stale job must be reconciled");
        assert_eq!(h.wire_stats().recompute_fence_misses.load(Ordering::SeqCst), 0);
        assert_eq!(h.wire_stats().recomputes_cancelled.load(Ordering::SeqCst), 0);
        // The reconciled publish covers the post-fence vertex too.
        let snap = h.reader().latest();
        assert!(snap.rank_of(100).is_some() && snap.rank_of(101).is_some());
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        let server = resp.get("stats").unwrap().get("server").unwrap();
        assert_eq!(server.get("recomputes_reconciled").unwrap().as_u64(), Some(1));
        assert_eq!(server.get("recompute_pool_size").unwrap().as_u64(), Some(2));
        assert_eq!(server.get("plan_reused").unwrap().as_u64(), Some(0));
        assert_eq!(server.get("plan_rebuilt").unwrap().as_u64(), Some(0));
        h.shutdown();
    }

    #[test]
    fn sharded_handle_routes_rank_and_reports_shards() {
        use crate::coordinator::sharded::ShardedEngineBuilder;
        let edges: Vec<(u64, u64)> = (0..20).map(|i| (i, (i + 1) % 20)).collect();
        let engine = ShardedEngineBuilder::new(3).build_from_edges(edges).unwrap();
        let h = ServerHandle::spawn_sharded(engine, &ServeOptions::new());
        // rank routes to the owning shard's owned-only snapshot.
        let (resp, _) = handle_request(&h, r#"{"op":"rank","id":7}"#);
        assert!(resp.get("rank").unwrap().as_f64().is_some());
        let (resp, _) = handle_request(&h, r#"{"op":"rank","id":424242}"#);
        assert_eq!(resp.get("rank"), Some(&Json::Null));
        // stats grow a per-shard section alongside the usual ones.
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        let stats = resp.get("stats").unwrap();
        let shards = stats.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 3);
        let total: u64 =
            shards.iter().map(|s| s.get("vertices").unwrap().as_u64().unwrap()).sum();
        assert_eq!(total, 20, "owned shard snapshots partition the vertex set");
        assert!(stats.get("server").is_some() && stats.get("durability").is_some());
        // The write + query surface is unchanged.
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":100,"dst":0}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":3}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 3);
        h.shutdown();
    }

    #[test]
    fn line_protocol_vertex_ops() {
        let h = handle();
        let (resp, _) = handle_request(&h, r#"{"op":"add_vertex","id":77}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"remove_vertex","id":3}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let r = h.query().unwrap();
        assert!(r.ids().contains(&77), "added vertex is ranked");
        assert!(r.rank_of(77).is_some());
        // no further mutations ⇒ the next query reuses the snapshot
        assert_eq!(h.query().unwrap().snapshot.version, r.snapshot.version);
        let (resp, _) = handle_request(&h, r#"{"op":"add_vertex"}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err_code(&resp), "bad_op");
        h.shutdown();
    }

    #[test]
    fn line_protocol_top_and_rank_are_off_queue() {
        let h = handle();
        let _ = h.query().unwrap(); // publish a post-update snapshot
        let before = h.reader().read_stats();
        let (resp, _) = handle_request(&h, r#"{"op":"top","k":4}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 4);
        assert!(resp.get("version").unwrap().as_u64().unwrap() >= 1);
        let (resp, _) = handle_request(&h, r#"{"op":"rank","id":0}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert!(resp.get("rank").unwrap().as_f64().is_some());
        let (resp, _) = handle_request(&h, r#"{"op":"rank","id":999999}"#);
        assert_eq!(resp.get("rank"), Some(&Json::Null));
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        let serving = resp.get("stats").unwrap().get("serving").unwrap();
        assert!(serving.get("reads_top").unwrap().as_u64().unwrap() >= 1);
        // The server section rides along with the snapshot stats.
        let server = resp.get("stats").unwrap().get("server").unwrap();
        assert_eq!(server.get("protocol_version").unwrap().as_u64(), Some(2));
        assert!(server.get("queue_capacity").unwrap().as_u64().unwrap() >= 1);
        assert!(server.get("policy").unwrap().get("approx_after_updates").is_some());
        // engine saw zero extra commands: all the ops hit the snapshot
        let after = h.reader().read_stats();
        assert_eq!(after.rank, before.rank + 2);
        let live = h.stats().unwrap();
        let queries = live.get("counters").unwrap().get("queries").unwrap().as_u64();
        assert_eq!(queries, Some(1), "read ops must not round-trip through the engine");
        h.shutdown();
    }

    #[test]
    fn line_protocol_rejects_garbage() {
        let h = handle();
        let (resp, stop) = handle_request(&h, "not json");
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err_code(&resp), "bad_op");
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":1}"#);
        assert_eq!(err_code(&resp), "bad_op");
        let (resp, _) = handle_request(&h, r#"{"op":"fly"}"#);
        assert!(err_msg(&resp).contains("fly"));
        h.shutdown();
    }

    #[test]
    fn versioned_requests_negotiate() {
        let h = handle();
        // Explicit v1 is accepted and answered in v1 framing.
        let (resp, _) = handle_request(&h, r#"{"v":1,"op":"top","k":2}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("v").unwrap().as_u64(), Some(1));
        // v2 is accepted and echoes the request id.
        let (resp, _) = handle_request(&h, r#"{"v":2,"id":17,"op":"top","k":2}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("v").unwrap().as_u64(), Some(2));
        assert_eq!(resp.get("id").unwrap().as_u64(), Some(17));
        // v1 requests have no id surface.
        let (resp, _) = handle_request(&h, r#"{"v":1,"id":17,"op":"top","k":2}"#);
        assert!(resp.get("id").is_none());
        // Future versions are refused with a stable code.
        let (resp, _) = handle_request(&h, r#"{"v":3,"op":"top","k":2}"#);
        assert_eq!(err_code(&resp), "bad_op");
        assert!(err_msg(&resp).contains("version"));
        // Non-numeric versions too.
        let (resp, _) = handle_request(&h, r#"{"v":"two","op":"top"}"#);
        assert_eq!(err_code(&resp), "bad_op");
        h.shutdown();
    }

    #[test]
    fn subscriptions_need_v2_and_a_wire_connection() {
        let h = handle();
        // v1 subscribe: refused before anything registers.
        let (resp, _) = handle_request(&h, r#"{"op":"subscribe","what":"topk","k":3}"#);
        assert_eq!(err_code(&resp), "bad_op");
        assert!(err_msg(&resp).contains("v2"), "{}", err_msg(&resp));
        // v2 subscribe without a wire connection (handle_request passes
        // no mailbox): also refused.
        let (resp, _) = handle_request(&h, r#"{"v":2,"op":"subscribe","what":"topk","k":3}"#);
        assert_eq!(err_code(&resp), "bad_op");
        assert!(err_msg(&resp).contains("connection"), "{}", err_msg(&resp));
        assert!(h.reader().subscriptions().is_empty());
        // Unknown unsubscribe ids are errors, not silent successes.
        let (resp, _) = handle_request(&h, r#"{"v":2,"op":"unsubscribe","sub":99}"#);
        assert_eq!(err_code(&resp), "bad_op");
        h.shutdown();
    }

    #[test]
    fn stopped_handle_answers_with_shutdown_code() {
        let h = handle();
        h.request_shutdown();
        // Give the engine thread a moment to drain and exit.
        for _ in 0..200 {
            if !h.is_running() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":1,"dst":2}"#);
        assert_eq!(err_code(&resp), "shutdown");
        let (resp, _) = handle_request(&h, r#"{"op":"query"}"#);
        assert_eq!(err_code(&resp), "shutdown");
    }

    #[test]
    fn serve_options_builder_clamps() {
        let o = ServeOptions::new()
            .max_connections(0)
            .workers(0)
            .queue_capacity(0)
            .rate_limit(2.5)
            .overflow(OverflowPolicy::Reject)
            .window_secs(-3.0)
            .communities(true)
            .recompute_workers(3)
            .reconcile(false);
        assert_eq!(o.max_connections, 1);
        assert_eq!(o.workers, 1);
        assert_eq!(o.queue_capacity, 1);
        assert_eq!(o.rate_limit, 2.5);
        assert_eq!(o.overflow, OverflowPolicy::Reject);
        assert_eq!(o.window_secs, 0.0, "negative windows clamp to unbounded");
        assert!(o.communities);
        assert_eq!(o.recompute_workers, 3);
        assert!(!o.reconcile);
        let d = ServeOptions::default();
        assert_eq!(d.max_connections, 4096);
        assert_eq!(d.workers, 4);
        assert_eq!(d.window_secs, 0.0);
        assert!(!d.communities);
        assert_eq!(d.recompute_workers, 0, "recompute jobs run single-threaded by default");
        assert!(d.reconcile, "fence reconciliation is on by default");
    }

    #[test]
    fn line_protocol_batch_registers_all_ops_in_one_request() {
        let h = handle();
        let line = r#"{"op":"batch","ops":[
            {"op":"add","src":100,"dst":0},
            {"op":"add","src":101,"dst":1},
            {"op":"add_vertex","id":102},
            {"op":"remove","src":0,"dst":1}
        ]}"#
        .replace('\n', "");
        let (resp, stop) = handle_request(&h, &line);
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("registered").unwrap().as_u64(), Some(4));
        let r = h.query().unwrap();
        assert!(r.ids().contains(&100) && r.ids().contains(&101) && r.ids().contains(&102));
        let g = h.query().unwrap();
        assert!(g.rank_of(102).is_some());
        h.shutdown();
    }

    #[test]
    fn line_protocol_batch_is_all_or_nothing() {
        let h = handle();
        // Second element is malformed: nothing from the batch registers.
        let line = r#"{"op":"batch","ops":[{"op":"add","src":30,"dst":0},{"op":"add","src":31}]}"#;
        let (resp, _) = handle_request(&h, line);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let err = err_msg(&resp);
        assert!(err.contains("batch op 1"), "error names the bad element: {err}");
        let r = h.query().unwrap();
        assert!(!r.ids().contains(&30), "no partial registration");
        // Non-array ops and bare batches fail cleanly too.
        let (resp, _) = handle_request(&h, r#"{"op":"batch"}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        h.shutdown();
    }

    #[test]
    fn line_protocol_batch_enforces_the_size_cap() {
        let h = handle();
        let ops: Vec<String> = (0..MAX_WIRE_BATCH_OPS as u64 + 1)
            .map(|i| format!(r#"{{"op":"add","src":{},"dst":{}}}"#, 10_000 + i, i % 20))
            .collect();
        let line = format!(r#"{{"op":"batch","ops":[{}]}}"#, ops.join(","));
        let (resp, _) = handle_request(&h, &line);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let err = err_msg(&resp);
        assert!(err.contains("cap"), "rejection names the cap: {err}");
        let r = h.query().unwrap();
        assert!(!r.ids().contains(&10_000), "nothing registered past the cap");
        h.shutdown();
    }

    #[test]
    fn oversized_request_line_is_rejected_and_dropped() {
        let h = handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions::new().workers(1);
        let server = std::thread::spawn(move || serve(h, listener, opts).unwrap());
        let mut client = TcpStream::connect(addr).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let huge = vec![b'x'; MAX_WIRE_LINE_BYTES + 64];
        client.write_all(&huge).unwrap();
        let mut r = BufReader::new(client.try_clone().unwrap());
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err_code(&j), "bad_op");
        assert!(err_msg(&j).contains("bytes"));
        let mut rest = String::new();
        assert_eq!(r.read_line(&mut rest).unwrap(), 0, "oversized client is dropped");
        // A fresh client can still stop the server: the violation cost
        // one connection, not the process.
        let mut c2 = TcpStream::connect(addr).unwrap();
        c2.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        server.join().unwrap();
    }

    #[test]
    fn rate_limiter_admits_burst_then_rejects() {
        let mut l = RateLimiter::new(3.0);
        let admitted = (0..50).filter(|_| l.admit()).count();
        assert!(admitted >= 3, "burst capacity admits the first requests");
        assert!(admitted < 50, "sustained flood is limited");
        // rate 0 = off
        let mut off = RateLimiter::new(0.0);
        assert!((0..1000).all(|_| off.admit()));
    }

    #[test]
    fn tcp_server_end_to_end() {
        let h = handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions::new().workers(2);
        let server = std::thread::spawn(move || serve(h, listener, opts).unwrap());
        let mut client = TcpStream::connect(addr).unwrap();
        let script = concat!(
            "{\"op\":\"add\",\"src\":1,\"dst\":15}\n",
            "{\"op\":\"query\",\"top\":2}\n",
            "{\"op\":\"shutdown\"}\n"
        );
        client.write_all(script.as_bytes()).unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(3).map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 3);
        let q = Json::parse(&lines[1]).unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(q.get("v").unwrap().as_u64(), Some(1));
        assert_eq!(q.get("top").unwrap().as_arr().unwrap().len(), 2);
        server.join().unwrap();
    }
}
