//! Async readiness-loop server: three planes behind a versioned wire
//! protocol.
//!
//! The server splits Fig. 2's "GraphBolt module" into three planes that
//! overlap freely:
//!
//! * **Ingest plane** — producers talk to a single engine thread through
//!   a bounded command queue ([`crate::stream::backpressure`]); mutations
//!   coalesce in the update buffer and apply in batches. The wire path
//!   uses `try_push` only: a full queue never stalls a poll worker, it
//!   surfaces as a structured `overload` error (or sheds under
//!   `DropOldest`).
//! * **Recompute plane** — the engine thread never runs PageRank. When
//!   the staleness policy escalates, [`Engine::query_async`] hands back a
//!   version-fenced [`RecomputeJob`]; a dedicated worker runs it and
//!   returns the result through the command queue, where
//!   [`Engine::finish_recompute`] installs (fence hit) or merges (fence
//!   miss) it and publishes. At most one job is in flight; decisions
//!   degrade down the accuracy ladder under queue pressure
//!   ([`StalenessPolicy::decide_under_pressure`]).
//! * **Read plane** — every [`ServerHandle`] carries a
//!   [`SnapshotReader`] onto the published
//!   [`RankSnapshot`](crate::coordinator::serving::RankSnapshot)s;
//!   `top`/`rank`/`stats` never enter the queue, so a recompute or batch
//!   apply in progress never blocks a read.
//!
//! The TCP front end ([`serve`]) is a nonblocking readiness loop: the
//! calling thread accepts, a small fixed set of poll workers each own a
//! slice of the connections and tick them through per-connection read/
//! write buffers. Thousands of mostly-idle clients cost no threads —
//! only a vector slot and two buffers each.
//!
//! All requests and responses speak wire protocol v1
//! ([`WIRE_PROTOCOL_VERSION`]): responses carry `"v":1` and errors are
//! structured objects `{"error":{"code":"...","msg":"..."}}` with stable
//! codes (`rate_limited`, `conn_cap`, `bad_op`, `overload`, `shutdown`).
//! Requests without a `"v"` field parse as v1.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::engine::{
    AsyncQueryResult, Engine, QueryResult, RecomputeJob, RecomputeResult,
};
use crate::coordinator::policies::StalenessPolicy;
use crate::coordinator::serving::{ReadKind, SnapshotReader};
use crate::coordinator::udf::Action;
use crate::error::{Error, Result};
use crate::stream::backpressure::{BoundedQueue, OverflowPolicy};
use crate::stream::event::EdgeOp;
use crate::util::json::Json;

/// The wire protocol version this server speaks. Responses carry it as
/// `"v"`; requests may omit it (legacy clients parse as v1) but a present
/// version must match.
pub const WIRE_PROTOCOL_VERSION: u64 = 1;

/// Commands accepted by the engine thread (the ingest plane).
enum Command {
    Op(EdgeOp),
    /// A pre-validated batch: registered contiguously (one queue slot,
    /// one engine call), so it is all-or-nothing with respect to other
    /// producers.
    Batch(Vec<EdgeOp>),
    /// Legacy synchronous query: applies updates and recomputes inline on
    /// the engine thread. Library callers that want one authoritative
    /// answer ([`ServerHandle::query`]) still use it; the wire path does
    /// not.
    Query(Sender<Result<QueryResult>>),
    /// Wire query: answered immediately from the published snapshot, with
    /// any recompute handed to the off-thread worker.
    WireQuery(Sender<Result<AsyncQueryResult>>),
    /// A finished off-thread recompute coming home to be installed.
    RecomputeDone(Box<RecomputeResult>),
    Stats(Sender<Json>),
    Shutdown,
}

/// Live counters for the wire front end, shared between the acceptor,
/// the poll workers and the `stats` op.
#[derive(Default)]
pub struct WireStats {
    /// Currently-open client connections.
    pub connections: AtomicUsize,
    /// Poll workers serving them (0 until [`serve`] starts).
    pub workers: AtomicUsize,
    /// Requests answered with the `overload` code.
    pub overloads: AtomicU64,
    /// Whether a recompute job is currently running off-thread.
    pub recompute_in_flight: AtomicBool,
    /// Last staleness decision taken by a wire query
    /// (0 = none yet, 1 = repeat-last, 2 = approximate, 3 = exact).
    last_decision: AtomicU8,
}

impl WireStats {
    fn set_last_decision(&self, a: Action) {
        let code = match a {
            Action::RepeatLast => 1,
            Action::ComputeApproximate => 2,
            Action::ComputeExact => 3,
        };
        self.last_decision.store(code, Ordering::Relaxed);
    }

    /// The most recent wire-query staleness decision, if any query ran.
    pub fn last_decision(&self) -> Option<Action> {
        match self.last_decision.load(Ordering::Relaxed) {
            1 => Some(Action::RepeatLast),
            2 => Some(Action::ComputeApproximate),
            3 => Some(Action::ComputeExact),
            _ => None,
        }
    }
}

/// Test hook: a gate the recompute worker passes through *before* running
/// each job. [`ServerHandle::hold_recompute`] parks the worker so tests
/// can prove readers and writers stay live while a recompute is pinned
/// mid-flight; [`ServerHandle::release_recompute`] lets it continue.
struct RecomputeGate {
    held: Mutex<bool>,
    cv: Condvar,
}

impl RecomputeGate {
    fn new() -> Self {
        Self { held: Mutex::new(false), cv: Condvar::new() }
    }

    fn hold(&self) {
        *self.held.lock().unwrap() = true;
    }

    fn release(&self) {
        *self.held.lock().unwrap() = false;
        self.cv.notify_all();
    }

    /// Wait until released; false means the server shut down while held.
    fn wait_released(&self, queue: &BoundedQueue<Command>) -> bool {
        let mut held = self.held.lock().unwrap();
        while *held {
            if queue.is_closed() {
                return false;
            }
            let (g, _) = self.cv.wait_timeout(held, Duration::from_millis(20)).unwrap();
            held = g;
        }
        true
    }
}

/// Handle to a running engine thread + recompute worker, plus the
/// lock-free read plane.
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Command>>,
    worker: Option<JoinHandle<()>>,
    recompute: Option<JoinHandle<()>>,
    running: Arc<AtomicBool>,
    reader: SnapshotReader,
    policy: StalenessPolicy,
    wire: Arc<WireStats>,
    gate: Arc<RecomputeGate>,
}

impl ServerHandle {
    /// Spawn the engine thread and the recompute worker with the queue,
    /// overflow and staleness knobs from `opts`.
    pub fn spawn_with(mut engine: Engine, opts: &ServeOptions) -> Self {
        let reader = engine.reader();
        let queue = Arc::new(BoundedQueue::new(opts.queue_capacity, opts.overflow));
        let running = Arc::new(AtomicBool::new(true));
        let wire = Arc::new(WireStats::default());
        let gate = Arc::new(RecomputeGate::new());
        let policy = opts.policy;

        let (job_tx, job_rx) = channel::<RecomputeJob>();
        let q_jobs = Arc::clone(&queue);
        let gate2 = Arc::clone(&gate);
        let recompute = std::thread::Builder::new()
            .name("veilgraph-recompute".into())
            .spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    if !gate2.wait_released(&q_jobs) {
                        break;
                    }
                    let res = job.run();
                    // Results ride the command queue ahead of capacity
                    // (control plane, at most one outstanding): a full
                    // queue must not be able to strand a finished
                    // recompute.
                    if q_jobs.force_push(Command::RecomputeDone(Box::new(res))).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn recompute thread");

        let q2 = Arc::clone(&queue);
        let r2 = Arc::clone(&running);
        let w2 = Arc::clone(&wire);
        let worker = std::thread::Builder::new()
            .name("veilgraph-engine".into())
            .spawn(move || {
                let cap = q2.capacity().max(1);
                // At most one recompute job outstanding: while it runs,
                // queries are still decided and answered (degraded) but
                // no second job is created.
                let mut in_flight = false;
                while let Some(cmd) = q2.pop() {
                    match cmd {
                        Command::Op(op) => engine.ingest(op),
                        Command::Batch(ops) => engine.ingest_batch(ops),
                        Command::Query(reply) => {
                            let _ = reply.send(engine.query());
                        }
                        Command::WireQuery(reply) => {
                            let pressure = q2.len() as f64 / cap as f64;
                            match engine.query_async(&policy, pressure, !in_flight) {
                                Ok((mut aq, job)) => {
                                    if let Some(job) = job {
                                        if job_tx.send(job).is_ok() {
                                            in_flight = true;
                                            w2.recompute_in_flight.store(true, Ordering::SeqCst);
                                        } else {
                                            aq.scheduled = false;
                                        }
                                    }
                                    w2.set_last_decision(aq.decision);
                                    let _ = reply.send(Ok(aq));
                                }
                                Err(e) => {
                                    let _ = reply.send(Err(e));
                                }
                            }
                        }
                        Command::RecomputeDone(res) => {
                            in_flight = false;
                            w2.recompute_in_flight.store(false, Ordering::SeqCst);
                            engine.finish_recompute(*res);
                        }
                        Command::Stats(reply) => {
                            let _ = reply.send(engine.metrics().to_json());
                        }
                        Command::Shutdown => break,
                    }
                }
                // Dropping the job sender unblocks the recompute worker's
                // recv so it can exit.
                drop(job_tx);
                engine.stop();
                r2.store(false, Ordering::SeqCst);
            })
            .expect("spawn engine thread");

        Self {
            queue,
            worker: Some(worker),
            recompute: Some(recompute),
            running,
            reader,
            policy,
            wire,
            gate,
        }
    }

    /// Spawn with a command queue of `queue_capacity` and default
    /// staleness policy (compatibility wrapper over [`Self::spawn_with`]).
    pub fn spawn(engine: Engine, queue_capacity: usize, policy: OverflowPolicy) -> Self {
        Self::spawn_with(
            engine,
            &ServeOptions::new().queue_capacity(queue_capacity).overflow(policy),
        )
    }

    /// Enqueue a graph operation (blocking backpressure per the overflow
    /// policy — library producers that *want* to wait).
    pub fn ingest(&self, op: EdgeOp) -> Result<()> {
        self.queue.push(Command::Op(op))
    }

    /// Enqueue a whole batch atomically: one queue slot, registered in
    /// one engine call — concurrent producers can never interleave into
    /// the middle of it, and a full queue rejects it as a unit.
    pub fn ingest_batch(&self, ops: Vec<EdgeOp>) -> Result<()> {
        self.queue.push(Command::Batch(ops))
    }

    /// Non-blocking ingest for the wire path: a full queue surfaces as
    /// [`Error::Backpressure`] (the `overload` wire code) instead of
    /// stalling the poll worker.
    pub fn try_ingest(&self, op: EdgeOp) -> Result<()> {
        self.queue.try_push(Command::Op(op))
    }

    /// Non-blocking batch ingest (see [`Self::try_ingest`]).
    pub fn try_ingest_batch(&self, ops: Vec<EdgeOp>) -> Result<()> {
        self.queue.try_push(Command::Batch(ops))
    }

    /// Serve a query synchronously (applies pending updates and may
    /// recompute inline on the engine thread).
    pub fn query(&self) -> Result<QueryResult> {
        let (tx, rx) = channel();
        self.queue.push(Command::Query(tx))?;
        rx.recv().map_err(|_| Error::Engine("engine thread gone".into()))?
    }

    /// Enqueue a wire query without blocking: the engine answers from the
    /// published snapshot and schedules any recompute off-thread. Returns
    /// the receiver the response will arrive on; a full queue surfaces as
    /// [`Error::Backpressure`] so the caller can degrade.
    pub fn query_wire(&self) -> Result<Receiver<Result<AsyncQueryResult>>> {
        let (tx, rx) = channel();
        self.queue.try_push(Command::WireQuery(tx))?;
        Ok(rx)
    }

    /// Live engine metrics snapshot (round-trips through the command
    /// queue; see [`Self::reader`] for the off-queue variant).
    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.queue.push(Command::Stats(tx))?;
        rx.recv().map_err(|_| Error::Engine("engine thread gone".into()))
    }

    /// The read plane: a cloneable handle answering `top`/`rank`/`stats`
    /// from the latest published snapshot without entering the queue.
    pub fn reader(&self) -> SnapshotReader {
        self.reader.clone()
    }

    /// The staleness policy wire queries are decided under.
    pub fn policy(&self) -> &StalenessPolicy {
        &self.policy
    }

    /// Live wire front-end counters.
    pub fn wire_stats(&self) -> &WireStats {
        &self.wire
    }

    /// Test hook: park the recompute worker before its next job (readers
    /// and writers must stay live while a recompute is pinned).
    pub fn hold_recompute(&self) {
        self.gate.hold();
    }

    /// Release a held recompute worker.
    pub fn release_recompute(&self) {
        self.gate.release();
    }

    /// The `server` section of the wire `stats` op: front-end gauges,
    /// queue occupancy/shedding, and the active staleness policy with the
    /// last escalation decision.
    pub fn server_stats_json(&self) -> Json {
        let qs = self.queue.stats();
        let last = match self.wire.last_decision() {
            Some(a) => Json::Str(a.to_string()),
            None => Json::Null,
        };
        Json::obj(vec![
            ("protocol_version", Json::Num(WIRE_PROTOCOL_VERSION as f64)),
            ("connections", Json::Num(self.wire.connections.load(Ordering::SeqCst) as f64)),
            ("workers", Json::Num(self.wire.workers.load(Ordering::SeqCst) as f64)),
            ("queue_len", Json::Num(self.queue.len() as f64)),
            ("queue_capacity", Json::Num(self.queue.capacity() as f64)),
            ("queue_pushed", Json::Num(qs.pushed as f64)),
            ("queue_popped", Json::Num(qs.popped as f64)),
            ("queue_dropped", Json::Num(qs.dropped as f64)),
            ("queue_rejected", Json::Num(qs.rejected as f64)),
            ("overloads", Json::Num(self.wire.overloads.load(Ordering::SeqCst) as f64)),
            (
                "recompute_in_flight",
                Json::Bool(self.wire.recompute_in_flight.load(Ordering::SeqCst)),
            ),
            ("policy", self.policy.to_json()),
            ("last_decision", last),
        ])
    }

    /// True while the engine thread is alive.
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Ask the engine thread to stop without joining it (used by the TCP
    /// front end, which holds the handle in an `Arc`; the final drop
    /// joins).
    pub fn request_shutdown(&self) {
        let _ = self.queue.force_push(Command::Shutdown);
        self.queue.close();
        self.gate.release();
    }

    /// Stop the engine and join both threads.
    pub fn shutdown(mut self) {
        self.request_shutdown();
        self.join();
    }

    fn join(&mut self) {
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        if let Some(h) = self.recompute.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.request_shutdown();
        self.join();
    }
}

/// Upper bound on ops per wire `batch` request. A batch occupies ONE
/// engine-queue slot regardless of size, so without a cap a fast writer
/// pipelining huge batches could buffer `queue_capacity x batch_size`
/// ops before backpressure engages; with the cap, queued memory stays
/// bounded. Clients with more ops send more batch lines.
pub const MAX_WIRE_BATCH_OPS: usize = 4096;

/// Upper bound on one request line's bytes, enforced WHILE buffering, so
/// an oversized line is rejected after accumulating at most this much —
/// not parsed, not fully read. Without it the batch-op cap is hollow: a
/// multi-gigabyte `batch` line would be buffered and JSON-parsed before
/// the op-count check ran. Sized so a full `MAX_WIRE_BATCH_OPS` batch of
/// maximal ops fits comfortably.
pub const MAX_WIRE_LINE_BYTES: usize = 1 << 20;

/// Per-connection token-bucket limiter over the read-path ops
/// (`top`/`rank`/`stats` — the requests that bypass the engine queue and
/// therefore see no backpressure). `rate` is ops/sec with a one-second
/// burst allowance; `rate <= 0` disables limiting.
pub struct RateLimiter {
    rate: f64,
    tokens: f64,
    last: Instant,
}

impl RateLimiter {
    /// A limiter admitting `rate` reads/sec (0 = unlimited).
    pub fn new(rate: f64) -> Self {
        Self { rate, tokens: rate.max(1.0), last: Instant::now() }
    }

    /// Take one token; false means the caller should reject the request.
    pub fn admit(&mut self) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let now = Instant::now();
        let refill = now.duration_since(self.last).as_secs_f64() * self.rate;
        self.tokens = (self.tokens + refill).min(self.rate.max(1.0));
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

// ---------------------------------------------------------------------------
// Wire protocol v1
// ---------------------------------------------------------------------------

/// A v1 success response: `{"v":1,"ok":true,…fields}`.
fn ok_response(fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![
        ("v", Json::Num(WIRE_PROTOCOL_VERSION as f64)),
        ("ok", Json::Bool(true)),
    ];
    all.extend(fields);
    Json::obj(all)
}

/// A v1 error response:
/// `{"v":1,"ok":false,"error":{"code":"…","msg":"…"}}`. The codes are
/// stable protocol surface: `rate_limited`, `conn_cap`, `bad_op`,
/// `overload`, `shutdown`.
pub fn err_response(code: &str, msg: &str) -> Json {
    err_response_with(code, msg, Vec::new())
}

/// [`err_response`] carrying extra top-level fields (e.g. the degraded
/// snapshot answer alongside an `overload` error).
fn err_response_with(code: &str, msg: &str, extra: Vec<(&str, Json)>) -> Json {
    let mut all = vec![
        ("v", Json::Num(WIRE_PROTOCOL_VERSION as f64)),
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::Str(code.into())),
                ("msg", Json::Str(msg.into())),
            ]),
        ),
    ];
    all.extend(extra);
    Json::obj(all)
}

/// Map an internal error onto its stable wire code.
fn error_code(e: &Error) -> &'static str {
    match e {
        Error::Backpressure(_) => "overload",
        Error::Engine(msg)
            if msg.contains("closed") || msg.contains("stopped") || msg.contains("gone") =>
        {
            "shutdown"
        }
        _ => "bad_op",
    }
}

fn error_json(e: &Error) -> Json {
    err_response(error_code(e), &e.to_string())
}

/// Render a top-k ranking as the wire's `[[id,score],…]` array.
fn top_pairs(pairs: Vec<(u64, f64)>) -> Json {
    Json::Arr(
        pairs
            .into_iter()
            .map(|(id, score)| Json::Arr(vec![Json::Num(id as f64), Json::Num(score)]))
            .collect(),
    )
}

/// The off-queue read ops — the one classification both the rate-limit
/// guard and the dispatch below consult, so a new read op cannot be
/// added to one and silently bypass the other.
fn is_read_op(op: &str) -> bool {
    matches!(op, "top" | "rank" | "stats")
}

/// Parse one write op object (shared by the single-op requests and the
/// elements of a `batch`).
fn parse_write_op(op: &str, req: &Json) -> std::result::Result<EdgeOp, String> {
    match op {
        "add" | "remove" => {
            match (req.get("src").and_then(Json::as_u64), req.get("dst").and_then(Json::as_u64)) {
                (Some(s), Some(d)) => {
                    Ok(if op == "add" { EdgeOp::add(s, d) } else { EdgeOp::remove(s, d) })
                }
                _ => Err("add/remove need numeric src and dst".into()),
            }
        }
        "add_vertex" | "remove_vertex" => match req.get("id").and_then(Json::as_u64) {
            Some(id) => Ok(if op == "add_vertex" {
                EdgeOp::AddVertex(id)
            } else {
                EdgeOp::RemoveVertex(id)
            }),
            None => Err("add_vertex/remove_vertex need a numeric id".into()),
        },
        other => Err(format!("unknown write op {other:?}")),
    }
}

/// Outcome of dispatching one request line: either a finished response
/// (plus whether it asked the server to shut down), or a wire query in
/// flight whose response will arrive on the receiver.
enum Reply {
    Done(Json, bool),
    Pending(Receiver<Result<AsyncQueryResult>>, usize),
}

/// Render a completed wire query. The answer always serves the published
/// snapshot; `action` reports the staleness decision and `scheduled`
/// whether a recompute was handed off-thread.
fn wire_query_response(res: Result<AsyncQueryResult>, k: usize) -> Json {
    match res {
        Ok(aq) => {
            let snap = &aq.snapshot;
            ok_response(vec![
                ("query_id", Json::Num(aq.query_id as f64)),
                ("version", Json::Num(snap.version as f64)),
                ("action", Json::Str(aq.decision.to_string())),
                ("scheduled", Json::Bool(aq.scheduled)),
                ("age_secs", Json::Num(snap.age_secs())),
                ("top", top_pairs(snap.top(k))),
            ])
        }
        Err(e) => error_json(&e),
    }
}

/// JSON line protocol (v1): one request object per line, one response per
/// line. Responses carry `"v":1`; errors are
/// `{"error":{"code":…,"msg":…}}`.
///
/// Write-path requests (non-blocking; a full queue answers `overload`):
/// * `{"op":"add","src":1,"dst":2}`      → `{"v":1,"ok":true}`
/// * `{"op":"remove","src":1,"dst":2}`   → `{"v":1,"ok":true}`
/// * `{"op":"add_vertex","id":7}`        → `{"v":1,"ok":true}`
/// * `{"op":"remove_vertex","id":7}`     → `{"v":1,"ok":true}`
/// * `{"op":"batch","ops":[…]}`          → `{"v":1,"ok":true,"registered":N}`
///   — applied atomically: every element is validated first and one
///   malformed (or cap-exceeding, see [`MAX_WIRE_BATCH_OPS`]) element
///   rejects the whole batch with nothing registered.
/// * `{"op":"query","top":10}` → `{"v":1,"ok":true,"action":…,
///   "scheduled":…,"top":[[id,score],…]}` — served from the published
///   snapshot; any recompute the staleness policy demands runs
///   off-thread and publishes later. Under queue pressure the response
///   is an `overload` error that still carries the (stale but valid)
///   snapshot answer.
/// * `{"op":"shutdown"}`                 → `{"v":1,"ok":true}` and closes.
///
/// Read-path requests (served off the published snapshot, never queued;
/// subject to the per-connection `--rate-limit`):
/// * `{"op":"top","k":10}`  → `{"v":1,"ok":true,"version":…,"top":…}`
/// * `{"op":"rank","id":7}` → `{"v":1,"ok":true,"version":…,"rank":…}`
/// * `{"op":"stats"}`       → `{"v":1,"ok":true,"stats":{"serving":…,
///   "ingest":…,"engine":…,"server":…}}`
pub fn handle_request(handle: &ServerHandle, line: &str) -> (Json, bool) {
    handle_request_limited(handle, line, None)
}

/// [`handle_request`] with an optional per-connection read limiter (what
/// the poll workers use; `None` = unlimited). Blocks on an in-flight
/// wire query — the readiness loop itself uses [`dispatch`] and polls.
pub fn handle_request_limited(
    handle: &ServerHandle,
    line: &str,
    mut limiter: Option<&mut RateLimiter>,
) -> (Json, bool) {
    let mut off = RateLimiter::new(0.0);
    let l = limiter.as_deref_mut().unwrap_or(&mut off);
    match dispatch(handle, line, l) {
        Reply::Done(resp, stop) => (resp, stop),
        Reply::Pending(rx, k) => {
            let res =
                rx.recv().unwrap_or_else(|_| Err(Error::Engine("engine thread gone".into())));
            (wire_query_response(res, k), false)
        }
    }
}

/// Dispatch one request line without ever blocking: writes go through
/// `try_push`, queries return [`Reply::Pending`], reads hit the snapshot.
fn dispatch(handle: &ServerHandle, line: &str, limiter: &mut RateLimiter) -> Reply {
    let bad = |msg: String| Reply::Done(err_response("bad_op", &msg), false);
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return bad(e.to_string()),
    };
    // Version negotiation: absent = v1 (legacy clients), present must
    // match.
    if let Some(v) = req.get("v") {
        if v.as_u64() != Some(WIRE_PROTOCOL_VERSION) {
            return bad(format!(
                "unsupported protocol version {}; this server speaks v{WIRE_PROTOCOL_VERSION}",
                v.to_string_compact()
            ));
        }
    }
    let op = req.get("op").and_then(Json::as_str).unwrap_or("");
    if is_read_op(op) && !limiter.admit() {
        return Reply::Done(err_response("rate_limited", "read rate limit exceeded"), false);
    }
    // Count overloads where they surface, not at every error site.
    let wire_err = |e: Error| {
        if matches!(e, Error::Backpressure(_)) {
            handle.wire.overloads.fetch_add(1, Ordering::SeqCst);
        }
        Reply::Done(error_json(&e), false)
    };
    match op {
        "add" | "remove" | "add_vertex" | "remove_vertex" => match parse_write_op(op, &req) {
            Ok(e) => match handle.try_ingest(e) {
                Ok(()) => Reply::Done(ok_response(Vec::new()), false),
                Err(e) => wire_err(e),
            },
            Err(msg) => bad(msg),
        },
        "batch" => {
            let items = match req.get("ops").and_then(Json::as_arr) {
                Some(items) => items,
                None => return bad("batch needs an ops array".into()),
            };
            if items.len() > MAX_WIRE_BATCH_OPS {
                return bad(format!(
                    "batch of {} ops exceeds the {MAX_WIRE_BATCH_OPS}-op cap; split it",
                    items.len()
                ));
            }
            // Validate everything before registering anything: a batch is
            // all-or-nothing.
            let mut ops = Vec::with_capacity(items.len());
            for (i, item) in items.iter().enumerate() {
                let kind = item.get("op").and_then(Json::as_str).unwrap_or("");
                match parse_write_op(kind, item) {
                    Ok(e) => ops.push(e),
                    Err(msg) => return bad(format!("batch op {i}: {msg}; nothing registered")),
                }
            }
            let n = ops.len();
            match handle.try_ingest_batch(ops) {
                Ok(()) => Reply::Done(
                    ok_response(vec![("registered", Json::Num(n as f64))]),
                    false,
                ),
                Err(e) => wire_err(e),
            }
        }
        "query" => {
            let k = req.get("top").and_then(Json::as_u64).unwrap_or(10) as usize;
            match handle.query_wire() {
                Ok(rx) => Reply::Pending(rx, k),
                Err(Error::Backpressure(_)) => {
                    handle.wire.overloads.fetch_add(1, Ordering::SeqCst);
                    // Degrade instead of queueing: answer from the
                    // published snapshot, flagged as overload. The reply
                    // is stale but internally consistent.
                    let snap = handle.reader.latest_for(ReadKind::Top);
                    Reply::Done(
                        err_response_with(
                            "overload",
                            "engine queue at capacity; serving the published snapshot",
                            vec![
                                ("version", Json::Num(snap.version as f64)),
                                ("query_id", Json::Num(snap.query_id as f64)),
                                ("action", Json::Str(snap.action.to_string())),
                                ("age_secs", Json::Num(snap.age_secs())),
                                ("top", top_pairs(snap.top(k))),
                            ],
                        ),
                        false,
                    )
                }
                Err(e) => wire_err(e),
            }
        }
        // Read-path fast path: answered from the published snapshot.
        "top" => {
            let k = req
                .get("k")
                .or_else(|| req.get("top"))
                .and_then(Json::as_u64)
                .unwrap_or(10) as usize;
            let snap = handle.reader.latest_for(ReadKind::Top);
            Reply::Done(
                ok_response(vec![
                    ("version", Json::Num(snap.version as f64)),
                    ("query_id", Json::Num(snap.query_id as f64)),
                    ("action", Json::Str(snap.action.to_string())),
                    ("top", top_pairs(snap.top(k))),
                ]),
                false,
            )
        }
        "rank" => {
            let id = match req.get("id").and_then(Json::as_u64) {
                Some(id) => id,
                None => return bad("rank needs a numeric id".into()),
            };
            let snap = handle.reader.latest_for(ReadKind::Rank);
            let rank = snap.rank_of(id).map(Json::Num).unwrap_or(Json::Null);
            Reply::Done(
                ok_response(vec![
                    ("version", Json::Num(snap.version as f64)),
                    ("id", Json::Num(id as f64)),
                    ("rank", rank),
                ]),
                false,
            )
        }
        "stats" => {
            let stats = match handle.reader.stats_json() {
                Json::Obj(mut fields) => {
                    fields.insert("server".into(), handle.server_stats_json());
                    Json::Obj(fields)
                }
                other => other,
            };
            Reply::Done(ok_response(vec![("stats", stats)]), false)
        }
        "shutdown" => Reply::Done(ok_response(Vec::new()), true),
        other => bad(format!("unknown op {other:?}")),
    }
}

// ---------------------------------------------------------------------------
// The readiness loop
// ---------------------------------------------------------------------------

/// Tuning knobs for the server: queue/policy knobs consumed by
/// [`ServerHandle::spawn_with`], front-end knobs by [`serve`]. Fluent
/// builder; construct with [`ServeOptions::new`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    max_connections: usize,
    rate_limit: f64,
    workers: usize,
    queue_capacity: usize,
    overflow: OverflowPolicy,
    policy: StalenessPolicy,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_connections: 4096,
            rate_limit: 0.0,
            workers: 4,
            queue_capacity: 1 << 16,
            overflow: OverflowPolicy::Block,
            policy: StalenessPolicy::default(),
        }
    }
}

impl ServeOptions {
    /// Defaults: 4096 connections, no rate limit, 4 poll workers, a
    /// 65536-slot `Block` queue, default staleness policy.
    pub fn new() -> Self {
        Self::default()
    }

    /// Simultaneous client connections; excess clients are rejected with
    /// one `conn_cap` error line and closed. Clamped to ≥ 1 so the
    /// server always admits the client that could send `shutdown`.
    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = n.max(1);
        self
    }

    /// Per-connection read-path rate limit in ops/sec (`top`/`rank`/
    /// `stats`; one-second burst allowance). Over-limit requests get a
    /// `rate_limited` error line, the connection stays open. 0 =
    /// unlimited.
    pub fn rate_limit(mut self, r: f64) -> Self {
        self.rate_limit = r;
        self
    }

    /// Poll workers ticking the connections (≥ 1). A small fixed set
    /// serves any number of mostly-idle clients.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Engine command queue slots (≥ 1).
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.queue_capacity = n.max(1);
        self
    }

    /// What a full engine queue does to blocking producers.
    pub fn overflow(mut self, p: OverflowPolicy) -> Self {
        self.overflow = p;
        self
    }

    /// Staleness policy wire queries are decided under.
    pub fn policy(mut self, p: StalenessPolicy) -> Self {
        self.policy = p;
        self
    }
}

/// Serve the line protocol over TCP until a client sends `shutdown`
/// (default [`ServeOptions`]).
pub fn serve_tcp(handle: ServerHandle, addr: &str) -> Result<()> {
    serve_tcp_with(handle, addr, ServeOptions::default())
}

/// [`serve_tcp`] with explicit options.
pub fn serve_tcp_with(handle: ServerHandle, addr: &str, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve(handle, listener, opts)
}

/// One connection owned by a poll worker: the socket plus its read/write
/// buffers and per-connection protocol state. Idle connections cost
/// exactly this struct — no thread.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet consumed as complete lines.
    buf: Vec<u8>,
    /// Response bytes not yet written to the socket.
    out: Vec<u8>,
    limiter: RateLimiter,
    /// An in-flight wire query: no further requests are read until it
    /// answers, so pipelined responses keep request order.
    pending: Option<(Receiver<Result<AsyncQueryResult>>, usize)>,
    /// Close once `out` drains (EOF, protocol violation, or shutdown).
    close_after_flush: bool,
}

/// What one tick did with a connection.
enum Tick {
    /// Bytes moved or a request was dispatched — poll again immediately.
    Progress,
    Idle,
    Close,
}

enum Flush {
    Progress,
    Idle,
    Closed,
}

/// Write as much of `out` as the socket accepts right now.
fn flush_out(c: &mut Conn) -> Flush {
    let mut wrote = 0usize;
    while wrote < c.out.len() {
        match c.stream.write(&c.out[wrote..]) {
            Ok(0) => return Flush::Closed,
            Ok(n) => wrote += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::Interrupted) => break,
            Err(_) => return Flush::Closed,
        }
    }
    if wrote > 0 {
        c.out.drain(..wrote);
        Flush::Progress
    } else {
        Flush::Idle
    }
}

fn queue_line(c: &mut Conn, resp: &Json) {
    c.out.extend_from_slice(resp.to_string_compact().as_bytes());
    c.out.push(b'\n');
}

/// Reject an over-long request line and schedule the connection for
/// close (mid-line there is no way to resync).
fn reject_oversize(c: &mut Conn) {
    queue_line(
        c,
        &err_response("bad_op", &format!("request line exceeds {MAX_WIRE_LINE_BYTES} bytes")),
    );
    c.buf.clear();
    c.close_after_flush = true;
}

/// Advance one connection: flush pending output, complete an in-flight
/// query, read what the socket has, dispatch complete lines, flush
/// again. Never blocks.
fn tick_conn(
    handle: &ServerHandle,
    c: &mut Conn,
    scratch: &mut [u8],
    stop: &AtomicBool,
) -> Tick {
    let mut progressed = false;
    match flush_out(c) {
        Flush::Closed => return Tick::Close,
        Flush::Progress => progressed = true,
        Flush::Idle => {}
    }
    // An in-flight wire query: deliver its answer when ready; until then
    // this connection reads nothing more (natural per-connection flow
    // control, and responses stay in request order).
    if let Some((rx, k)) = c.pending.take() {
        match rx.try_recv() {
            Ok(res) => {
                queue_line(c, &wire_query_response(res, k));
                progressed = true;
            }
            Err(TryRecvError::Empty) => {
                c.pending = Some((rx, k));
                return if progressed { Tick::Progress } else { Tick::Idle };
            }
            Err(TryRecvError::Disconnected) => {
                queue_line(c, &err_response("shutdown", "engine thread gone"));
                c.close_after_flush = true;
            }
        }
    }
    if c.close_after_flush {
        let _ = flush_out(c);
        return if c.out.is_empty() { Tick::Close } else { Tick::Progress };
    }
    match c.stream.read(scratch) {
        Ok(0) => {
            // EOF: the client hung up. Flush whatever is queued, then go.
            if c.out.is_empty() {
                return Tick::Close;
            }
            c.close_after_flush = true;
            return Tick::Progress;
        }
        Ok(n) => {
            c.buf.extend_from_slice(&scratch[..n]);
            progressed = true;
        }
        Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::Interrupted) => {}
        Err(_) => return Tick::Close,
    }
    loop {
        match c.buf.iter().position(|&b| b == b'\n') {
            Some(pos) if pos > MAX_WIRE_LINE_BYTES => {
                reject_oversize(c);
                break;
            }
            None => {
                if c.buf.len() > MAX_WIRE_LINE_BYTES {
                    reject_oversize(c);
                }
                break;
            }
            Some(pos) => {
                let line: Vec<u8> = c.buf.drain(..=pos).collect();
                let text = String::from_utf8_lossy(&line[..pos]);
                let text = text.trim();
                if text.is_empty() {
                    continue;
                }
                progressed = true;
                match dispatch(handle, text, &mut c.limiter) {
                    Reply::Done(resp, shutdown) => {
                        queue_line(c, &resp);
                        if shutdown {
                            c.close_after_flush = true;
                            stop.store(true, Ordering::SeqCst);
                            break;
                        }
                    }
                    Reply::Pending(rx, k) => {
                        c.pending = Some((rx, k));
                        break;
                    }
                }
            }
        }
    }
    match flush_out(c) {
        Flush::Closed => return Tick::Close,
        Flush::Progress => progressed = true,
        Flush::Idle => {}
    }
    if c.close_after_flush && c.out.is_empty() {
        return Tick::Close;
    }
    if progressed {
        Tick::Progress
    } else {
        Tick::Idle
    }
}

/// One poll worker: owns a slice of the connections, ticks each in turn,
/// sleeps briefly only when a full sweep made no progress.
fn poll_worker(
    handle: Arc<ServerHandle>,
    inject: Arc<Mutex<Vec<TcpStream>>>,
    stop: Arc<AtomicBool>,
    rate_limit: f64,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut scratch = vec![0u8; 16 * 1024];
    while !stop.load(Ordering::SeqCst) {
        {
            let mut inj = inject.lock().unwrap();
            for stream in inj.drain(..) {
                conns.push(Conn {
                    stream,
                    buf: Vec::new(),
                    out: Vec::new(),
                    limiter: RateLimiter::new(rate_limit),
                    pending: None,
                    close_after_flush: false,
                });
            }
        }
        let mut progressed = false;
        let mut i = 0;
        while i < conns.len() {
            match tick_conn(&handle, &mut conns[i], &mut scratch, &stop) {
                Tick::Close => {
                    drop(conns.swap_remove(i));
                    handle.wire.connections.fetch_sub(1, Ordering::SeqCst);
                }
                Tick::Progress => {
                    progressed = true;
                    i += 1;
                }
                Tick::Idle => i += 1,
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    // Stopping: flush queued responses best-effort (bounded), then drop.
    for mut c in conns {
        let _ = c.stream.set_nonblocking(false);
        let _ = c.stream.set_write_timeout(Some(Duration::from_millis(200)));
        if !c.out.is_empty() {
            let _ = c.stream.write_all(&c.out);
        }
        handle.wire.connections.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Nonblocking TCP front end over a pre-bound listener (bind to port 0
/// in tests and read `listener.local_addr()` first): the calling thread
/// accepts, `opts.workers` poll threads tick the connections through
/// per-connection buffers. Read ops never enter the engine queue and
/// wire queries never block a worker, so thousands of mostly-idle
/// clients are served by this small fixed thread set even while a
/// recompute runs. Returns once a client sends `shutdown`.
pub fn serve(handle: ServerHandle, listener: TcpListener, opts: ServeOptions) -> Result<()> {
    let local = listener.local_addr()?;
    crate::log_info!("listening on {local}");
    listener.set_nonblocking(true)?;
    let workers = opts.workers.max(1);
    let max_connections = opts.max_connections.max(1);
    let handle = Arc::new(handle);
    handle.wire.workers.store(workers, Ordering::SeqCst);
    let stop = Arc::new(AtomicBool::new(false));
    let mut injects: Vec<Arc<Mutex<Vec<TcpStream>>>> = Vec::with_capacity(workers);
    let mut threads: Vec<JoinHandle<()>> = Vec::with_capacity(workers);
    for w in 0..workers {
        let inject = Arc::new(Mutex::new(Vec::new()));
        injects.push(Arc::clone(&inject));
        let h2 = Arc::clone(&handle);
        let stop2 = Arc::clone(&stop);
        let rate = opts.rate_limit;
        threads.push(
            std::thread::Builder::new()
                .name(format!("veilgraph-poll-{w}"))
                .spawn(move || poll_worker(h2, inject, stop2, rate))
                .expect("spawn poll worker"),
        );
    }
    let mut next = 0usize;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                if handle.wire.connections.load(Ordering::SeqCst) >= max_connections {
                    let mut s = stream;
                    let _ = s.set_nonblocking(false);
                    let _ = s.set_write_timeout(Some(Duration::from_millis(200)));
                    let reject = err_response("conn_cap", "server at connection capacity");
                    let _ = s.write_all(reject.to_string_compact().as_bytes());
                    let _ = s.write_all(b"\n");
                    crate::log_warn!("rejected {peer}: at connection capacity");
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                crate::log_debug!("client {peer}");
                handle.wire.connections.fetch_add(1, Ordering::SeqCst);
                injects[next % workers].lock().unwrap().push(stream);
                next += 1;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                stop.store(true, Ordering::SeqCst);
                for t in threads {
                    let _ = t.join();
                }
                return Err(e.into());
            }
        }
    }
    for t in threads {
        let _ = t.join();
    }
    handle.request_shutdown();
    // Last Arc: join the engine + recompute threads before returning.
    if let Ok(h) = Arc::try_unwrap(handle) {
        h.shutdown();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineBuilder;
    use std::io::{BufRead, BufReader};

    fn handle() -> ServerHandle {
        let edges: Vec<(u64, u64)> = (0..20).map(|i| (i, (i + 1) % 20)).collect();
        let engine = EngineBuilder::new().build_from_edges(edges).unwrap();
        ServerHandle::spawn(engine, 64, OverflowPolicy::Block)
    }

    fn err_code(resp: &Json) -> &str {
        resp.get("error").unwrap().get("code").unwrap().as_str().unwrap()
    }

    fn err_msg(resp: &Json) -> &str {
        resp.get("error").unwrap().get("msg").unwrap().as_str().unwrap()
    }

    #[test]
    fn ingest_then_query_roundtrip() {
        let h = handle();
        h.ingest(EdgeOp::add(0, 10)).unwrap();
        let r = h.query().unwrap();
        assert_eq!(r.query_id, 1);
        assert!(!r.ranks().is_empty());
        h.shutdown();
    }

    #[test]
    fn stats_reflect_served_queries() {
        let h = handle();
        let _ = h.query().unwrap();
        let _ = h.query().unwrap();
        let stats = h.stats().unwrap();
        assert_eq!(
            stats.get("counters").unwrap().get("queries").unwrap().as_u64(),
            Some(2)
        );
        h.shutdown();
    }

    #[test]
    fn concurrent_producers_are_serialized() {
        let h = std::sync::Arc::new(handle());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h2 = std::sync::Arc::clone(&h);
            joins.push(std::thread::spawn(move || {
                for i in 0..25u64 {
                    h2.ingest(EdgeOp::add(100 + t * 100 + i, i % 20)).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let r = h.query().unwrap();
        assert_eq!(r.ids().len(), 20 + 100, "20 ring + 100 new sources");
    }

    #[test]
    fn line_protocol_add_query_stats() {
        let h = handle();
        let (resp, stop) = handle_request(&h, r#"{"op":"add","src":3,"dst":9}"#);
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("v").unwrap().as_u64(), Some(WIRE_PROTOCOL_VERSION));
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":3}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 3);
        // One effective update pending: the policy escalates and the
        // recompute is handed off-thread.
        assert_eq!(resp.get("action").unwrap().as_str(), Some("approximate"));
        assert_eq!(resp.get("scheduled").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        assert!(resp.get("stats").is_some());
        let (_, stop) = handle_request(&h, r#"{"op":"shutdown"}"#);
        assert!(stop);
        h.shutdown();
    }

    #[test]
    fn wire_query_publishes_off_thread() {
        let h = handle();
        let v0 = h.reader().latest().version;
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":5,"dst":12}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":2}"#);
        assert_eq!(resp.get("scheduled").unwrap().as_bool(), Some(true));
        // The recompute publishes asynchronously. The wire reply itself
        // may republish a repeat-last snapshot (the graph moved), so wait
        // specifically for a recompute-published one.
        let reader = h.reader();
        let mut refreshed = false;
        for _ in 0..500 {
            let s = reader.latest();
            if s.version > v0 && s.action != Action::RepeatLast {
                refreshed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(refreshed, "off-thread recompute must publish a fresh snapshot");
        h.shutdown();
    }

    #[test]
    fn line_protocol_vertex_ops() {
        let h = handle();
        let (resp, _) = handle_request(&h, r#"{"op":"add_vertex","id":77}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"remove_vertex","id":3}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let r = h.query().unwrap();
        assert!(r.ids().contains(&77), "added vertex is ranked");
        assert!(r.rank_of(77).is_some());
        // no further mutations ⇒ the next query reuses the snapshot
        assert_eq!(h.query().unwrap().snapshot.version, r.snapshot.version);
        let (resp, _) = handle_request(&h, r#"{"op":"add_vertex"}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err_code(&resp), "bad_op");
        h.shutdown();
    }

    #[test]
    fn line_protocol_top_and_rank_are_off_queue() {
        let h = handle();
        let _ = h.query().unwrap(); // publish a post-update snapshot
        let before = h.reader().read_stats();
        let (resp, _) = handle_request(&h, r#"{"op":"top","k":4}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 4);
        assert!(resp.get("version").unwrap().as_u64().unwrap() >= 1);
        let (resp, _) = handle_request(&h, r#"{"op":"rank","id":0}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert!(resp.get("rank").unwrap().as_f64().is_some());
        let (resp, _) = handle_request(&h, r#"{"op":"rank","id":999999}"#);
        assert_eq!(resp.get("rank"), Some(&Json::Null));
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        let serving = resp.get("stats").unwrap().get("serving").unwrap();
        assert!(serving.get("reads_top").unwrap().as_u64().unwrap() >= 1);
        // The server section rides along with the snapshot stats.
        let server = resp.get("stats").unwrap().get("server").unwrap();
        assert_eq!(server.get("protocol_version").unwrap().as_u64(), Some(1));
        assert!(server.get("queue_capacity").unwrap().as_u64().unwrap() >= 1);
        assert!(server.get("policy").unwrap().get("approx_after_updates").is_some());
        // engine saw zero extra commands: all the ops hit the snapshot
        let after = h.reader().read_stats();
        assert_eq!(after.rank, before.rank + 2);
        let live = h.stats().unwrap();
        let queries = live.get("counters").unwrap().get("queries").unwrap().as_u64();
        assert_eq!(queries, Some(1), "read ops must not round-trip through the engine");
        h.shutdown();
    }

    #[test]
    fn line_protocol_rejects_garbage() {
        let h = handle();
        let (resp, stop) = handle_request(&h, "not json");
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err_code(&resp), "bad_op");
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":1}"#);
        assert_eq!(err_code(&resp), "bad_op");
        let (resp, _) = handle_request(&h, r#"{"op":"fly"}"#);
        assert!(err_msg(&resp).contains("fly"));
        h.shutdown();
    }

    #[test]
    fn versioned_requests_negotiate() {
        let h = handle();
        // Explicit v1 is accepted.
        let (resp, _) = handle_request(&h, r#"{"v":1,"op":"top","k":2}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        // Future versions are refused with a stable code.
        let (resp, _) = handle_request(&h, r#"{"v":2,"op":"top","k":2}"#);
        assert_eq!(err_code(&resp), "bad_op");
        assert!(err_msg(&resp).contains("version"));
        // Non-numeric versions too.
        let (resp, _) = handle_request(&h, r#"{"v":"two","op":"top"}"#);
        assert_eq!(err_code(&resp), "bad_op");
        h.shutdown();
    }

    #[test]
    fn stopped_handle_answers_with_shutdown_code() {
        let h = handle();
        h.request_shutdown();
        // Give the engine thread a moment to drain and exit.
        for _ in 0..200 {
            if !h.is_running() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":1,"dst":2}"#);
        assert_eq!(err_code(&resp), "shutdown");
        let (resp, _) = handle_request(&h, r#"{"op":"query"}"#);
        assert_eq!(err_code(&resp), "shutdown");
    }

    #[test]
    fn serve_options_builder_clamps() {
        let o = ServeOptions::new()
            .max_connections(0)
            .workers(0)
            .queue_capacity(0)
            .rate_limit(2.5)
            .overflow(OverflowPolicy::Reject);
        assert_eq!(o.max_connections, 1);
        assert_eq!(o.workers, 1);
        assert_eq!(o.queue_capacity, 1);
        assert_eq!(o.rate_limit, 2.5);
        assert_eq!(o.overflow, OverflowPolicy::Reject);
        let d = ServeOptions::default();
        assert_eq!(d.max_connections, 4096);
        assert_eq!(d.workers, 4);
    }

    #[test]
    fn line_protocol_batch_registers_all_ops_in_one_request() {
        let h = handle();
        let line = r#"{"op":"batch","ops":[
            {"op":"add","src":100,"dst":0},
            {"op":"add","src":101,"dst":1},
            {"op":"add_vertex","id":102},
            {"op":"remove","src":0,"dst":1}
        ]}"#
        .replace('\n', "");
        let (resp, stop) = handle_request(&h, &line);
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("registered").unwrap().as_u64(), Some(4));
        let r = h.query().unwrap();
        assert!(r.ids().contains(&100) && r.ids().contains(&101) && r.ids().contains(&102));
        let g = h.query().unwrap();
        assert!(g.rank_of(102).is_some());
        h.shutdown();
    }

    #[test]
    fn line_protocol_batch_is_all_or_nothing() {
        let h = handle();
        // Second element is malformed: nothing from the batch registers.
        let line = r#"{"op":"batch","ops":[{"op":"add","src":30,"dst":0},{"op":"add","src":31}]}"#;
        let (resp, _) = handle_request(&h, line);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let err = err_msg(&resp);
        assert!(err.contains("batch op 1"), "error names the bad element: {err}");
        let r = h.query().unwrap();
        assert!(!r.ids().contains(&30), "no partial registration");
        // Non-array ops and bare batches fail cleanly too.
        let (resp, _) = handle_request(&h, r#"{"op":"batch"}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        h.shutdown();
    }

    #[test]
    fn line_protocol_batch_enforces_the_size_cap() {
        let h = handle();
        let ops: Vec<String> = (0..MAX_WIRE_BATCH_OPS as u64 + 1)
            .map(|i| format!(r#"{{"op":"add","src":{},"dst":{}}}"#, 10_000 + i, i % 20))
            .collect();
        let line = format!(r#"{{"op":"batch","ops":[{}]}}"#, ops.join(","));
        let (resp, _) = handle_request(&h, &line);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let err = err_msg(&resp);
        assert!(err.contains("cap"), "rejection names the cap: {err}");
        let r = h.query().unwrap();
        assert!(!r.ids().contains(&10_000), "nothing registered past the cap");
        h.shutdown();
    }

    #[test]
    fn oversized_request_line_is_rejected_and_dropped() {
        let h = handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions::new().workers(1);
        let server = std::thread::spawn(move || serve(h, listener, opts).unwrap());
        let mut client = TcpStream::connect(addr).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let huge = vec![b'x'; MAX_WIRE_LINE_BYTES + 64];
        client.write_all(&huge).unwrap();
        let mut r = BufReader::new(client.try_clone().unwrap());
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err_code(&j), "bad_op");
        assert!(err_msg(&j).contains("bytes"));
        let mut rest = String::new();
        assert_eq!(r.read_line(&mut rest).unwrap(), 0, "oversized client is dropped");
        // A fresh client can still stop the server: the violation cost
        // one connection, not the process.
        let mut c2 = TcpStream::connect(addr).unwrap();
        c2.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        server.join().unwrap();
    }

    #[test]
    fn rate_limiter_admits_burst_then_rejects() {
        let mut l = RateLimiter::new(3.0);
        let admitted = (0..50).filter(|_| l.admit()).count();
        assert!(admitted >= 3, "burst capacity admits the first requests");
        assert!(admitted < 50, "sustained flood is limited");
        // rate 0 = off
        let mut off = RateLimiter::new(0.0);
        assert!((0..1000).all(|_| off.admit()));
    }

    #[test]
    fn tcp_server_end_to_end() {
        let h = handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = ServeOptions::new().workers(2);
        let server = std::thread::spawn(move || serve(h, listener, opts).unwrap());
        let mut client = TcpStream::connect(addr).unwrap();
        let script = concat!(
            "{\"op\":\"add\",\"src\":1,\"dst\":15}\n",
            "{\"op\":\"query\",\"top\":2}\n",
            "{\"op\":\"shutdown\"}\n"
        );
        client.write_all(script.as_bytes()).unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(3).map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 3);
        let q = Json::parse(&lines[1]).unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(q.get("v").unwrap().as_u64(), Some(1));
        assert_eq!(q.get("top").unwrap().as_arr().unwrap().len(), 2);
        server.join().unwrap();
    }
}
