//! Threaded query server: the “GraphBolt module” of Fig. 2, read/write
//! split.
//!
//! The *write path*: producers (stream sources, clients) talk to a
//! single engine thread through a bounded command queue (backpressure
//! per [`crate::stream::backpressure`]); mutations and
//! recompute-triggering queries serialize there. Writes travel batched:
//! [`ServerHandle::ingest_batch`] (and the line protocol's `batch` op)
//! registers a whole pre-validated op vector in one queue slot, so a
//! client pays one round-trip per batch instead of one per edge, and the
//! batch is all-or-nothing with respect to other producers. The *read
//! path*: every [`ServerHandle`] carries a
//! [`SnapshotReader`](crate::coordinator::serving::SnapshotReader) onto
//! the engine's published [`RankSnapshot`]s, so `top` / `rank` / `stats`
//! requests are answered without entering the command queue — a slow
//! recompute in progress never blocks a read. Because those reads see no
//! queue backpressure, [`ServeOptions::rate_limit`] can cap them per
//! connection ([`RateLimiter`], token bucket).
//!
//! A JSON line protocol over TCP is layered on top for out-of-process
//! clients (`veilgraph serve`); [`serve_listener`] runs an acceptor plus
//! one thread per connection (capped), so any number of clients are
//! served simultaneously.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::engine::{Engine, QueryResult};
use crate::coordinator::serving::{ReadKind, SnapshotReader};
use crate::error::{Error, Result};
use crate::stream::backpressure::{BoundedQueue, OverflowPolicy};
use crate::stream::event::EdgeOp;
use crate::util::json::Json;

/// Commands accepted by the engine thread (the write path).
enum Command {
    Op(EdgeOp),
    /// A pre-validated batch: registered contiguously (one queue slot,
    /// one engine call), so it is all-or-nothing with respect to other
    /// producers.
    Batch(Vec<EdgeOp>),
    Query(Sender<Result<QueryResult>>),
    Stats(Sender<Json>),
    Shutdown,
}

/// Handle to a running engine thread plus the lock-free read path.
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Command>>,
    worker: Option<JoinHandle<()>>,
    running: Arc<AtomicBool>,
    reader: SnapshotReader,
}

impl ServerHandle {
    /// Spawn the engine thread with a command queue of `queue_capacity`.
    pub fn spawn(mut engine: Engine, queue_capacity: usize, policy: OverflowPolicy) -> Self {
        let reader = engine.reader();
        let queue = Arc::new(BoundedQueue::new(queue_capacity, policy));
        let running = Arc::new(AtomicBool::new(true));
        let q2 = Arc::clone(&queue);
        let r2 = Arc::clone(&running);
        let worker = std::thread::Builder::new()
            .name("veilgraph-engine".into())
            .spawn(move || {
                while let Some(cmd) = q2.pop() {
                    match cmd {
                        Command::Op(op) => engine.ingest(op),
                        Command::Batch(ops) => engine.ingest_batch(ops),
                        Command::Query(reply) => {
                            let _ = reply.send(engine.query());
                        }
                        Command::Stats(reply) => {
                            let _ = reply.send(engine.metrics().to_json());
                        }
                        Command::Shutdown => break,
                    }
                }
                engine.stop();
                r2.store(false, Ordering::SeqCst);
            })
            .expect("spawn engine thread");
        Self { queue, worker: Some(worker), running, reader }
    }

    /// Enqueue a graph operation (non-blocking result; backpressure policy
    /// applies).
    pub fn ingest(&self, op: EdgeOp) -> Result<()> {
        self.queue.push(Command::Op(op))
    }

    /// Enqueue a whole batch atomically: one queue slot, registered in
    /// one engine call — concurrent producers can never interleave into
    /// the middle of it, and a full queue rejects it as a unit.
    pub fn ingest_batch(&self, ops: Vec<EdgeOp>) -> Result<()> {
        self.queue.push(Command::Batch(ops))
    }

    /// Serve a query synchronously (write path: applies pending updates
    /// and may recompute).
    pub fn query(&self) -> Result<QueryResult> {
        let (tx, rx) = channel();
        self.queue.push(Command::Query(tx))?;
        rx.recv().map_err(|_| Error::Engine("engine thread gone".into()))?
    }

    /// Live engine metrics snapshot (write path: round-trips through the
    /// command queue; see [`Self::reader`] for the off-queue variant).
    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.queue.push(Command::Stats(tx))?;
        rx.recv().map_err(|_| Error::Engine("engine thread gone".into()))
    }

    /// The read path: a cloneable handle answering `top`/`rank`/`stats`
    /// from the latest published snapshot without entering the queue.
    pub fn reader(&self) -> SnapshotReader {
        self.reader.clone()
    }

    /// True while the engine thread is alive.
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Ask the engine thread to stop without joining it (used by the
    /// concurrent TCP front end, which holds the handle in an `Arc`; the
    /// final drop joins).
    pub fn request_shutdown(&self) {
        let _ = self.queue.push(Command::Shutdown);
        self.queue.close();
    }

    /// Stop the engine and join the thread.
    pub fn shutdown(mut self) {
        self.request_shutdown();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.request_shutdown();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// Upper bound on ops per wire `batch` request. A batch occupies ONE
/// engine-queue slot regardless of size, so without a cap a fast writer
/// pipelining huge batches could buffer `queue_capacity x batch_size`
/// ops before backpressure engages; with the cap, queued memory stays
/// bounded. Clients with more ops send more batch lines.
pub const MAX_WIRE_BATCH_OPS: usize = 4096;

/// Upper bound on one request line's bytes, enforced WHILE reading (a
/// `Read::take` per read call), so an oversized line is rejected after
/// buffering at most this much — not parsed, not fully read. Without
/// it the batch-op cap is hollow: a multi-gigabyte `batch` line would
/// be buffered and JSON-parsed before the op-count check ran. Sized so
/// a full `MAX_WIRE_BATCH_OPS` batch of maximal ops fits comfortably.
pub const MAX_WIRE_LINE_BYTES: usize = 1 << 20;

/// Per-connection token-bucket limiter over the read-path ops
/// (`top`/`rank`/`stats` — the requests that bypass the engine queue and
/// therefore see no backpressure). `rate` is ops/sec with a one-second
/// burst allowance; `rate <= 0` disables limiting.
pub struct RateLimiter {
    rate: f64,
    tokens: f64,
    last: Instant,
}

impl RateLimiter {
    /// A limiter admitting `rate` reads/sec (0 = unlimited).
    pub fn new(rate: f64) -> Self {
        Self { rate, tokens: rate.max(1.0), last: Instant::now() }
    }

    /// Take one token; false means the caller should reject the request.
    pub fn admit(&mut self) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let now = Instant::now();
        let refill = now.duration_since(self.last).as_secs_f64() * self.rate;
        self.tokens = (self.tokens + refill).min(self.rate.max(1.0));
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// The off-queue read ops — the one classification both the rate-limit
/// guard and the dispatch below consult, so a new read op cannot be
/// added to one and silently bypass the other.
fn is_read_op(op: &str) -> bool {
    matches!(op, "top" | "rank" | "stats")
}

/// Parse one write op object (shared by the single-op requests and the
/// elements of a `batch`).
fn parse_write_op(op: &str, req: &Json) -> std::result::Result<EdgeOp, String> {
    match op {
        "add" | "remove" => {
            match (req.get("src").and_then(Json::as_u64), req.get("dst").and_then(Json::as_u64)) {
                (Some(s), Some(d)) => {
                    Ok(if op == "add" { EdgeOp::add(s, d) } else { EdgeOp::remove(s, d) })
                }
                _ => Err("add/remove need numeric src and dst".into()),
            }
        }
        "add_vertex" | "remove_vertex" => match req.get("id").and_then(Json::as_u64) {
            Some(id) => Ok(if op == "add_vertex" {
                EdgeOp::AddVertex(id)
            } else {
                EdgeOp::RemoveVertex(id)
            }),
            None => Err("add_vertex/remove_vertex need a numeric id".into()),
        },
        other => Err(format!("unknown write op {other:?}")),
    }
}

/// JSON line protocol: one request object per line, one response per line.
///
/// Write-path requests (serialized through the engine queue):
/// * `{"op":"add","src":1,"dst":2}`      → `{"ok":true}`
/// * `{"op":"remove","src":1,"dst":2}`   → `{"ok":true}`
/// * `{"op":"add_vertex","id":7}`        → `{"ok":true}`
/// * `{"op":"remove_vertex","id":7}`     → `{"ok":true}`
/// * `{"op":"batch","ops":[{"op":"add","src":1,"dst":2},…]}`
///   → `{"ok":true,"registered":N}` — applied atomically: every element
///   is validated first and one malformed (or cap-exceeding, see
///   [`MAX_WIRE_BATCH_OPS`]) element rejects the whole batch with
///   nothing registered; the batch occupies one engine-queue slot, so
///   clients pay one round-trip for N edges instead of N.
/// * `{"op":"query","top":10}`           → `{"ok":true,"action":…,"top":[[id,score],…]}`
/// * `{"op":"shutdown"}`                 → `{"ok":true}` and closes.
///
/// Read-path requests (served off the published snapshot, never queued;
/// subject to the per-connection `--rate-limit`):
/// * `{"op":"top","k":10}`     → `{"ok":true,"version":…,"top":[[id,score],…]}`
/// * `{"op":"rank","id":7}`    → `{"ok":true,"version":…,"rank":…}`
/// * `{"op":"stats"}`          → `{"ok":true,"stats":{"serving":…,"engine":…}}`
pub fn handle_request(handle: &ServerHandle, line: &str) -> (Json, bool) {
    handle_request_limited(handle, line, None)
}

/// [`handle_request`] with an optional per-connection read limiter (what
/// [`serve_listener`] uses; `None` = unlimited).
pub fn handle_request_limited(
    handle: &ServerHandle,
    line: &str,
    mut limiter: Option<&mut RateLimiter>,
) -> (Json, bool) {
    let fail = |msg: String| {
        (Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg))]), false)
    };
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return fail(e.to_string()),
    };
    let op = req.get("op").and_then(Json::as_str).unwrap_or("");
    if is_read_op(op) {
        if let Some(l) = limiter.as_deref_mut() {
            if !l.admit() {
                return fail("read rate limit exceeded".into());
            }
        }
    }
    match op {
        "add" | "remove" | "add_vertex" | "remove_vertex" => match parse_write_op(op, &req) {
            Ok(e) => match handle.ingest(e) {
                Ok(()) => (Json::obj(vec![("ok", Json::Bool(true))]), false),
                Err(e) => fail(e.to_string()),
            },
            Err(msg) => fail(msg),
        },
        "batch" => {
            let items = match req.get("ops").and_then(Json::as_arr) {
                Some(items) => items,
                None => return fail("batch needs an ops array".into()),
            };
            if items.len() > MAX_WIRE_BATCH_OPS {
                return fail(format!(
                    "batch of {} ops exceeds the {MAX_WIRE_BATCH_OPS}-op cap; split it",
                    items.len()
                ));
            }
            // Validate everything before registering anything: a batch is
            // all-or-nothing.
            let mut ops = Vec::with_capacity(items.len());
            for (i, item) in items.iter().enumerate() {
                let kind = item.get("op").and_then(Json::as_str).unwrap_or("");
                match parse_write_op(kind, item) {
                    Ok(e) => ops.push(e),
                    Err(msg) => return fail(format!("batch op {i}: {msg}; nothing registered")),
                }
            }
            let n = ops.len();
            match handle.ingest_batch(ops) {
                Ok(()) => (
                    Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("registered", Json::Num(n as f64)),
                    ]),
                    false,
                ),
                Err(e) => fail(e.to_string()),
            }
        }
        "query" => {
            let top = req.get("top").and_then(Json::as_u64).unwrap_or(10) as usize;
            match handle.query() {
                Ok(res) => {
                    let pairs = res
                        .top(top)
                        .into_iter()
                        .map(|(id, score)| {
                            Json::Arr(vec![Json::Num(id as f64), Json::Num(score)])
                        })
                        .collect();
                    (
                        Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("query_id", Json::Num(res.query_id as f64)),
                            ("action", Json::Str(res.action.to_string())),
                            ("elapsed_secs", Json::Num(res.exec.elapsed_secs)),
                            ("summary_vertices", Json::Num(res.exec.summary_vertices as f64)),
                            ("top", Json::Arr(pairs)),
                        ]),
                        false,
                    )
                }
                Err(e) => fail(e.to_string()),
            }
        }
        // Read-path fast path: answered from the published snapshot.
        "top" => {
            let k = req
                .get("k")
                .or_else(|| req.get("top"))
                .and_then(Json::as_u64)
                .unwrap_or(10) as usize;
            let snap = handle.reader.latest_for(ReadKind::Top);
            let pairs = snap
                .top(k)
                .into_iter()
                .map(|(id, score)| Json::Arr(vec![Json::Num(id as f64), Json::Num(score)]))
                .collect();
            (
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("version", Json::Num(snap.version as f64)),
                    ("query_id", Json::Num(snap.query_id as f64)),
                    ("action", Json::Str(snap.action.to_string())),
                    ("top", Json::Arr(pairs)),
                ]),
                false,
            )
        }
        "rank" => {
            let id = match req.get("id").and_then(Json::as_u64) {
                Some(id) => id,
                None => return fail("rank needs a numeric id".into()),
            };
            let snap = handle.reader.latest_for(ReadKind::Rank);
            let rank = snap.rank_of(id).map(Json::Num).unwrap_or(Json::Null);
            (
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("version", Json::Num(snap.version as f64)),
                    ("id", Json::Num(id as f64)),
                    ("rank", rank),
                ]),
                false,
            )
        }
        "stats" => {
            let stats = handle.reader.stats_json();
            (Json::obj(vec![("ok", Json::Bool(true)), ("stats", stats)]), false)
        }
        "shutdown" => (Json::obj(vec![("ok", Json::Bool(true))]), true),
        other => fail(format!("unknown op {other:?}")),
    }
}

/// Tuning knobs for the concurrent TCP front end.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Simultaneous client connections; excess clients are rejected with
    /// one error line and closed. Clamped to ≥ 1 so the server always
    /// admits the client that could send `shutdown`.
    pub max_connections: usize,
    /// Per-connection read-path rate limit in ops/sec (`top`/`rank`/
    /// `stats`; one-second burst allowance). Over-limit requests get an
    /// error line, the connection stays open. 0 = unlimited.
    pub rate_limit: f64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { max_connections: 64, rate_limit: 0.0 }
    }
}

/// Serve the line protocol over TCP until a client sends `shutdown`
/// (default [`ServeOptions`]).
pub fn serve_tcp(handle: ServerHandle, addr: &str) -> Result<()> {
    serve_tcp_with(handle, addr, ServeOptions::default())
}

/// [`serve_tcp`] with explicit options.
pub fn serve_tcp_with(handle: ServerHandle, addr: &str, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_listener(handle, listener, opts)
}

/// Concurrent TCP front end over a pre-bound listener (bind to port 0 in
/// tests and read `listener.local_addr()` first): an acceptor thread plus
/// one thread per connection, capped at `opts.max_connections`. Read-only
/// ops never enter the engine queue, so clients issuing `top`/`rank`/
/// `stats` are served even while a recompute is in flight for another
/// client. Returns once a client sends `shutdown` and all connection
/// threads have drained.
pub fn serve_listener(
    handle: ServerHandle,
    listener: TcpListener,
    opts: ServeOptions,
) -> Result<()> {
    let local = listener.local_addr()?;
    crate::log_info!("listening on {local}");
    // Self-connect target for waking the acceptor: a wildcard bind
    // (0.0.0.0 / ::) is not a connectable destination everywhere, so
    // route the wake through loopback on the bound port.
    let wake = if local.ip().is_unspecified() {
        std::net::SocketAddr::new(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST), local.port())
    } else {
        local
    };
    let max_connections = opts.max_connections.max(1);
    let handle = Arc::new(handle);
    let stop = Arc::new(AtomicBool::new(false));
    let active = Arc::new(AtomicUsize::new(0));
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        let (stream, peer) = listener.accept()?;
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // Reap finished connection threads so the vec stays bounded.
        conns.retain(|h| !h.is_finished());
        if active.load(Ordering::SeqCst) >= max_connections {
            let mut s = stream;
            let reject = Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str("server at connection capacity".into())),
            ]);
            let _ = s.write_all(reject.to_string_compact().as_bytes());
            let _ = s.write_all(b"\n");
            crate::log_warn!("rejected {peer}: at connection capacity");
            continue;
        }
        active.fetch_add(1, Ordering::SeqCst);
        let h2 = Arc::clone(&handle);
        let stop2 = Arc::clone(&stop);
        let active2 = Arc::clone(&active);
        let t = std::thread::Builder::new()
            .name("veilgraph-conn".into())
            .spawn(move || {
                crate::log_debug!("client {peer}");
                let shutdown = serve_connection(&h2, stream, &stop2, &opts).unwrap_or(false);
                active2.fetch_sub(1, Ordering::SeqCst);
                if shutdown {
                    stop2.store(true, Ordering::SeqCst);
                    // Wake the acceptor blocked in accept().
                    let _ = TcpStream::connect(wake);
                }
            })
            .expect("spawn connection thread");
        conns.push(t);
    }
    for c in conns {
        let _ = c.join();
    }
    // Last drop of the Arc joins the engine thread (ServerHandle::drop).
    drop(handle);
    Ok(())
}

/// Serve one client connection until EOF, a `shutdown` request, or the
/// server-wide stop flag (polled via a read timeout so lingering clients
/// cannot pin a stopping server). Returns whether this client requested
/// shutdown.
fn serve_connection(
    handle: &ServerHandle,
    stream: TcpStream,
    stop: &AtomicBool,
    opts: &ServeOptions,
) -> Result<bool> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut limiter = RateLimiter::new(opts.rate_limit);
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        // Hard-capped read: `take` bounds how much one request line can
        // buffer, so an oversized line is dropped, never parsed.
        let cap = (MAX_WIRE_LINE_BYTES + 1 - line.len().min(MAX_WIRE_LINE_BYTES)) as u64;
        match (&mut reader).take(cap).read_line(&mut line) {
            Ok(0) if line.trim().is_empty() => return Ok(false), // EOF — client hung up
            Ok(n) => {
                if line.len() > MAX_WIRE_LINE_BYTES {
                    let reject = Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        (
                            "error",
                            Json::Str(format!(
                                "request line exceeds {MAX_WIRE_LINE_BYTES} bytes"
                            )),
                        ),
                    ]);
                    writer.write_all(reject.to_string_compact().as_bytes())?;
                    writer.write_all(b"\n")?;
                    return Ok(false); // cannot resync mid-line: drop the client
                }
                if !line.ends_with('\n') && n > 0 {
                    // Cap-bounded partial read of a still-incomplete
                    // line: keep accumulating.
                    continue;
                }
                if !line.trim().is_empty() {
                    let (resp, shutdown) =
                        handle_request_limited(handle, line.trim(), Some(&mut limiter));
                    writer.write_all(resp.to_string_compact().as_bytes())?;
                    writer.write_all(b"\n")?;
                    if shutdown {
                        return Ok(true);
                    }
                }
                if n == 0 {
                    return Ok(false); // EOF after a final unterminated line
                }
                line.clear();
            }
            // Timeout (or interrupt) mid-wait: partial bytes stay in
            // `line`; check the stop flag and keep reading.
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineBuilder;

    fn handle() -> ServerHandle {
        let edges: Vec<(u64, u64)> = (0..20).map(|i| (i, (i + 1) % 20)).collect();
        let engine = EngineBuilder::new().build_from_edges(edges).unwrap();
        ServerHandle::spawn(engine, 64, OverflowPolicy::Block)
    }

    #[test]
    fn ingest_then_query_roundtrip() {
        let h = handle();
        h.ingest(EdgeOp::add(0, 10)).unwrap();
        let r = h.query().unwrap();
        assert_eq!(r.query_id, 1);
        assert!(!r.ranks().is_empty());
        h.shutdown();
    }

    #[test]
    fn stats_reflect_served_queries() {
        let h = handle();
        let _ = h.query().unwrap();
        let _ = h.query().unwrap();
        let stats = h.stats().unwrap();
        assert_eq!(
            stats.get("counters").unwrap().get("queries").unwrap().as_u64(),
            Some(2)
        );
        h.shutdown();
    }

    #[test]
    fn concurrent_producers_are_serialized() {
        let h = std::sync::Arc::new(handle());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h2 = std::sync::Arc::clone(&h);
            joins.push(std::thread::spawn(move || {
                for i in 0..25u64 {
                    h2.ingest(EdgeOp::add(100 + t * 100 + i, i % 20)).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let r = h.query().unwrap();
        assert_eq!(r.ids().len(), 20 + 100, "20 ring + 100 new sources");
    }

    #[test]
    fn line_protocol_add_query_stats() {
        let h = handle();
        let (resp, stop) = handle_request(&h, r#"{"op":"add","src":3,"dst":9}"#);
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"query","top":3}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 3);
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        assert!(resp.get("stats").is_some());
        let (_, stop) = handle_request(&h, r#"{"op":"shutdown"}"#);
        assert!(stop);
        h.shutdown();
    }

    #[test]
    fn line_protocol_vertex_ops() {
        let h = handle();
        let (resp, _) = handle_request(&h, r#"{"op":"add_vertex","id":77}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_request(&h, r#"{"op":"remove_vertex","id":3}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let r = h.query().unwrap();
        assert!(r.ids().contains(&77), "added vertex is ranked");
        assert!(r.rank_of(77).is_some());
        // no further mutations ⇒ the next query reuses the snapshot
        assert_eq!(h.query().unwrap().snapshot.version, r.snapshot.version);
        let (resp, _) = handle_request(&h, r#"{"op":"add_vertex"}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        h.shutdown();
    }

    #[test]
    fn line_protocol_top_and_rank_are_off_queue() {
        let h = handle();
        let _ = h.query().unwrap(); // publish a post-update snapshot
        let before = h.reader().read_stats();
        let (resp, _) = handle_request(&h, r#"{"op":"top","k":4}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("top").unwrap().as_arr().unwrap().len(), 4);
        assert!(resp.get("version").unwrap().as_u64().unwrap() >= 1);
        let (resp, _) = handle_request(&h, r#"{"op":"rank","id":0}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert!(resp.get("rank").unwrap().as_f64().is_some());
        let (resp, _) = handle_request(&h, r#"{"op":"rank","id":999999}"#);
        assert_eq!(resp.get("rank"), Some(&Json::Null));
        let (resp, _) = handle_request(&h, r#"{"op":"stats"}"#);
        let serving = resp.get("stats").unwrap().get("serving").unwrap();
        assert!(serving.get("reads_top").unwrap().as_u64().unwrap() >= 1);
        // engine saw zero extra commands: all three ops hit the snapshot
        let after = h.reader().read_stats();
        assert_eq!(after.rank, before.rank + 2);
        let live = h.stats().unwrap();
        let queries = live.get("counters").unwrap().get("queries").unwrap().as_u64();
        assert_eq!(queries, Some(1), "read ops must not round-trip through the engine");
        h.shutdown();
    }

    #[test]
    fn line_protocol_rejects_garbage() {
        let h = handle();
        let (resp, stop) = handle_request(&h, "not json");
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let (resp, _) = handle_request(&h, r#"{"op":"add","src":1}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let (resp, _) = handle_request(&h, r#"{"op":"fly"}"#);
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("fly"));
        h.shutdown();
    }

    #[test]
    fn line_protocol_batch_registers_all_ops_in_one_request() {
        let h = handle();
        let line = r#"{"op":"batch","ops":[
            {"op":"add","src":100,"dst":0},
            {"op":"add","src":101,"dst":1},
            {"op":"add_vertex","id":102},
            {"op":"remove","src":0,"dst":1}
        ]}"#
        .replace('\n', "");
        let (resp, stop) = handle_request(&h, &line);
        assert!(!stop);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("registered").unwrap().as_u64(), Some(4));
        let r = h.query().unwrap();
        assert!(r.ids().contains(&100) && r.ids().contains(&101) && r.ids().contains(&102));
        let g = h.query().unwrap();
        assert!(g.rank_of(102).is_some());
        h.shutdown();
    }

    #[test]
    fn line_protocol_batch_is_all_or_nothing() {
        let h = handle();
        // Second element is malformed: nothing from the batch registers.
        let line = r#"{"op":"batch","ops":[{"op":"add","src":30,"dst":0},{"op":"add","src":31}]}"#;
        let (resp, _) = handle_request(&h, line);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let err = resp.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("batch op 1"), "error names the bad element: {err}");
        let r = h.query().unwrap();
        assert!(!r.ids().contains(&30), "no partial registration");
        // Non-array ops and bare batches fail cleanly too.
        let (resp, _) = handle_request(&h, r#"{"op":"batch"}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        h.shutdown();
    }

    #[test]
    fn line_protocol_batch_enforces_the_size_cap() {
        let h = handle();
        let ops: Vec<String> = (0..MAX_WIRE_BATCH_OPS as u64 + 1)
            .map(|i| format!(r#"{{"op":"add","src":{},"dst":{}}}"#, 10_000 + i, i % 20))
            .collect();
        let line = format!(r#"{{"op":"batch","ops":[{}]}}"#, ops.join(","));
        let (resp, _) = handle_request(&h, &line);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let err = resp.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("cap"), "rejection names the cap: {err}");
        let r = h.query().unwrap();
        assert!(!r.ids().contains(&10_000), "nothing registered past the cap");
        h.shutdown();
    }

    #[test]
    fn oversized_request_line_is_rejected_and_dropped() {
        use std::io::{BufRead, BufReader, Write};
        let h = handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let stop = AtomicBool::new(false);
            let _ = serve_connection(&h, stream, &stop, &ServeOptions::default());
            h.shutdown();
        });
        let mut client = TcpStream::connect(addr).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let huge = vec![b'x'; MAX_WIRE_LINE_BYTES + 64];
        client.write_all(&huge).unwrap();
        let mut r = BufReader::new(client.try_clone().unwrap());
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("bytes"));
        let mut rest = String::new();
        assert_eq!(r.read_line(&mut rest).unwrap(), 0, "oversized client is dropped");
        server.join().unwrap();
    }

    #[test]
    fn rate_limiter_admits_burst_then_rejects() {
        let mut l = RateLimiter::new(3.0);
        let admitted = (0..50).filter(|_| l.admit()).count();
        assert!(admitted >= 3, "burst capacity admits the first requests");
        assert!(admitted < 50, "sustained flood is limited");
        // rate 0 = off
        let mut off = RateLimiter::new(0.0);
        assert!((0..1000).all(|_| off.admit()));
    }

    #[test]
    fn tcp_server_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        let h = handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let stop = AtomicBool::new(false);
            serve_connection(&h, stream, &stop, &ServeOptions::default()).unwrap();
            h.shutdown();
        });
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .write_all(
                b"{\"op\":\"add\",\"src\":1,\"dst\":15}\n{\"op\":\"query\",\"top\":2}\n{\"op\":\"shutdown\"}\n",
            )
            .unwrap();
        let reader = BufReader::new(client.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().take(3).map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 3);
        let q = Json::parse(&lines[1]).unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true));
        server.join().unwrap();
    }
}
