//! Built-in query-serving policies (§4: “For simple rules, these
//! functions don't need to be programmed, as we supply the implementation
//! with parameters for the simplest rules such as threshold comparisons,
//! fixed values, intervals and change ratios.”)
//!
//! Also implements the paper's motivating SLA idea (§1: “SLAs for graph
//! processing, with different tiers of accuracy and resource
//! efficiency”) as [`SlaTier`].

use crate::coordinator::udf::{Action, ExecStats, QueryContext, UdfSuite};
use crate::error::{Error, Result};
use crate::stream::buffer::UpdateStatistics;
use crate::stream::event::EdgeOp;
use crate::util::json::Json;

/// Always recompute exactly (the ground-truth baseline of §5).
#[derive(Clone, Copy, Debug, Default)]
pub struct AlwaysExact;

impl UdfSuite for AlwaysExact {
    fn on_query(&mut self, _ctx: &QueryContext) -> Action {
        Action::ComputeExact
    }
}

/// Always serve the summarized approximation (the paper's evaluated
/// configuration).
#[derive(Clone, Copy, Debug, Default)]
pub struct AlwaysApproximate;

impl UdfSuite for AlwaysApproximate {
    fn on_query(&mut self, _ctx: &QueryContext) -> Action {
        Action::ComputeApproximate
    }
}

/// Change-ratio thresholds: if the fraction of touched vertices is below
/// `repeat_below`, repeat the last answer; above `exact_above`, recompute
/// exactly; otherwise approximate. (“e.g., repeating the last results if
/// the updates were not deemed significant or performing an exact
/// computation if too much entropy has accumulated” — §7.)
#[derive(Clone, Copy, Debug)]
pub struct ChangeRatioPolicy {
    /// Touched-vertex ratio below which the cached result is fresh enough.
    pub repeat_below: f64,
    /// Touched-vertex ratio above which only an exact recompute will do.
    pub exact_above: f64,
}

impl ChangeRatioPolicy {
    /// Construct; requires `repeat_below <= exact_above`.
    pub fn new(repeat_below: f64, exact_above: f64) -> Self {
        assert!(repeat_below <= exact_above);
        Self { repeat_below, exact_above }
    }
}

impl UdfSuite for ChangeRatioPolicy {
    fn on_query(&mut self, ctx: &QueryContext) -> Action {
        let ratio = ctx.stats.touched_ratio();
        if ratio < self.repeat_below {
            Action::RepeatLast
        } else if ratio > self.exact_above {
            Action::ComputeExact
        } else {
            Action::ComputeApproximate
        }
    }
}

/// Interval policy: exact every `exact_every` queries, approximate in
/// between (bounds error accumulation — the paper's RBO plots show why
/// periodic refresh matters over long streams).
#[derive(Clone, Copy, Debug)]
pub struct PeriodicExactPolicy {
    /// Period of exact refreshes (≥ 1).
    pub exact_every: u64,
}

impl PeriodicExactPolicy {
    /// Construct with period ≥ 1.
    pub fn new(exact_every: u64) -> Self {
        Self { exact_every: exact_every.max(1) }
    }
}

impl UdfSuite for PeriodicExactPolicy {
    fn on_query(&mut self, ctx: &QueryContext) -> Action {
        if ctx.queries_since_exact + 1 >= self.exact_every {
            Action::ComputeExact
        } else {
            Action::ComputeApproximate
        }
    }
}

/// Accuracy/efficiency SLA tiers (§1's motivation, made concrete).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlaTier {
    /// Max accuracy: exact on every query.
    Gold,
    /// Balanced: approximate, exact refresh every `refresh`.
    Silver { refresh: u64 },
    /// Max efficiency: approximate; repeat cached results for tiny
    /// updates (< 0.1 % touched).
    Bronze,
}

/// UDF suite implementing [`SlaTier`].
#[derive(Clone, Copy, Debug)]
pub struct SlaPolicy {
    /// Configured tier.
    pub tier: SlaTier,
}

impl UdfSuite for SlaPolicy {
    fn on_query(&mut self, ctx: &QueryContext) -> Action {
        match self.tier {
            SlaTier::Gold => Action::ComputeExact,
            SlaTier::Silver { refresh } => {
                if ctx.queries_since_exact + 1 >= refresh.max(1) {
                    Action::ComputeExact
                } else {
                    Action::ComputeApproximate
                }
            }
            SlaTier::Bronze => {
                if ctx.stats.touched_ratio() < 0.001 {
                    Action::RepeatLast
                } else {
                    Action::ComputeApproximate
                }
            }
        }
    }
}

/// Staleness-driven serving policy (the GraphGuess-style trigger: decide
/// *when* accumulated error warrants correction, not just how to serve).
///
/// Escalates `RepeatLast → ComputeApproximate → ComputeExact` as the
/// published snapshot's age (in queries served and wall seconds — the
/// engine's snapshot-age gauges) and the effective updates accumulated
/// since the last recompute grow. With zero accumulated updates the
/// cached result is exact for the applied graph, so the policy always
/// repeats it regardless of age.
///
/// Escalation is monotone: growing any staleness signal never de-escalates
/// the action (property-tested), which the constructor guarantees by
/// requiring every approximate threshold ≤ its exact counterpart.
#[derive(Clone, Copy, Debug)]
pub struct StalenessPolicy {
    /// Accumulated effective updates at which repeating stops being
    /// acceptable.
    pub approx_after_updates: u64,
    /// Accumulated effective updates at which only exact will do.
    pub exact_after_updates: u64,
    /// Snapshot age in queries at which repeating stops being acceptable.
    pub approx_after_queries: u64,
    /// Snapshot age in queries at which only exact will do.
    pub exact_after_queries: u64,
    /// Snapshot age in seconds at which repeating stops being acceptable.
    pub approx_after_secs: f64,
    /// Snapshot age in seconds at which only exact will do.
    pub exact_after_secs: f64,
}

impl Default for StalenessPolicy {
    /// Any update makes the cached answer stale enough to approximate;
    /// exact refreshes kick in once 10k effective updates, 64 queries or
    /// 120 s accumulate on one snapshot.
    fn default() -> Self {
        Self::new(1, 10_000, 8, 64, 5.0, 120.0)
    }
}

impl StalenessPolicy {
    /// Construct; every `approx_after_*` must be ≤ its `exact_after_*`
    /// counterpart (this is what makes escalation monotone).
    pub fn new(
        approx_after_updates: u64,
        exact_after_updates: u64,
        approx_after_queries: u64,
        exact_after_queries: u64,
        approx_after_secs: f64,
        exact_after_secs: f64,
    ) -> Self {
        assert!(approx_after_updates <= exact_after_updates);
        assert!(approx_after_queries <= exact_after_queries);
        assert!(approx_after_secs <= exact_after_secs);
        Self {
            approx_after_updates,
            exact_after_updates,
            approx_after_queries,
            exact_after_queries,
            approx_after_secs,
            exact_after_secs,
        }
    }

    /// The pure escalation rule over the three staleness signals
    /// (exposed for property tests).
    pub fn decide(&self, updates: u64, age_queries: u64, age_secs: f64) -> Action {
        if updates == 0 {
            // Nothing accumulated: the cached ranking is exact for the
            // applied graph, whatever its age.
            return Action::RepeatLast;
        }
        if updates >= self.exact_after_updates
            || age_queries >= self.exact_after_queries
            || age_secs >= self.exact_after_secs
        {
            return Action::ComputeExact;
        }
        if updates >= self.approx_after_updates
            || age_queries >= self.approx_after_queries
            || age_secs >= self.approx_after_secs
        {
            return Action::ComputeApproximate;
        }
        Action::RepeatLast
    }

    /// [`Self::decide`], tempered by queue pressure (`queue_len /
    /// queue_capacity` of the engine command queue). Under pressure the
    /// server sheds work by *downgrading* the accuracy ladder rather than
    /// queueing unboundedly: at ≥ 50 % occupancy Exact degrades to
    /// Approximate; at ≥ 100 % everything degrades to RepeatLast (the
    /// published snapshot is served as-is). Staler answers under load is
    /// exactly the accuracy-for-latency trade the paper argues for.
    pub fn decide_under_pressure(
        &self,
        updates: u64,
        age_queries: u64,
        age_secs: f64,
        pressure: f64,
    ) -> Action {
        let base = self.decide(updates, age_queries, age_secs);
        if pressure >= 1.0 {
            Action::RepeatLast
        } else if pressure >= 0.5 && base == Action::ComputeExact {
            Action::ComputeApproximate
        } else {
            base
        }
    }

    /// Parse the CLI spec `repeatlast:AGE:UPD[,approx:AGE:UPD]`.
    ///
    /// Each segment bounds how long its accuracy tier may be served:
    /// `repeatlast:AGE:UPD` repeats the published snapshot until it is
    /// `AGE` seconds old or `UPD` effective updates have accumulated
    /// (these become the approximate thresholds); `approx:AGE:UPD` serves
    /// approximations until the same signals cross the exact thresholds.
    /// Omitting the `approx` segment disables exact escalation. The
    /// query-age thresholds are disabled by specs (wall age and update
    /// volume are the wire-level signals).
    pub fn parse_spec(spec: &str) -> Result<Self> {
        let mut approx: Option<(f64, u64)> = None;
        let mut exact: Option<(f64, u64)> = None;
        for seg in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let parts: Vec<&str> = seg.trim().split(':').collect();
            if parts.len() != 3 {
                return Err(Error::Usage(format!(
                    "bad policy segment {seg:?}; expected name:AGE_SECS:UPDATES"
                )));
            }
            let age: f64 = parts[1]
                .parse()
                .map_err(|_| Error::Usage(format!("bad policy age {:?} in {seg:?}", parts[1])))?;
            let upd: u64 = parts[2].parse().map_err(|_| {
                Error::Usage(format!("bad policy update count {:?} in {seg:?}", parts[2]))
            })?;
            if !age.is_finite() || age < 0.0 {
                return Err(Error::Usage(format!("policy age must be finite and ≥ 0 in {seg:?}")));
            }
            let slot = match parts[0].to_ascii_lowercase().as_str() {
                "repeatlast" | "repeat-last" => &mut approx,
                "approx" | "approximate" => &mut exact,
                other => {
                    return Err(Error::Usage(format!(
                        "unknown policy tier {other:?}; expected repeatlast or approx"
                    )))
                }
            };
            if slot.replace((age, upd)).is_some() {
                return Err(Error::Usage(format!("duplicate policy tier in {spec:?}")));
            }
        }
        let (approx_secs, approx_upd) =
            approx.ok_or_else(|| Error::Usage("policy spec needs a repeatlast segment".into()))?;
        let (exact_secs, exact_upd) = exact.unwrap_or((f64::INFINITY, u64::MAX));
        if approx_secs > exact_secs || approx_upd > exact_upd {
            return Err(Error::Usage(
                "repeatlast thresholds must not exceed approx thresholds".into(),
            ));
        }
        Ok(Self {
            approx_after_updates: approx_upd,
            exact_after_updates: exact_upd,
            approx_after_queries: u64::MAX,
            exact_after_queries: u64::MAX,
            approx_after_secs: approx_secs,
            exact_after_secs: exact_secs,
        })
    }

    /// Thresholds as JSON (surfaced by the wire `stats` op).
    pub fn to_json(&self) -> Json {
        let num_u64 = |v: u64| {
            if v == u64::MAX {
                Json::Null
            } else {
                Json::Num(v as f64)
            }
        };
        let num_f64 = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        Json::obj(vec![
            ("approx_after_updates", num_u64(self.approx_after_updates)),
            ("exact_after_updates", num_u64(self.exact_after_updates)),
            ("approx_after_queries", num_u64(self.approx_after_queries)),
            ("exact_after_queries", num_u64(self.exact_after_queries)),
            ("approx_after_secs", num_f64(self.approx_after_secs)),
            ("exact_after_secs", num_f64(self.exact_after_secs)),
        ])
    }
}

impl std::str::FromStr for StalenessPolicy {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        Self::parse_spec(s)
    }
}

impl UdfSuite for StalenessPolicy {
    fn on_query(&mut self, ctx: &QueryContext) -> Action {
        self.decide(ctx.updates_since_refresh, ctx.snapshot_age_queries, ctx.snapshot_age_secs)
    }
}

/// Postpone applying updates until at least `min_pending` operations have
/// accumulated (a `BeforeUpdates` batching rule); composes with an inner
/// `OnQuery` policy.
#[derive(Debug)]
pub struct BatchingPolicy<P: UdfSuite> {
    /// Minimum buffered operations before updates are applied.
    pub min_pending: usize,
    /// Inner policy deciding the action.
    pub inner: P,
}

impl<P: UdfSuite> UdfSuite for BatchingPolicy<P> {
    fn before_updates(&mut self, pending: &[EdgeOp], stats: &UpdateStatistics) -> bool {
        let _ = stats;
        pending.len() >= self.min_pending
    }

    fn on_query(&mut self, ctx: &QueryContext) -> Action {
        // If updates were postponed the cached result is still exact w.r.t.
        // the applied graph — repeating is free.
        if ctx.stats.pending_total() > 0 && ctx.stats.pending_total() < self.min_pending {
            Action::RepeatLast
        } else {
            self.inner.on_query(ctx)
        }
    }

    fn on_query_result(&mut self, ctx: &QueryContext, action: Action, stats: &ExecStats) {
        self.inner.on_query_result(ctx, action, stats);
    }
}

/// A recording wrapper that logs every decision (used by tests and the
/// experiment harness to audit policies).
#[derive(Debug, Default)]
pub struct RecordingSuite<P: UdfSuite> {
    /// Inner policy.
    pub inner: P,
    /// Actions taken, in order.
    pub actions: Vec<Action>,
    /// `(on_start, on_stop)` call counts.
    pub lifecycle: (u32, u32),
}

impl<P: UdfSuite> UdfSuite for RecordingSuite<P> {
    fn on_start(&mut self) {
        self.lifecycle.0 += 1;
        self.inner.on_start();
    }

    fn before_updates(&mut self, pending: &[EdgeOp], stats: &UpdateStatistics) -> bool {
        self.inner.before_updates(pending, stats)
    }

    fn on_query(&mut self, ctx: &QueryContext) -> Action {
        let a = self.inner.on_query(ctx);
        self.actions.push(a);
        a
    }

    fn on_query_result(&mut self, ctx: &QueryContext, action: Action, stats: &ExecStats) {
        self.inner.on_query_result(ctx, action, stats);
    }

    fn on_stop(&mut self) {
        self.lifecycle.1 += 1;
        self.inner.on_stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(touched: usize, total: usize, since_exact: u64) -> QueryContext {
        QueryContext {
            query_id: 1,
            stats: UpdateStatistics {
                touched_vertices: touched,
                total_vertices: total,
                pending_add_edges: touched, // representative
                ..Default::default()
            },
            num_vertices: total,
            num_edges: total * 4,
            queries_since_exact: since_exact,
            snapshot_age_queries: 0,
            snapshot_age_secs: 0.0,
            updates_since_refresh: 0,
        }
    }

    #[test]
    fn change_ratio_policy_three_bands() {
        let mut p = ChangeRatioPolicy::new(0.01, 0.5);
        assert_eq!(p.on_query(&ctx(1, 1000, 0)), Action::RepeatLast);
        assert_eq!(p.on_query(&ctx(100, 1000, 0)), Action::ComputeApproximate);
        assert_eq!(p.on_query(&ctx(900, 1000, 0)), Action::ComputeExact);
    }

    #[test]
    fn periodic_policy_refreshes() {
        let mut p = PeriodicExactPolicy::new(3);
        assert_eq!(p.on_query(&ctx(10, 100, 0)), Action::ComputeApproximate);
        assert_eq!(p.on_query(&ctx(10, 100, 1)), Action::ComputeApproximate);
        assert_eq!(p.on_query(&ctx(10, 100, 2)), Action::ComputeExact);
    }

    #[test]
    fn sla_tiers_behave() {
        let mut gold = SlaPolicy { tier: SlaTier::Gold };
        assert_eq!(gold.on_query(&ctx(0, 100, 0)), Action::ComputeExact);
        let mut silver = SlaPolicy { tier: SlaTier::Silver { refresh: 2 } };
        assert_eq!(silver.on_query(&ctx(5, 100, 0)), Action::ComputeApproximate);
        assert_eq!(silver.on_query(&ctx(5, 100, 1)), Action::ComputeExact);
        let mut bronze = SlaPolicy { tier: SlaTier::Bronze };
        assert_eq!(bronze.on_query(&ctx(0, 100_000, 0)), Action::RepeatLast);
        assert_eq!(bronze.on_query(&ctx(5_000, 100_000, 0)), Action::ComputeApproximate);
    }

    #[test]
    fn staleness_policy_escalates_and_repeats_when_clean() {
        let mut p = StalenessPolicy::new(1, 100, 4, 16, 1.0, 30.0);
        // No accumulated updates: always repeat, however old the snapshot.
        assert_eq!(p.decide(0, 1_000, 1e6), Action::RepeatLast);
        // One update: approximate; crossing any exact threshold: exact.
        assert_eq!(p.decide(1, 0, 0.0), Action::ComputeApproximate);
        assert_eq!(p.decide(100, 0, 0.0), Action::ComputeExact);
        assert_eq!(p.decide(1, 16, 0.0), Action::ComputeExact);
        assert_eq!(p.decide(1, 0, 30.0), Action::ComputeExact);
        // Below the approximate thresholds entirely: repeat.
        let lazy = StalenessPolicy::new(10, 100, 4, 16, 1.0, 30.0);
        assert_eq!(lazy.decide(3, 0, 0.0), Action::RepeatLast);
        // The UDF wiring reads the context's staleness fields.
        let mut c = ctx(5, 100, 0);
        c.updates_since_refresh = 1;
        c.snapshot_age_queries = 20;
        assert_eq!(p.on_query(&c), Action::ComputeExact);
    }

    #[test]
    fn staleness_policy_parses_the_cli_spec() {
        let p = StalenessPolicy::parse_spec("repeatlast:2:10,approx:30:5000").unwrap();
        assert_eq!(p.approx_after_updates, 10);
        assert_eq!(p.exact_after_updates, 5000);
        assert_eq!(p.approx_after_secs, 2.0);
        assert_eq!(p.exact_after_secs, 30.0);
        // query-age thresholds are disabled by specs
        assert_eq!(p.approx_after_queries, u64::MAX);
        assert_eq!(p.decide(11, 0, 0.0), Action::ComputeApproximate);
        assert_eq!(p.decide(11, 0, 31.0), Action::ComputeExact);

        // approx segment is optional: exact escalation disabled
        let p = "repeatlast:1:1".parse::<StalenessPolicy>().unwrap();
        assert_eq!(p.decide(1_000_000, 0, 1e9), Action::ComputeApproximate);

        for bad in [
            "",
            "approx:1:1",               // repeatlast segment required
            "repeatlast:1",             // wrong arity
            "repeatlast:x:1",           // bad age
            "repeatlast:1:x",           // bad count
            "fast:1:1",                 // unknown tier
            "repeatlast:9:9,approx:1:1", // non-monotone
            "repeatlast:1:1,repeatlast:2:2", // duplicate
        ] {
            assert!(StalenessPolicy::parse_spec(bad).is_err(), "spec {bad:?} must fail");
        }
    }

    #[test]
    fn staleness_policy_degrades_under_pressure() {
        let p = StalenessPolicy::new(1, 100, 4, 16, 1.0, 30.0);
        // Idle queue: the base decision stands.
        assert_eq!(p.decide_under_pressure(100, 0, 0.0, 0.0), Action::ComputeExact);
        // Half-full: exact degrades one rung to approximate.
        assert_eq!(p.decide_under_pressure(100, 0, 0.0, 0.5), Action::ComputeApproximate);
        assert_eq!(p.decide_under_pressure(1, 0, 0.0, 0.5), Action::ComputeApproximate);
        // Saturated: everything degrades to repeating the snapshot.
        assert_eq!(p.decide_under_pressure(100, 0, 0.0, 1.0), Action::RepeatLast);
    }

    #[test]
    fn staleness_policy_json_reports_thresholds() {
        let p = StalenessPolicy::parse_spec("repeatlast:2:10").unwrap();
        let j = p.to_json();
        assert_eq!(j.get("approx_after_updates").unwrap().as_u64(), Some(10));
        // disabled thresholds render as null, not a magic number
        assert!(matches!(j.get("exact_after_updates"), Some(Json::Null)));
        assert!(matches!(j.get("exact_after_secs"), Some(Json::Null)));
    }

    #[test]
    fn batching_policy_postpones_small_batches() {
        let mut p = BatchingPolicy { min_pending: 10, inner: AlwaysApproximate };
        assert!(!p.before_updates(&[EdgeOp::add(1, 2)], &UpdateStatistics::default()));
        let many: Vec<EdgeOp> = (0..10).map(|i| EdgeOp::add(i, i + 1)).collect();
        assert!(p.before_updates(&many, &UpdateStatistics::default()));
        // small pending ⇒ repeat
        assert_eq!(p.on_query(&ctx(2, 100, 0)), Action::RepeatLast);
    }

    #[test]
    fn recording_suite_captures_everything() {
        let mut p = RecordingSuite { inner: AlwaysExact, actions: vec![], lifecycle: (0, 0) };
        p.on_start();
        let _ = p.on_query(&ctx(1, 10, 0));
        let _ = p.on_query(&ctx(2, 10, 0));
        p.on_stop();
        assert_eq!(p.actions, vec![Action::ComputeExact, Action::ComputeExact]);
        assert_eq!(p.lifecycle, (1, 1));
    }
}
