//! L3 coordinator: the Alg.-1 engine, the five-UDF API, built-in serving
//! policies, the read/write-split snapshot layer, and the threaded query
//! server.

pub mod adaptive;
pub mod checkpoint;
pub mod engine;
pub mod policies;
pub mod protocol;
pub mod server;
pub mod serving;
pub mod sharded;
pub mod subscription;
pub mod udf;
pub mod wal;
