//! L3 coordinator: the Alg.-1 engine, the five-UDF API, built-in serving
//! policies, and the threaded query server.

pub mod adaptive;
pub mod checkpoint;
pub mod engine;
pub mod policies;
pub mod server;
pub mod udf;
