//! The five-UDF program structure (Alg. 1, §4).
//!
//! “The API of GraphBolt consists of these five ordered UDFs which
//! specify the execution logic that will guide the approximate
//! processing”: `OnStart`, `BeforeUpdates`, `OnQuery`, `OnQueryResult`,
//! `OnStop`. Users needing custom behaviour implement [`UdfSuite`];
//! built-in policies for “the simplest rules such as threshold
//! comparisons, fixed values, intervals and change ratios” live in
//! [`crate::coordinator::policies`].

use crate::runtime::executor::Backend;
use crate::stream::buffer::UpdateStatistics;
use crate::stream::event::EdgeOp;

/// The action indicator returned by `OnQuery` (§4 item 3): serve from
/// cache, approximate over the summary graph, or recompute exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// a) return the last calculated result.
    RepeatLast,
    /// b) compute an approximation over the summary graph.
    ComputeApproximate,
    /// c) exact recomputation over the complete graph.
    ComputeExact,
}

impl std::fmt::Display for Action {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Action::RepeatLast => "repeat-last",
            Action::ComputeApproximate => "approximate",
            Action::ComputeExact => "exact",
        };
        f.write_str(s)
    }
}

/// Context handed to `OnQuery`: everything Alg. 1 exposes (query id,
/// update statistics, graph dimensions, history).
#[derive(Clone, Debug)]
pub struct QueryContext {
    /// Unique, monotonically increasing query id (measurement point `t`).
    pub query_id: u64,
    /// Statistics of the updates pending when the query arrived.
    pub stats: UpdateStatistics,
    /// |V| after updates were applied.
    pub num_vertices: usize,
    /// |E| after updates were applied.
    pub num_edges: usize,
    /// Queries since the last exact computation.
    pub queries_since_exact: u64,
    /// Queries served since the engine last published a fresh
    /// [`crate::coordinator::serving::RankSnapshot`] (staleness in
    /// queries).
    pub snapshot_age_queries: u64,
    /// Wall seconds since that snapshot was produced (staleness in time).
    pub snapshot_age_secs: f64,
    /// Effective (coalesced) updates applied since the ranking was last
    /// recomputed — includes the batch this query just applied. The
    /// accumulated-error signal staleness policies escalate on.
    pub updates_since_refresh: u64,
}

/// Per-query execution statistics handed to `OnQueryResult` (§4 item 4).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Wall time serving the query (seconds).
    pub elapsed_secs: f64,
    /// Backend that served it (None for repeat-last).
    pub backend: Option<Backend>,
    /// |K| (summary vertices), 0 unless approximate.
    pub summary_vertices: usize,
    /// |E_K| + |E_B| (summary edges), 0 unless approximate.
    pub summary_edges: usize,
    /// Power iterations executed.
    pub iterations: usize,
}

/// The five ordered user-defined functions.
///
/// Default implementations reproduce the paper's evaluation behaviour:
/// always apply pending updates, always compute the approximate
/// (summarized) result.
pub trait UdfSuite: Send {
    /// Preparatory hook (resources, files, …).
    fn on_start(&mut self) {}

    /// Called after a query arrives, before updates are applied. Return
    /// `false` to postpone applying updates (they stay buffered).
    fn before_updates(&mut self, _pending: &[EdgeOp], _stats: &UpdateStatistics) -> bool {
        true
    }

    /// Decide how to serve this query.
    fn on_query(&mut self, _ctx: &QueryContext) -> Action {
        Action::ComputeApproximate
    }

    /// Invoked after the response is computed.
    fn on_query_result(&mut self, _ctx: &QueryContext, _action: Action, _stats: &ExecStats) {}

    /// Symmetrical to `on_start`.
    fn on_stop(&mut self) {}
}

/// The default suite: paper-protocol behaviour (apply everything,
/// always approximate).
#[derive(Clone, Copy, Debug, Default)]
pub struct DefaultSuite;

impl UdfSuite for DefaultSuite {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_suite_applies_and_approximates() {
        let mut s = DefaultSuite;
        s.on_start();
        assert!(s.before_updates(&[], &UpdateStatistics::default()));
        let ctx = QueryContext {
            query_id: 1,
            stats: UpdateStatistics::default(),
            num_vertices: 10,
            num_edges: 20,
            queries_since_exact: 1,
            snapshot_age_queries: 0,
            snapshot_age_secs: 0.0,
            updates_since_refresh: 0,
        };
        assert_eq!(s.on_query(&ctx), Action::ComputeApproximate);
        s.on_stop();
    }

    #[test]
    fn action_display() {
        assert_eq!(Action::RepeatLast.to_string(), "repeat-last");
        assert_eq!(Action::ComputeExact.to_string(), "exact");
    }
}
