//! Micro-benchmark harness (substrate for the unavailable `criterion`).
//!
//! Warmup + timed iterations with outlier-aware statistics; results print
//! as an aligned table and export to CSV. Used by the `cargo bench`
//! targets (`rust/benches/*.rs`, `harness = false`).

use crate::util::stats::{percentile, Summary};
use crate::util::timer::{fmt_duration, Stopwatch};

/// One benchmark's measurements.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration seconds.
    pub samples: Vec<f64>,
    pub summary: Summary,
}

impl BenchResult {
    /// Median iteration time in seconds.
    pub fn median_secs(&self) -> f64 {
        self.summary.p50
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (discarded).
    pub warmup: usize,
    /// Measured iterations.
    pub iters: usize,
    /// Minimum total measured time; iterations repeat until reached.
    pub min_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup: 3, iters: 10, min_secs: 0.05 }
    }
}

/// The bench harness: collects named results.
#[derive(Debug, Default)]
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bencher {
    /// Harness with default config.
    pub fn new() -> Self {
        Self { config: BenchConfig::default(), results: Vec::new() }
    }

    /// Harness with explicit config.
    pub fn with_config(config: BenchConfig) -> Self {
        Self { config, results: Vec::new() }
    }

    /// Benchmark a closure; the closure's return value is black-boxed so
    /// the optimizer cannot elide the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.config.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.config.iters);
        let total = Stopwatch::start();
        loop {
            for _ in 0..self.config.iters {
                let sw = Stopwatch::start();
                std::hint::black_box(f());
                samples.push(sw.secs());
            }
            if total.secs() >= self.config.min_secs {
                break;
            }
        }
        let summary = Summary::of(&samples);
        self.results.push(BenchResult { name: name.to_string(), samples, summary });
        self.results.last().unwrap()
    }

    /// All collected results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render an aligned report table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let name_w = self.results.iter().map(|r| r.name.len()).max().unwrap_or(4).max(4);
        out.push_str(&format!(
            "{:<name_w$}  {:>10}  {:>10}  {:>10}  {:>10}  {:>6}\n",
            "name", "median", "mean", "p95", "max", "iters"
        ));
        for r in &self.results {
            out.push_str(&format!(
                "{:<name_w$}  {:>10}  {:>10}  {:>10}  {:>10}  {:>6}\n",
                r.name,
                fmt_duration(r.summary.p50),
                fmt_duration(r.summary.mean),
                fmt_duration(percentile(&r.samples, 95.0)),
                fmt_duration(r.summary.max),
                r.samples.len(),
            ));
        }
        out
    }

    /// Export results as CSV (`name,median_secs,mean_secs,p95_secs,iters`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,median_secs,mean_secs,p95_secs,iters\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                r.name,
                r.summary.p50,
                r.summary.mean,
                percentile(&r.samples, 95.0),
                r.samples.len()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples_and_reports() {
        let mut b = Bencher::with_config(BenchConfig { warmup: 1, iters: 5, min_secs: 0.0 });
        b.bench("noop", || 1 + 1);
        b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(b.results().len(), 2);
        assert!(b.results()[0].samples.len() >= 5);
        let rep = b.report();
        assert!(rep.contains("noop") && rep.contains("spin") && rep.contains("median"));
        let csv = b.to_csv();
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn min_secs_forces_more_iterations() {
        let mut b = Bencher::with_config(BenchConfig { warmup: 0, iters: 2, min_secs: 0.01 });
        let r = b.bench("tiny", || 0);
        assert!(r.samples.len() > 2, "should repeat until min time");
    }

    #[test]
    fn median_is_positive_for_real_work() {
        let mut b = Bencher::with_config(BenchConfig { warmup: 1, iters: 5, min_secs: 0.0 });
        let r = b.bench("sleepish", || std::thread::sleep(std::time::Duration::from_micros(100)));
        assert!(r.median_secs() >= 50e-6);
    }
}
