//! In-repo micro-benchmark harness (criterion substitute; see DESIGN.md
//! §Substitutions).

pub mod bencher;
pub use bencher::{BenchConfig, Bencher};
